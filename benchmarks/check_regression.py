"""Benchmark regression gate.

Diffs fresh ``BENCH_*.json`` smoke artifacts (``python -m benchmarks.run
--smoke --out DIR``) against the committed baselines in
``benchmarks/baselines/`` and fails CI when performance or contracts
regress:

* **gauges** — machine-portable RATIO metrics only (speedups, dedup
  rates, example savings): a fresh value more than ``--tolerance``
  (default 20%) below its baseline fails.  Absolute wall seconds are
  never compared — the committed baselines come from a different machine
  than the CI runner, and only ratios survive that move.
* **contracts** — every boolean acceptance flag in the fresh payloads
  (``ok``, ``*identical*``, ``bounded``, ``no_rerun``, ``*match*``,
  ``*zero_lost*``): any ``False`` fails regardless of baselines.
* **coverage** — a baseline artifact whose fresh counterpart is missing
  fails (a suite silently dropping out of the smoke run is itself a
  regression); a fresh artifact without a baseline is only noted, so new
  benchmarks can land before their baseline is committed.

Writes a JSON diff report (``--report``) for the CI artifact upload and
exits non-zero on any failure.

  PYTHONPATH=src python -m benchmarks.check_regression \\
      --fresh bench-artifacts [--baselines benchmarks/baselines] \\
      [--report bench-artifacts/regression_report.json]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Any

#: ratio metrics compared against baseline, per artifact (dotted paths).
#: Higher is better for every gauge listed here.
GAUGES: dict[str, list[str]] = {
    "BENCH_serving.json": [
        "speedup",
        "dedup_rate",
        "replica_scaling.speedup_2",
        "replica_scaling.speedup_4",
        "shared_prefix.speedup",
        "shared_prefix.prefix_reuse",
        "chaos.completed_fraction",
        "quantized.capacity_ratio",
        "quantized.speedup",
    ],
    "BENCH_concurrency.json": ["speedup_at_4_inflight"],
    "BENCH_suite.json": ["speedup"],
    "BENCH_stats.json": ["acceptance.speedup"],
    "BENCH_adaptive.json": ["example_savings"],
    "BENCH_streaming.json": [],  # contract flags only
}

#: boolean keys treated as acceptance contracts when False
def _is_contract_key(key: str) -> bool:
    return (
        key == "ok"
        or "identical" in key
        or "match" in key
        or "zero_lost" in key
        or key in ("bounded", "no_rerun", "resumable", "parity")
    )


def _lookup(payload: Any, dotted: str) -> Any:
    cur = payload
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def _contract_violations(payload: Any, prefix: str = "") -> list[str]:
    out: list[str] = []
    if isinstance(payload, dict):
        for k, v in payload.items():
            path = f"{prefix}.{k}" if prefix else k
            if isinstance(v, bool):
                if _is_contract_key(k) and v is False:
                    out.append(path)
            elif isinstance(v, (dict, list)):
                out.extend(_contract_violations(v, path))
    elif isinstance(payload, list):
        for i, v in enumerate(payload):
            out.extend(_contract_violations(v, f"{prefix}[{i}]"))
    return out


def check(
    fresh_dir: pathlib.Path,
    baseline_dir: pathlib.Path,
    tolerance: float,
) -> dict:
    failures: list[str] = []
    notes: list[str] = []
    gauges: list[dict] = []

    baselines = sorted(baseline_dir.glob("BENCH_*.json"))
    fresh_files = sorted(fresh_dir.glob("BENCH_*.json"))
    if not baselines:
        failures.append(f"no baselines found under {baseline_dir}")
    if not fresh_files:
        failures.append(f"no fresh artifacts found under {fresh_dir}")

    fresh_payloads: dict[str, Any] = {}
    for path in fresh_files:
        try:
            fresh_payloads[path.name] = json.loads(path.read_text())
        except json.JSONDecodeError as e:
            failures.append(f"{path.name}: unreadable fresh artifact ({e})")

    # contracts: every boolean acceptance flag in every fresh payload
    for name, payload in sorted(fresh_payloads.items()):
        for path in _contract_violations(payload):
            failures.append(f"{name}: contract flag {path} is False")

    for bpath in baselines:
        name = bpath.name
        base = json.loads(bpath.read_text())
        if name not in fresh_payloads:
            failures.append(
                f"{name}: baseline exists but the smoke run produced no "
                f"fresh artifact"
            )
            continue
        fresh = fresh_payloads[name]
        for dotted in GAUGES.get(name, []):
            bval, fval = _lookup(base, dotted), _lookup(fresh, dotted)
            if not isinstance(bval, (int, float)) or isinstance(bval, bool):
                notes.append(f"{name}: baseline lacks gauge {dotted}")
                continue
            if not isinstance(fval, (int, float)) or isinstance(fval, bool):
                failures.append(
                    f"{name}: gauge {dotted} missing from fresh artifact "
                    f"(baseline {bval:.3f})"
                )
                continue
            floor = bval * (1.0 - tolerance)
            entry = {
                "artifact": name, "gauge": dotted,
                "baseline": bval, "fresh": fval,
                "floor": floor, "ok": fval >= floor,
            }
            gauges.append(entry)
            if not entry["ok"]:
                failures.append(
                    f"{name}: {dotted} regressed {bval:.3f} -> {fval:.3f} "
                    f"(floor {floor:.3f} at {tolerance:.0%} tolerance)"
                )

    for name in sorted(set(fresh_payloads) - {b.name for b in baselines}):
        notes.append(
            f"{name}: no committed baseline — commit "
            f"benchmarks/baselines/{name} to gate it"
        )

    return {
        "ok": not failures,
        "tolerance": tolerance,
        "failures": failures,
        "notes": notes,
        "gauges": gauges,
    }


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--fresh", required=True,
                   help="directory with fresh BENCH_*.json artifacts")
    p.add_argument("--baselines", default="benchmarks/baselines")
    p.add_argument("--report", default="",
                   help="where to write the JSON diff report")
    p.add_argument("--tolerance", type=float, default=0.20,
                   help="allowed relative drop per gauge (default 0.20)")
    args = p.parse_args()

    report = check(
        pathlib.Path(args.fresh), pathlib.Path(args.baselines),
        args.tolerance,
    )
    if args.report:
        out = pathlib.Path(args.report)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(report, indent=2))
        print(f"wrote {out}")
    for g in report["gauges"]:
        mark = "ok " if g["ok"] else "REGRESSED"
        print(
            f"{mark} {g['artifact']}:{g['gauge']} "
            f"baseline={g['baseline']:.3f} fresh={g['fresh']:.3f}"
        )
    for n in report["notes"]:
        print(f"note: {n}")
    if not report["ok"]:
        for f in report["failures"]:
            print(f"FAIL: {f}", file=sys.stderr)
        raise SystemExit(1)
    print(f"benchmark regression gate passed ({len(report['gauges'])} gauges)")


if __name__ == "__main__":
    main()
