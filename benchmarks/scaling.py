"""Paper Fig 2: throughput vs executor count — linear until the global rate
limit saturates."""

from __future__ import annotations

import time

from benchmarks.simkit import simulate_eval


def run(n_examples: int = 20_000) -> list[str]:
    lines = []
    for workers in (1, 2, 4, 8, 12, 16):
        t0 = time.perf_counter()
        res = simulate_eval(n_examples, workers)
        us = (time.perf_counter() - t0) * 1e6
        lines.append(
            f"fig2_scaling_w{workers},{us:.0f},"
            f"throughput={res.throughput_per_min:.0f}/min "
            f"p50={res.latency_p50_ms:.0f}ms waited={res.rate_limited_s:.1f}s"
        )
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
