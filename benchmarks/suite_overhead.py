"""Per-task setup overhead: N sequential ``EvalRunner.evaluate`` calls
(fresh engine + cache handle + limiter + pool each time) vs one
``EvalSession.run_suite`` (shared resources, initialize once).

Emits ``BENCH_suite.json`` with wall times, per-task setup cost, and
engine initialization counts for both paths.

  PYTHONPATH=src python -m benchmarks.suite_overhead [--local]
"""

from __future__ import annotations

import json
import tempfile
import time

from repro.core import (
    EngineModelConfig,
    EvalRunner,
    EvalSession,
    EvalSuite,
    EvalTask,
    InferenceConfig,
    MetricConfig,
    SimulatedAPIEngine,
    StatisticsConfig,
)
from repro.core.engines import LocalJaxEngine
from repro.data import mixed_examples

from benchmarks import artifacts

MODELS = {
    "api": [
        EngineModelConfig(provider="openai", model_name="gpt-4o-mini"),
        EngineModelConfig(provider="anthropic", model_name="claude-3-haiku"),
    ],
    "local": [
        EngineModelConfig(provider="local", model_name="qwen3-4b", reduced=True),
    ],
}


class _InitCounter:
    """Count engine initializations without changing behaviour."""

    def __init__(self) -> None:
        self.count = 0
        self._origs = {}

    def __enter__(self) -> "_InitCounter":
        for cls in (SimulatedAPIEngine, LocalJaxEngine):
            orig = cls.initialize
            self._origs[cls] = orig

            def counting(engine, _orig=orig):
                self.count += 1
                _orig(engine)

            cls.initialize = counting
        return self

    def __exit__(self, *exc) -> None:
        for cls, orig in self._origs.items():
            cls.initialize = orig


def _tasks(models, root: str, n_tasks: int) -> list[tuple[EvalTask, list[dict]]]:
    out = []
    for t in range(n_tasks):
        rows = mixed_examples(40, seed=t)
        out.append(
            (
                EvalTask(
                    task_id=f"bench-task-{t}",
                    model=models[0],
                    inference=InferenceConfig(
                        batch_size=10, n_workers=4,
                        cache_dir=f"{root}/task{t}",
                    ),
                    metrics=(MetricConfig("token_f1"), MetricConfig("exact_match")),
                    statistics=StatisticsConfig(
                        bootstrap_iterations=100, ci_method="percentile"
                    ),
                ),
                rows,
            )
        )
    return out


def run(*, local: bool = False, n_tasks: int = 3) -> list[str]:
    models = MODELS["local" if local else "api"]
    n_jobs = len(models) * n_tasks

    # -- legacy path: fresh runner resources per (model, task) ----------------
    root = tempfile.mkdtemp()
    tasks = _tasks(models, root, n_tasks)
    with _InitCounter() as runner_inits:
        t0 = time.perf_counter()
        runner = EvalRunner()
        for model in models:
            for task, rows in tasks:
                runner.evaluate(rows, task.with_model(model))
        runner_s = time.perf_counter() - t0

    # -- session path: one suite over the same (model, task) grid -------------
    root = tempfile.mkdtemp()
    tasks = _tasks(models, root, n_tasks)
    suite = EvalSuite("overhead")
    for task, rows in tasks:
        suite.add_task(task, rows)
    suite.sweep_models(models)
    with _InitCounter() as session_inits:
        t0 = time.perf_counter()
        with EvalSession() as session:
            session.run_suite(suite)
        session_s = time.perf_counter() - t0

    payload = {
        "mode": "local" if local else "api",
        "n_models": len(models),
        "n_tasks": n_tasks,
        "runner_sequential_s": runner_s,
        "session_suite_s": session_s,
        "runner_per_task_s": runner_s / n_jobs,
        "session_per_task_s": session_s / n_jobs,
        "speedup": runner_s / session_s if session_s > 0 else float("inf"),
        "engine_inits_runner": runner_inits.count,
        "engine_inits_session": session_inits.count,
    }
    artifacts.write_bench("BENCH_suite.json", payload)

    return [
        f"suite_overhead_runner,{runner_s * 1e6 / n_jobs:.0f},"
        f"inits={runner_inits.count} total={runner_s:.2f}s",
        f"suite_overhead_session,{session_s * 1e6 / n_jobs:.0f},"
        f"inits={session_inits.count} total={session_s:.2f}s "
        f"speedup={payload['speedup']:.2f}x",
    ]


def main() -> None:
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--local", action="store_true",
                   help="use the LocalJaxEngine (real init cost) instead of "
                        "the simulated API engines")
    p.add_argument("--n-tasks", type=int, default=3)
    args = p.parse_args()
    for line in run(local=args.local, n_tasks=args.n_tasks):
        print(line)
    print(f"wrote {artifacts.bench_path('BENCH_suite.json')}")


if __name__ == "__main__":
    main()
