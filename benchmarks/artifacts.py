"""Benchmark artifact output directory.

Every suite that emits a ``BENCH_*.json`` payload writes it through
:func:`write_bench`, so ``python -m benchmarks.run --out DIR`` collects
the artifacts in one clean directory instead of littering the repo root
(and CI's regression gate diffs that directory against the committed
baselines in ``benchmarks/baselines/``).  The default stays the current
working directory for bare ``python -m benchmarks.<suite>`` runs.
"""

from __future__ import annotations

import json
import pathlib
from typing import Any

_OUT_DIR = pathlib.Path(".")


def set_out_dir(path: str | pathlib.Path) -> pathlib.Path:
    global _OUT_DIR
    _OUT_DIR = pathlib.Path(path)
    _OUT_DIR.mkdir(parents=True, exist_ok=True)
    return _OUT_DIR


def out_dir() -> pathlib.Path:
    return _OUT_DIR


def bench_path(name: str) -> pathlib.Path:
    return _OUT_DIR / name


def write_bench(name: str, payload: Any) -> pathlib.Path:
    path = bench_path(name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return path
