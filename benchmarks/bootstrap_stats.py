"""Statistics-stage scaling: numpy (B, n) weight-matrix path vs the
device/blocked chunked-partials backend (ISSUE 4 acceptance).

Measures, for the full bootstrap aggregation of a task's metrics
(replicate accumulation + percentile CI extraction):

* wall-clock — steady-state, after a same-shape warmup so the pallas
  backend's one-time XLA compile is amortized the way it is across a
  streaming run's chunks;
* peak host allocation — each point runs in a fresh spawned subprocess
  and reports its ``ru_maxrss`` high-water mark minus a baseline
  subprocess (same imports, same data, no engine work), which captures
  allocations tracemalloc cannot see (XLA buffers); the Python-heap
  tracemalloc peak is reported alongside.

Also cross-checks CI endpoints of the two weight streams (host Philox vs
kernel counter-mixer, run both natively and through the Pallas
interpreter) within Monte-Carlo tolerance.

Emits ``BENCH_stats.json``.

  PYTHONPATH=src python -m benchmarks.bootstrap_stats [--smoke]
"""

from __future__ import annotations

import json
import multiprocessing as mp
import time

from benchmarks import artifacts

#: the acceptance point: device/blocked backend must cut wall-clock >= 5x
#: and peak host allocation >= 10x vs the (B, n) weight-matrix path here
ACCEPT_N, ACCEPT_B = 100_000, 2_000
N_METRICS = 2  # one binary (exact_match-like), one continuous (token_f1-like)


def _make_scores(n: int, seed: int = 0) -> dict:
    import numpy as np

    rng = np.random.default_rng(seed)
    scores = {
        "exact_match": (rng.random(n) < 0.62).astype(np.float64),
        "token_f1": rng.beta(5.0, 2.0, n),
    }
    scores["token_f1"][:: max(n // 211, 2)] = np.nan  # unscorable examples
    return scores


def _aggregate(backend: str, scores: dict, n_boot: int) -> list:
    """The statistics stage, as the streaming pipeline runs it: fold the
    scores into replicate state, extract percentile CIs."""
    from repro.stats import (
        MetricAccumulator,
        make_bootstrap_engine,
        streaming_ci,
    )

    names = tuple(scores)
    engine = make_bootstrap_engine(backend, n_boot, 0, names)
    engine.update(scores, 0)
    out = []
    for m in names:
        acc = MetricAccumulator()
        acc.update(scores[m])
        iv = streaming_ci(acc, engine.view(m), method="percentile")
        out.append((m, iv.value, iv.lo, iv.hi))
    return out


def _point_worker(backend: str, n: int, n_boot: int, q) -> None:
    """One measurement in a clean process: warmup, then a measured pass."""
    import resource
    import tracemalloc

    scores = _make_scores(n)
    if backend:
        _aggregate(backend, scores, n_boot)  # warmup: XLA compile, pools
        tracemalloc.start()
        t0 = time.perf_counter()
        cis = _aggregate(backend, scores, n_boot)
        wall = time.perf_counter() - t0
        _, py_peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
    else:  # baseline: imports + data only
        from repro.stats import make_bootstrap_engine  # noqa: F401

        wall, py_peak, cis = 0.0, 0, []
    q.put({
        "wall_s": wall,
        "py_heap_peak_mb": py_peak / 1e6,
        "ru_maxrss_mb": resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        / 1024,
        "cis": cis,
    })


def _measure(backend: str, n: int, n_boot: int) -> dict:
    import queue as queue_mod

    ctx = mp.get_context("spawn")
    q = ctx.Queue()
    p = ctx.Process(target=_point_worker, args=(backend, n, n_boot, q))
    p.start()
    try:
        # bounded wait: an OOM-killed subprocess must fail the benchmark,
        # not hang the CI job on a queue that will never be fed
        out = q.get(timeout=600)
    except queue_mod.Empty:
        p.terminate()
        p.join()
        raise RuntimeError(
            f"measurement subprocess produced no result: {backend} n={n} "
            f"B={n_boot} (exitcode={p.exitcode}; killed/OOM?)"
        ) from None
    p.join()
    if p.exitcode != 0:
        raise RuntimeError(f"measurement subprocess failed: {backend} {n}")
    return out


def _parity_check(n: int = 2000, n_boot: int = 300) -> dict:
    """Host Philox vs kernel counter-mixer (native and interpreted): CI
    endpoints within Monte-Carlo tolerance of each other."""
    from repro.stats import PallasBootstrapEngine

    scores = _make_scores(n, seed=7)

    class _Interp(PallasBootstrapEngine):
        mode = "interpret"

    cis = {
        "numpy": _aggregate("numpy", scores, n_boot),
        "pallas": _aggregate("pallas", scores, n_boot),
    }
    interp_engine = _Interp(n_boot, 0, tuple(scores))
    interp_engine.update(scores, 0)
    from repro.stats import MetricAccumulator, streaming_ci

    cis["pallas_interpret"] = []
    for m in scores:
        acc = MetricAccumulator()
        acc.update(scores[m])
        iv = streaming_ci(acc, interp_engine.view(m), method="percentile")
        cis["pallas_interpret"].append((m, iv.value, iv.lo, iv.hi))

    ok = True
    for variant in ("pallas", "pallas_interpret"):
        for (m, v, lo, hi), (_, rv, rlo, rhi) in zip(
            cis[variant], cis["numpy"]
        ):
            width = max(rhi - rlo, 1e-9)
            ok &= abs(v - rv) < 1e-9  # the point estimate is exact moments
            ok &= abs(lo - rlo) <= width and abs(hi - rhi) <= width
    return {"n": n, "n_boot": n_boot, "cis": cis, "ok": bool(ok)}


def run(*, smoke: bool = False) -> list[str]:
    if smoke:
        points = [(20_000, 1_000), (ACCEPT_N, ACCEPT_B)]
    else:
        points = [
            (n, b) for n in (20_000, 100_000) for b in (1_000, 2_000)
        ]

    lines: list[str] = []
    rows = []
    baselines: dict[int, float] = {}
    for n, n_boot in points:
        if n not in baselines:
            baselines[n] = _measure("", n, n_boot)["ru_maxrss_mb"]
        row: dict = {"n": n, "n_boot": n_boot, "n_metrics": N_METRICS}
        for backend in ("numpy", "pallas"):
            r = _measure(backend, n, n_boot)
            row[backend] = {
                "wall_s": r["wall_s"],
                "py_heap_peak_mb": r["py_heap_peak_mb"],
                # the path's own high-water allocation over the baseline
                "host_alloc_mb": max(
                    r["ru_maxrss_mb"] - baselines[n], r["py_heap_peak_mb"]
                ),
            }
        row["speedup"] = row["numpy"]["wall_s"] / max(
            row["pallas"]["wall_s"], 1e-9
        )
        row["host_alloc_ratio"] = row["numpy"]["host_alloc_mb"] / max(
            row["pallas"]["host_alloc_mb"], 1e-3
        )
        rows.append(row)
        lines.append(
            f"bootstrap_stats_n{n}_B{n_boot},{row['pallas']['wall_s'] * 1e6:.0f},"
            f"speedup={row['speedup']:.1f}x "
            f"alloc={row['numpy']['host_alloc_mb']:.0f}MB"
            f"->{row['pallas']['host_alloc_mb']:.0f}MB "
            f"({row['host_alloc_ratio']:.0f}x)"
        )

    parity = _parity_check()
    lines.append(f"bootstrap_stats_parity,0,ok={parity['ok']}")

    accept = next(
        r for r in rows if (r["n"], r["n_boot"]) == (ACCEPT_N, ACCEPT_B)
    )
    payload = {
        "mode": "smoke" if smoke else "default",
        "n_metrics": N_METRICS,
        "points": rows,
        "parity": parity,
        "acceptance": {
            "n": ACCEPT_N,
            "n_boot": ACCEPT_B,
            "speedup": accept["speedup"],
            "host_alloc_ratio": accept["host_alloc_ratio"],
            "ok": bool(
                accept["speedup"] >= 5.0
                and accept["host_alloc_ratio"] >= 10.0
                and parity["ok"]
            ),
        },
    }
    artifacts.write_bench("BENCH_stats.json", payload)

    if not payload["acceptance"]["ok"]:
        raise RuntimeError(
            f"bootstrap stats acceptance failed: {payload['acceptance']}"
        )
    return lines


def main() -> None:
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true")
    args = p.parse_args()
    for line in run(smoke=args.smoke):
        print(line)
    print(f"wrote {artifacts.bench_path('BENCH_stats.json')}")


if __name__ == "__main__":
    main()
