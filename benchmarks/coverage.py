"""Paper Table 5: empirical coverage of 95% CIs on lognormal(sigma=0.5)
data — BCa stays near-nominal at small n, percentile/analytical undercover."""

from __future__ import annotations

import time

import numpy as np

from repro.stats import bca_bootstrap, percentile_bootstrap, t_interval


def run(n_datasets: int = 200, n_boot: int = 300, full: bool = False) -> list[str]:
    if full:
        n_datasets, n_boot = 1000, 1000
    sigma = 0.5
    true_mean = float(np.exp(sigma**2 / 2))
    methods = {
        "percentile": lambda d, s: percentile_bootstrap(d, n_boot=n_boot, seed=s),
        "bca": lambda d, s: bca_bootstrap(d, n_boot=n_boot, seed=s),
        "analytical_t": lambda d, s: t_interval(d),
    }
    lines = []
    rng = np.random.default_rng(0)
    for n in (50, 200, 1000):
        data_sets = [rng.lognormal(0.0, sigma, n) for _ in range(n_datasets)]
        for name, fn in methods.items():
            t0 = time.perf_counter()
            hits = 0
            for s, d in enumerate(data_sets):
                iv = fn(d, s)
                hits += int(iv.lo <= true_mean <= iv.hi)
            dt = time.perf_counter() - t0
            cov = hits / n_datasets
            lines.append(
                f"table5_coverage_{name}_n{n},{dt*1e6/n_datasets:.0f},"
                f"coverage={cov:.3f} target=0.95"
            )
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
