"""Streaming bounded-memory evaluation at paper scale (ISSUE 2 tentpole).

Demonstrates O(chunk) — not O(dataset) — memory: the streaming pipeline's
peak Python-heap allocation stays flat as the dataset grows (it is
dominated by the B x chunk Poisson-weight block), while the in-memory
pipeline's peak grows linearly with n.  Also cross-checks the streaming
Poisson-bootstrap CIs against the in-memory multinomial bootstrap on a
small shared dataset, and proves crash-resume: a run killed mid-way
restarts, skips committed chunks, and reproduces the uninterrupted
metrics exactly.

Emits ``BENCH_streaming.json``.

  PYTHONPATH=src python -m benchmarks.streaming_scale [--smoke|--full]
"""

from __future__ import annotations

import json
import resource
import tempfile
import time
import tracemalloc

from repro.core import (
    EngineModelConfig,
    EvalSession,
    EvalTask,
    InferenceConfig,
    MetricConfig,
    StatisticsConfig,
)
from repro.data import iter_qa_examples, qa_examples
from repro.ft import ChunkCrashMiddleware, Fault, SimulatedCrash

from benchmarks import artifacts

MODEL = EngineModelConfig(provider="openai", model_name="gpt-4o-mini")


def _task(task_id: str, *, streaming: bool, chunk: int, spill: str = "") -> EvalTask:
    t = EvalTask(
        task_id=task_id,
        model=MODEL,
        inference=InferenceConfig(batch_size=256, n_workers=8, cache_dir=""),
        metrics=(MetricConfig("exact_match"), MetricConfig("token_f1")),
        statistics=StatisticsConfig(
            bootstrap_iterations=1000, ci_method="percentile"
        ),
    )
    if streaming:
        t = t.with_streaming(max_memory_rows=chunk, spill_dir=spill)
    return t


def _measured_run(source_factory, task) -> dict:
    """``source_factory`` is called inside the traced region so the
    in-memory path's O(n) dataset list counts toward its peak."""
    tracemalloc.start()
    t0 = time.perf_counter()
    with EvalSession() as session:
        res = session.run_task(source_factory(), task)
    wall = time.perf_counter() - t0
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    n = res.logs.get("streaming", {}).get("n_examples") or len(res.responses)
    return {
        "n": n,
        "wall_s": wall,
        "throughput_per_s": n / wall if wall > 0 else float("inf"),
        "py_heap_peak_mb": peak / 1e6,
        "max_resident_rows": res.logs.get("streaming", {}).get(
            "max_resident_rows", n
        ),
        "metrics": {m: mv.value for m, mv in res.metrics.items()},
    }


def _ci_crosscheck(n: int) -> dict:
    """Streaming vs in-memory CIs on the same rows (Monte-Carlo tolerance:
    bounds within one CI width of each other)."""
    rows = qa_examples(n, seed=42)
    with EvalSession() as session:
        mem = session.run_task(rows, _task("xc-mem", streaming=False, chunk=0))
    with EvalSession() as session:
        stream = session.run_task(
            iter(rows), _task("xc-stream", streaming=True, chunk=max(64, n // 8))
        )
    out: dict = {"n": n, "metrics": {}, "ok": True}
    for m, mv in mem.metrics.items():
        sv = stream.metrics[m]
        width = max(mv.ci[1] - mv.ci[0], 1e-6)
        ok = (
            abs(sv.value - mv.value) < 1e-5
            and abs(sv.ci[0] - mv.ci[0]) <= width
            and abs(sv.ci[1] - mv.ci[1]) <= width
        )
        out["metrics"][m] = {
            "in_memory": {"value": mv.value, "ci": list(mv.ci)},
            "streaming": {"value": sv.value, "ci": list(sv.ci)},
            "ok": ok,
        }
        out["ok"] = out["ok"] and ok
    return out


def _resume_check(n: int, chunk: int) -> dict:
    """Kill a spilling run mid-way, restart, verify skip + identical metrics."""
    spill = tempfile.mkdtemp()
    ref_spill = tempfile.mkdtemp()
    task = _task("resume", streaming=True, chunk=chunk, spill=spill)
    ref_task = _task("resume", streaming=True, chunk=chunk, spill=ref_spill)
    with EvalSession() as session:
        ref = session.run_task(iter_qa_examples(n, seed=7), ref_task)

    crash_after = (n // chunk) // 2
    crash = ChunkCrashMiddleware([Fault(shard=crash_after, attempt=1)])
    calls_before = calls_after = -1
    with EvalSession(middleware=[crash]) as session:
        try:
            session.run_task(iter_qa_examples(n, seed=7), task)
        except SimulatedCrash:
            calls_before = session.accounting.engine_calls
    with EvalSession() as session:
        res = session.run_task(iter_qa_examples(n, seed=7), task)
        calls_after = session.accounting.engine_calls
    identical = all(
        res.metrics[m].value == mv.value and res.metrics[m].ci == mv.ci
        for m, mv in ref.metrics.items()
    )
    return {
        "n": n,
        "chunk": chunk,
        "crashed_after_chunk": crash_after,
        "engine_calls_first_attempt": calls_before,
        "engine_calls_resumed": calls_after,
        "resumed_chunks": res.logs["streaming"]["n_resumed_chunks"],
        "no_rerun": calls_before + calls_after == n,
        "metrics_identical": identical,
        "ok": identical and calls_before + calls_after == n,
    }


def run(*, smoke: bool = False, full: bool = False) -> list[str]:
    if smoke:
        sizes, chunk, xcheck_n, resume_n = [2_000, 5_000], 512, 500, 2_000
    elif full:
        sizes, chunk = [20_000, 100_000, 300_000], 2_048
        xcheck_n, resume_n = 1_000, 4_000
    else:
        sizes, chunk = [20_000, 50_000, 100_000], 2_048
        xcheck_n, resume_n = 1_000, 4_000

    lines = []
    streaming_runs = []
    in_memory_runs = []
    for n in sizes:
        r = _measured_run(
            lambda n=n: iter_qa_examples(n, seed=0),
            _task(f"stream-{n}", streaming=True, chunk=chunk),
        )
        streaming_runs.append(r)
        lines.append(
            f"streaming_scale_n{n},{r['wall_s'] * 1e6 / n:.1f},"
            f"throughput={r['throughput_per_s']:.0f}/s "
            f"peak={r['py_heap_peak_mb']:.1f}MB "
            f"resident_rows={r['max_resident_rows']}"
        )
        if n <= 50_000:  # in-memory contrast capped to keep runtime sane
            rm = _measured_run(
                lambda n=n: qa_examples(n, seed=0),
                _task(f"mem-{n}", streaming=False, chunk=0),
            )
            in_memory_runs.append(rm)
            lines.append(
                f"streaming_scale_inmem_n{n},{rm['wall_s'] * 1e6 / n:.1f},"
                f"peak={rm['py_heap_peak_mb']:.1f}MB"
            )

    # O(chunk) evidence: streaming peak flat across a 5x n range
    peaks = [r["py_heap_peak_mb"] for r in streaming_runs]
    bounded = max(peaks) <= 1.5 * min(peaks)
    xcheck = _ci_crosscheck(xcheck_n)
    resume = _resume_check(resume_n, chunk=max(256, chunk // 4))
    payload = {
        "mode": "smoke" if smoke else ("full" if full else "default"),
        "chunk_size": chunk,
        "bootstrap_iterations": 1000,
        "streaming": streaming_runs,
        "in_memory": in_memory_runs,
        "bounded_memory": bounded,
        "ru_maxrss_mb": resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024,
        "ci_crosscheck": xcheck,
        "resume": resume,
    }
    artifacts.write_bench("BENCH_streaming.json", payload)

    lines.append(
        f"streaming_scale_bounded,0,peaks_mb="
        + "/".join(f"{p:.0f}" for p in peaks)
        + f" bounded={bounded}"
    )
    lines.append(
        f"streaming_scale_ci_crosscheck,0,n={xcheck_n} ok={xcheck['ok']}"
    )
    lines.append(
        f"streaming_scale_resume,0,resumed={resume['resumed_chunks']}chunks "
        f"no_rerun={resume['no_rerun']} identical={resume['metrics_identical']}"
    )
    if not (bounded and xcheck["ok"] and resume["ok"]):
        raise RuntimeError(f"streaming acceptance checks failed: {payload}")
    return lines


def main() -> None:
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--full", action="store_true")
    args = p.parse_args()
    for line in run(smoke=args.smoke, full=args.full):
        print(line)
    print(f"wrote {artifacts.bench_path('BENCH_streaming.json')}")


if __name__ == "__main__":
    main()
