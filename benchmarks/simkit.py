"""Discrete-event simulation of the distributed inference stage.

The paper's Fig 2 / Table 3 measure wall-clock throughput against live
APIs.  Offline we replay the same dynamics with a virtual clock: W workers
process examples serially (per-request latency from the engine's latency
model) under a *global* RPM/TPM budget enforced by the token bucket.  This
reproduces the paper's two regimes exactly: latency-bound linear scaling at
small W, rate-limit saturation at large W.
"""

from __future__ import annotations

import dataclasses

from repro.core.config import EngineModelConfig
from repro.core.engines import SimulatedAPIEngine
from repro.core.ratelimit import TokenBucket


@dataclasses.dataclass
class SimResult:
    examples: int
    workers: int
    wall_s: float
    throughput_per_min: float
    latency_p50_ms: float
    latency_p99_ms: float
    rate_limited_s: float


def simulate_eval(
    n_examples: int,
    n_workers: int,
    *,
    rpm: float = 10_000.0,
    tpm: float = 2_000_000.0,
    base_latency_ms: float = 250.0,
    per_token_ms: float = 0.6,
    schedule_overhead_s: float = 4.0,
    per_shard_overhead_ms: float = 40.0,
    batch_size: int = 50,
    per_worker_concurrency: int = 6,
) -> SimResult:
    engine = SimulatedAPIEngine(
        EngineModelConfig(provider="openai", model_name="gpt-4o"),
        base_latency_ms=base_latency_ms,
        per_token_ms=per_token_ms,
    )
    engine.initialize()

    # per-worker buckets with a virtual clock each (paper Algorithm 1)
    clocks = [0.0] * n_workers

    def make_bucket(i: int) -> TokenBucket:
        b = TokenBucket(
            rpm, tpm, n_workers,
            clock=lambda i=i: clocks[i],
            sleep=lambda s, i=i: clocks.__setitem__(i, clocks[i] + s),
        )
        # steady-state measurement: don't let the initial burst allowance
        # mask the rate limit (paper Fig 2 reports sustained throughput)
        b.request_tokens = 0.1 * b.r
        b.token_tokens = 0.1 * b.t
        return b

    buckets = [make_bucket(i) for i in range(n_workers)]

    # shards round-robin over workers; each worker runs its shards serially
    shards = [
        list(range(i, min(i + batch_size, n_examples)))
        for i in range(0, n_examples, batch_size)
    ]
    latencies: list[float] = []
    waited = 0.0
    for si, shard in enumerate(shards):
        w = si % n_workers
        clocks[w] += per_shard_overhead_ms / 1e3
        for idx in shard:
            prompt = f"example {idx} with a moderately long question body"
            # ~200 tokens/request (paper's workload: TPM is then slack and
            # the 10k RPM limit is the binding constraint, saturating near
            # 9.8k examples/min as in Fig 2)
            waited += buckets[w].acquire(120 + 64)
            # deterministic latency from the engine's model; each executor
            # pipelines `per_worker_concurrency` in-flight requests (async
            # HTTP inside the Pandas-UDF batch), so the worker clock
            # advances by latency / concurrency per request
            resp = engine.infer(
                __import__("repro.core.engines", fromlist=["InferenceRequest"])
                .InferenceRequest(prompt, max_tokens=64)
            )
            clocks[w] += resp.latency_ms / 1e3 / per_worker_concurrency
            latencies.append(resp.latency_ms)

    wall = max(clocks) + schedule_overhead_s
    latencies.sort()
    return SimResult(
        examples=n_examples,
        workers=n_workers,
        wall_s=wall,
        throughput_per_min=n_examples / wall * 60.0,
        latency_p50_ms=latencies[len(latencies) // 2],
        latency_p99_ms=latencies[int(len(latencies) * 0.99) - 1],
        rate_limited_s=waited,
    )
