"""Concurrent streaming executor benchmark (ISSUE 3 tentpole).

Serial streaming processes chunks one at a time, so wall-clock throughput
is bounded by a single chunk's critical path even though chunk merges are
order-independent.  This suite measures serial vs N-way-concurrent
streaming on the simulated API engine in *wall-clock* mode (every call
sleeps its modeled latency, like a real provider), and verifies the two
acceptance properties:

* **>= 2x throughput** at 4 in-flight chunks over serial streaming,
  with **byte-identical** metric/CI output (the executor merges chunk
  states in chunk-index order, so float accumulation matches serially);
* **bounded memory**: peak Python heap at window W stays <= W x the
  serial run's peak (the window frees a slot only when a chunk is merged).

Emits ``BENCH_concurrency.json``.

  PYTHONPATH=src python -m benchmarks.concurrent_streaming [--smoke|--full]
"""

from __future__ import annotations

import json
import time
import tracemalloc

from repro.core import (
    EngineModelConfig,
    EvalSession,
    EvalTask,
    InferenceConfig,
    MetricConfig,
    StatisticsConfig,
)
from repro.data import iter_qa_examples

from benchmarks import artifacts

MODEL = EngineModelConfig(provider="openai", model_name="gpt-4o-mini")

#: wall-clock latency model: small but real sleeps, so chunk-level
#: concurrency shows up as wall-clock speedup exactly as it would against
#: a provider API (sleeping threads release the GIL)
ENGINE_KW = {"wall_clock": True, "base_latency_ms": 3.0, "per_token_ms": 0.0}


def _task(task_id: str, *, chunk: int, window: int, n_boot: int) -> EvalTask:
    return EvalTask(
        task_id=task_id,
        model=MODEL,
        inference=InferenceConfig(batch_size=32, n_workers=4, cache_dir=""),
        metrics=(MetricConfig("exact_match"), MetricConfig("token_f1")),
        statistics=StatisticsConfig(
            bootstrap_iterations=n_boot, ci_method="percentile"
        ),
    ).with_streaming(max_memory_rows=chunk, max_inflight_chunks=window)


def _measured_run(n: int, task: EvalTask) -> dict:
    tracemalloc.start()
    t0 = time.perf_counter()
    with EvalSession(engine_kwargs=ENGINE_KW) as session:
        res = session.run_task(iter_qa_examples(n, seed=0), task)
    wall = time.perf_counter() - t0
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    log = res.logs["streaming"]
    return {
        "n": n,
        "window": log.get("max_inflight_chunks", 1),
        "wall_s": wall,
        "throughput_per_s": n / wall if wall > 0 else float("inf"),
        "py_heap_peak_mb": peak / 1e6,
        "max_resident_rows": log["max_resident_rows"],
        "metrics": {
            m: {"value": mv.value, "ci": list(mv.ci), "n": mv.n}
            for m, mv in res.metrics.items()
        },
    }


def run(*, smoke: bool = False, full: bool = False) -> list[str]:
    if smoke:
        n, chunk, n_boot, windows = 1_200, 150, 300, [2, 4]
    elif full:
        n, chunk, n_boot, windows = 8_000, 500, 1_000, [2, 4, 8]
    else:
        n, chunk, n_boot, windows = 3_200, 200, 500, [2, 4, 8]

    lines = []
    serial = _measured_run(
        n, _task("cs-serial", chunk=chunk, window=1, n_boot=n_boot)
    )
    lines.append(
        f"concurrent_streaming_serial,{serial['wall_s'] * 1e6 / n:.1f},"
        f"throughput={serial['throughput_per_s']:.0f}/s "
        f"peak={serial['py_heap_peak_mb']:.1f}MB"
    )

    runs = []
    identical = True
    for w in windows:
        r = _measured_run(
            n, _task("cs-serial", chunk=chunk, window=w, n_boot=n_boot)
        )
        r["speedup_vs_serial"] = serial["wall_s"] / r["wall_s"]
        # acceptance: byte-identical metric values AND CI bounds
        r["metrics_identical"] = r["metrics"] == serial["metrics"]
        r["peak_within_window_bound"] = (
            r["py_heap_peak_mb"] <= w * serial["py_heap_peak_mb"]
        )
        identical = identical and r["metrics_identical"]
        runs.append(r)
        lines.append(
            f"concurrent_streaming_w{w},{r['wall_s'] * 1e6 / n:.1f},"
            f"throughput={r['throughput_per_s']:.0f}/s "
            f"speedup={r['speedup_vs_serial']:.2f}x "
            f"peak={r['py_heap_peak_mb']:.1f}MB "
            f"identical={r['metrics_identical']}"
        )

    at4 = next((r for r in runs if r["window"] == 4), runs[-1])
    ok = (
        identical
        and at4["speedup_vs_serial"] >= 2.0
        and all(r["peak_within_window_bound"] for r in runs)
    )
    payload = {
        "mode": "smoke" if smoke else ("full" if full else "default"),
        "n_examples": n,
        "chunk_size": chunk,
        "bootstrap_iterations": n_boot,
        "engine": {"model": MODEL.model_name, **ENGINE_KW},
        "serial": serial,
        "concurrent": runs,
        "speedup_at_4_inflight": at4["speedup_vs_serial"],
        "byte_identical_metrics": identical,
        "ok": ok,
    }
    artifacts.write_bench("BENCH_concurrency.json", payload)

    lines.append(
        f"concurrent_streaming_accept,0,"
        f"speedup@4={at4['speedup_vs_serial']:.2f}x "
        f"identical={identical} ok={ok}"
    )
    if not ok:
        raise RuntimeError(f"concurrency acceptance checks failed: {payload}")
    return lines


def main() -> None:
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--full", action="store_true")
    args = p.parse_args()
    for line in run(smoke=args.smoke, full=args.full):
        print(line)
    print(f"wrote {artifacts.bench_path('BENCH_concurrency.json')}")


if __name__ == "__main__":
    main()
