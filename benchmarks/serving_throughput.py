"""Shared inference-service benchmark (ISSUE 5 tentpole).

Two workloads against the lock-step baseline (``use_service=False`` — the
pre-service code path, kept verbatim as ``LockStepInferStage``):

* **multi-task continuous batching** — M streaming tasks share one
  simulated slot engine (``SimulatedSlotEngine``: n_slots decode slots,
  fixed per-step wall cost, long-tail output lengths).  Lock-step decodes
  a gang per call and serializes concurrent callers behind the engine
  lock, so slots idle whenever a gang is short or skewed; the service's
  persistent batcher loop refills slots across shards, chunks and tasks.
  Acceptance: **>= 2x wall-clock** with byte-identical metrics, plus the
  cross-task slot-occupancy the lock-step path cannot reach.
* **single-flight dedup** — one streaming task whose chunks repeat the
  same 60 prompts (cache disabled, all chunks in flight at once): every
  in-flight duplicate coalesces onto the leader's engine call.
  Acceptance: **>= 90% dedup** (coalesced / submitted) where the
  lock-step baseline pays for every repeat.

* **replica scaling** — the same multi-task suite (two models, so the
  pairwise significance matrix is exercised too) served by 1, 2 and 4
  data-parallel replicas per engine.  Each replica is its own slot
  engine behind one submit queue (``InferenceConfig.n_replicas``), so
  suite throughput should scale near-linearly while the routing stays
  stats-plane-invisible.  Acceptance: **>= 1.7x at 2 replicas, >= 3x at
  4**, with metrics, CIs and significance matrices byte-identical to the
  1-replica run.

* **shared-prefix decode** (ISSUE 8) — a few-shot workload where every
  prompt is one long shared header plus a short unique question, served
  by the paged engine with a per-token prefill cost.  With
  ``prefix_cache=False`` (exact-duplicate coalescing only) every request
  pays the full header prefill; with sharing ON the header pages prefill
  once and later requests skip them.  Acceptance: **>= 1.5x wall-clock**
  with byte-identical metrics and ``prefix_tokens_saved > 0`` surfaced
  in the suite markdown.

* **quantized KV pages** (ISSUE 10) — the same pool *byte* budget served
  with bf16 vs int8 block-quantized pages under decode-growth pressure.
  int8 pages are ~half the bytes, so the budget admits ~2x pages.
  Acceptance: **>= 1.8x page capacity**, **>= 1.5x wall-clock**
  (min-of-3), preemptions strictly reduced, and metrics/CIs/significance
  matrices byte-identical across 1/2/4 replicas x page sizes at fixed
  dtype (the real-model int8-vs-bf16 token-match gate lives in
  ``tests/test_quantized_serving.py``).

Emits ``BENCH_serving.json``.

  PYTHONPATH=src python -m benchmarks.serving_throughput [--smoke|--full]
"""

from __future__ import annotations

import dataclasses
import json
import time

from repro.core import (
    EngineModelConfig,
    EvalSession,
    EvalSuite,
    EvalTask,
    InferenceConfig,
    MetricConfig,
    StatisticsConfig,
)
from repro.data import iter_qa_examples, qa_examples

from benchmarks import artifacts

SLOT_MODEL = EngineModelConfig(provider="slotsim", model_name="slot-sim")
API_MODEL = EngineModelConfig(provider="openai", model_name="gpt-4o-mini")

#: slot engine: 8 decode slots, 0.4ms per step, skewed output lengths —
#: the regime where lock-step gangs pay the straggler price every wave
SLOT_KW = {"n_slots": 8, "step_ms": 0.4, "wall_clock": True,
           "min_out": 4, "max_out": 48}
#: API engine for the dedup workload: flat 60ms calls — wide enough that
#: every chunk worker's submissions land while the leaders are still in
#: flight even on a loaded CI machine
API_KW = {"wall_clock": True, "base_latency_ms": 60.0, "per_token_ms": 0.0}


def _task(task_id: str, *, model, use_service: bool, n_workers: int,
          chunk: int, window: int, n_replicas: int = 1) -> EvalTask:
    return EvalTask(
        task_id=task_id,
        model=model,
        inference=InferenceConfig(
            batch_size=16, n_workers=n_workers, cache_dir="",
            use_service=use_service, n_replicas=n_replicas,
        ),
        metrics=(MetricConfig("exact_match"), MetricConfig("token_f1")),
        statistics=StatisticsConfig(
            bootstrap_iterations=200, ci_method="percentile"
        ),
    ).with_streaming(max_memory_rows=chunk, max_inflight_chunks=window)


def _metric_dict(res) -> dict:
    return {
        m: {"value": mv.value, "ci": list(mv.ci), "n": mv.n}
        for m, mv in res.metrics.items()
    }


def _multi_task(
    n_per_task: int, n_tasks: int, chunk: int, window: int, trials: int = 3,
) -> dict:
    def build_suite(use_service: bool) -> EvalSuite:
        suite = EvalSuite("serving")
        for t in range(n_tasks):
            suite.add_task(
                _task(
                    f"serve-{t}", model=SLOT_MODEL,
                    use_service=use_service, n_workers=4,
                    chunk=chunk, window=window,
                ),
                (lambda t=t: iter_qa_examples(n_per_task, seed=100 + t)),
            )
        return suite

    def run(use_service: bool) -> dict:
        t0 = time.perf_counter()
        with EvalSession(engine_kwargs=SLOT_KW) as session:
            res = session.run_suite(
                build_suite(use_service),
                parallel_jobs=n_tasks if use_service else 1,
            )
            serving = session.serving_stats()
        wall = time.perf_counter() - t0
        metrics = {
            task_id: _metric_dict(res.result(SLOT_MODEL.model_name, task_id))
            for task_id in res.tasks
        }
        out = {"wall_s": wall, "metrics": metrics}
        if serving:
            snap = serving[0]
            out["service"] = {
                k: snap.get(k)
                for k in ("mode", "dispatchers", "submitted", "dispatched",
                          "coalesced", "dedup_rate")
            }
            if "batcher" in snap:
                out["batcher"] = snap["batcher"]
        return out

    # min-wall over trials on BOTH sides: the lock-step reference
    # serializes behind the engine lock, so its wall is scheduling-noise
    # sensitive and a single sample makes the speedup ratio flaky
    def best_of(use_service: bool) -> dict:
        attempts = [run(use_service) for _ in range(trials)]
        for r in attempts[1:]:
            assert r["metrics"] == attempts[0]["metrics"]
        return min(attempts, key=lambda r: r["wall_s"])

    baseline = best_of(False)
    service = best_of(True)
    n_total = n_per_task * n_tasks
    return {
        "n_tasks": n_tasks,
        "n_examples_total": n_total,
        "engine": {"model": SLOT_MODEL.model_name, **SLOT_KW},
        "baseline_wall_s": baseline["wall_s"],
        "service_wall_s": service["wall_s"],
        "speedup": baseline["wall_s"] / service["wall_s"],
        "slot_occupancy": service.get("batcher", {}).get("slot_occupancy"),
        "tokens_per_step": service.get("batcher", {}).get("tokens_per_step"),
        "metrics_identical": baseline["metrics"] == service["metrics"],
        "service": service.get("service"),
    }


#: replica-scaling engine: slower steps than SLOT_KW so decode wall
#: dominates host-side scoring (the regime where adding replicas is the
#: only lever left), and a narrow output-length band so the end-of-run
#: tail does not idle a large fleet
REPLICA_SLOT_KW = {"n_slots": 8, "step_ms": 2.5, "wall_clock": True,
                   "min_out": 24, "max_out": 40}
SLOT_MODEL_B = EngineModelConfig(provider="slotsim", model_name="slot-sim-b")


def _cmp_cell(c) -> dict:
    return {
        "diff": c.diff, "diff_ci": list(c.diff_ci),
        "p_value": c.test.p_value, "effect": c.effect.value,
    }


def _replica_scaling(
    n_per_task: int, n_tasks: int, chunk: int, window: int,
    counts: tuple[int, ...] = (1, 2, 4),
    trials: int = 3,
) -> dict:
    """Same suite, growing replica fleet: wall-clock must scale and the
    statistics plane must not move a byte.

    Each fleet size is timed ``trials`` times and the fastest wall is
    kept (for the 1-replica base too): a single run is only a few
    seconds, so hundreds of ms of host noise can eat the scaling ratio;
    min-wall is the standard noise-floor estimator.  Every trial must
    still produce byte-identical statistics."""

    def build_suite(n_replicas: int) -> EvalSuite:
        suite = EvalSuite(f"replicas-{n_replicas}")
        for t in range(n_tasks):
            suite.add_task(
                _task(
                    f"scale-{t}", model=SLOT_MODEL, use_service=True,
                    n_workers=4, chunk=chunk, window=window,
                    n_replicas=n_replicas,
                ),
                (lambda t=t: iter_qa_examples(n_per_task, seed=300 + t)),
            )
        return suite.sweep_models([SLOT_MODEL, SLOT_MODEL_B])

    def run(n_replicas: int) -> dict:
        t0 = time.perf_counter()
        with EvalSession(engine_kwargs=REPLICA_SLOT_KW) as session:
            res = session.run_suite(
                build_suite(n_replicas), parallel_jobs=n_tasks * 2
            )
            serving = session.serving_stats()
        wall = time.perf_counter() - t0
        metrics = {
            f"{model}|{task_id}": _metric_dict(res.results[(model, task_id)])
            for (model, task_id) in res.results
        }
        comparisons = {
            task_id: {
                metric: {
                    "|".join(pair): _cmp_cell(cell)
                    for pair, cell in cells.items()
                }
                for metric, cells in metrics_.items()
            }
            for task_id, metrics_ in res.comparisons.items()
        }
        assert all(s["replicas"] == n_replicas for s in serving)
        occ = [
            s["batcher"]["slot_occupancy"] for s in serving if "batcher" in s
        ]
        return {
            "wall_s": wall,
            "metrics": metrics,
            "comparisons": comparisons,
            "occupancy": sum(occ) / len(occ) if occ else None,
        }

    identical = True

    def best_of(n_replicas: int) -> dict:
        nonlocal identical
        attempts = [run(n_replicas) for _ in range(trials)]
        for r in attempts[1:]:
            identical = identical and (
                r["metrics"] == attempts[0]["metrics"]
                and r["comparisons"] == attempts[0]["comparisons"]
            )
        return min(attempts, key=lambda r: r["wall_s"])

    runs = {n: best_of(n) for n in counts}
    base = runs[counts[0]]
    per_replica = {}
    for n, r in runs.items():
        identical = identical and (
            r["metrics"] == base["metrics"]
            and r["comparisons"] == base["comparisons"]
        )
        per_replica[str(n)] = {
            "wall_s": r["wall_s"],
            "speedup": base["wall_s"] / r["wall_s"],
            "occupancy": r["occupancy"],
        }
    speedup_2 = per_replica.get("2", {}).get("speedup", 0.0)
    speedup_4 = per_replica.get("4", {}).get("speedup", 0.0)
    return {
        "n_tasks": n_tasks,
        "n_models": 2,
        "n_examples_total": n_per_task * n_tasks * 2,
        "engine": {"model": SLOT_MODEL.model_name, **REPLICA_SLOT_KW},
        "per_replica": per_replica,
        "speedup_2": speedup_2,
        "speedup_4": speedup_4,
        "byte_identical_stats": identical,
        "ok": speedup_2 >= 1.7 and speedup_4 >= 3.0 and identical,
    }


#: shared-prefix engine: a per-token simulated prefill cost makes prompt
#: length the dominant wall term (few-shot regime), so prompt-prefix page
#: sharing is the lever being measured; short outputs keep decode cheap
PREFIX_SLOT_KW = {"n_slots": 8, "step_ms": 0.2, "wall_clock": True,
                  "min_out": 4, "max_out": 8, "prefill_ms_per_token": 0.12}


def _shared_prefix(n_rows: int, header_words: int, trials: int = 3) -> dict:
    """Few-shot workload: every prompt = one long shared header + a short
    unique question.  Baseline is the paged engine with cross-request
    sharing OFF (``prefix_cache=False``) — exact-duplicate coalescing
    still applies, but no two prompts are identical, so the baseline pays
    the full header prefill per request; sharing ON prefills each
    header page once.  Acceptance: **>= 1.5x wall-clock** with
    byte-identical metrics and a nonzero saved-token counter that
    surfaces in the suite markdown."""
    header = " ".join(f"shot{i // 8}tok{i}" for i in range(header_words))
    rows = [
        {"question": f"{header} question {i} now", "reference": f"ref {i}"}
        for i in range(n_rows)
    ]
    prompt_tokens = sum(len(r["question"].split()) for r in rows)

    def build_task(prefix_cache: bool) -> EvalTask:
        return EvalTask(
            task_id="fewshot",
            model=SLOT_MODEL,
            inference=InferenceConfig(
                batch_size=16, n_workers=4, cache_dir="", use_service=True,
                kv_page_size=16, prefix_cache=prefix_cache,
            ),
            metrics=(MetricConfig("exact_match"), MetricConfig("token_f1")),
            statistics=StatisticsConfig(
                bootstrap_iterations=200, ci_method="percentile"
            ),
        )

    def run(prefix_cache: bool) -> dict:
        suite = EvalSuite("prefix").add_task(build_task(prefix_cache), rows)
        t0 = time.perf_counter()
        with EvalSession(engine_kwargs=PREFIX_SLOT_KW) as session:
            res = session.run_suite(suite)
            serving = session.serving_stats()
        wall = time.perf_counter() - t0
        snap = serving[0]
        return {
            "wall_s": wall,
            "metrics": _metric_dict(res.result(SLOT_MODEL.model_name, "fewshot")),
            "saved": snap["batcher"]["prefix_tokens_saved"],
            "hits": snap["batcher"]["prefix_pages_hit"],
            "markdown": "| prefix hits |" in res.to_markdown(),
        }

    def best_of(prefix_cache: bool) -> dict:
        attempts = [run(prefix_cache) for _ in range(trials)]
        for r in attempts[1:]:
            assert r["metrics"] == attempts[0]["metrics"]
        return min(attempts, key=lambda r: r["wall_s"])

    baseline = best_of(False)
    shared = best_of(True)
    speedup = baseline["wall_s"] / shared["wall_s"]
    identical = baseline["metrics"] == shared["metrics"]
    return {
        "n_rows": n_rows,
        "header_words": header_words,
        "prompt_tokens_total": prompt_tokens,
        "engine": {"model": SLOT_MODEL.model_name, **PREFIX_SLOT_KW},
        "kv_page_size": 16,
        "baseline_wall_s": baseline["wall_s"],
        "shared_wall_s": shared["wall_s"],
        "speedup": speedup,
        "prefix_tokens_saved": shared["saved"],
        "prefix_pages_hit": shared["hits"],
        "prefix_reuse": shared["saved"] / prompt_tokens,
        "baseline_prefix_tokens_saved": baseline["saved"],
        "byte_identical_stats": identical,
        "markdown_reports_prefix": shared["markdown"],
        "ok": (
            speedup >= 1.5
            and identical
            and shared["saved"] > 0
            and baseline["saved"] == 0
            and shared["markdown"]
        ),
    }


#: quantized-cache engine: byte-budgeted page pool under decode-growth
#: pressure — the pool, not the slot count, is the admission bottleneck,
#: so KV bytes-per-token is the lever being measured.  Outputs long
#: enough that decode growth (one page per generated token past the
#: prompt) overcommits what the admission gate reserved, forcing organic
#: preemptions on the smaller bf16 pool.
QUANT_SLOT_KW = {"n_slots": 8, "step_ms": 0.25, "wall_clock": True,
                 "min_out": 32, "max_out": 64,
                 "prefill_ms_per_token": 0.05, "decode_page_growth": True}
#: fixed pool byte budget — 14 bf16 pages at kv_page_size=16 under the
#: simulator's nominal KV geometry (~1.8 MB).  A fully decoded request
#: spans ~7 pages (39-word prompt + up to 64 generated tokens), so bf16
#: sustains ~2 resident requests while the same budget in int8 (~28
#: pages) sustains ~4 — admission-gate reserve (one page per busy slot)
#: understates decode growth, so the bf16 pool preempts organically
QUANT_POOL_BYTES = 14 * 131072


def _quantized(
    n_rows: int,
    trials: int = 3,
    counts: tuple[int, ...] = (1, 2, 4),
    page_sizes: tuple[int, ...] = (16, 64),
) -> dict:
    """Quantized paged KV cache (ISSUE 10): the same pool *byte* budget
    served with bf16 pages vs int8 block-quantized pages.  int8 pages are
    ~half the bytes, so the budget admits ~2x pages: fewer preemptions,
    fewer re-decoded tokens, less wall.  Acceptance: **>= 1.8x**
    resident-page capacity and **>= 1.5x wall-clock** (min-of-3) with
    preemptions strictly reduced; metrics, CIs and significance matrices
    byte-identical across 1/2/4 replicas x page sizes at fixed dtype; and
    int8 stats byte-identical to bf16 (the simulator's token plane is a
    pure prompt function — the real-model >= 99% greedy token-match gate
    lives in ``tests/test_quantized_serving.py``)."""
    rows = [
        {
            "question": " ".join(f"ctx{i}w{j}" for j in range(36))
            + f" question {i} now",
            "reference": f"ref {i}",
        }
        for i in range(n_rows)
    ]

    def build_suite(dtype: str, page: int, n_replicas: int) -> EvalSuite:
        task = EvalTask(
            task_id="quant",
            model=SLOT_MODEL,
            inference=InferenceConfig(
                batch_size=16, n_workers=4, cache_dir="", use_service=True,
                kv_page_size=page, kv_cache_dtype=dtype,
                n_replicas=n_replicas,
            ),
            metrics=(MetricConfig("exact_match"), MetricConfig("token_f1")),
            statistics=StatisticsConfig(
                bootstrap_iterations=200, ci_method="percentile"
            ),
        )
        suite = EvalSuite(f"quant-{dtype}").add_task(task, rows)
        return suite.sweep_models([SLOT_MODEL, SLOT_MODEL_B])

    def run(dtype: str, page: int = 16, n_replicas: int = 1) -> dict:
        t0 = time.perf_counter()
        kw = {**QUANT_SLOT_KW, "page_pool_bytes": QUANT_POOL_BYTES}
        with EvalSession(engine_kwargs=kw) as session:
            res = session.run_suite(
                build_suite(dtype, page, n_replicas), parallel_jobs=2
            )
            serving = session.serving_stats()
        wall = time.perf_counter() - t0
        metrics = {
            f"{model}|{task_id}": _metric_dict(res.results[(model, task_id)])
            for (model, task_id) in res.results
        }
        comparisons = {
            task_id: {
                metric: {
                    "|".join(pair): _cmp_cell(cell)
                    for pair, cell in cells.items()
                }
                for metric, cells in metrics_.items()
            }
            for task_id, metrics_ in res.comparisons.items()
        }
        bat = [s["batcher"] for s in serving if "batcher" in s]
        return {
            "wall_s": wall,
            "metrics": metrics,
            "comparisons": comparisons,
            "preemptions": sum(b.get("preemptions", 0) for b in bat),
            "preempted_tokens": sum(b.get("preempted_tokens", 0) for b in bat),
            # one service per model; each run's pools are identically
            # sized, so max == the per-service pool page count
            "pool_pages": max((b.get("pool_pages", 0) for b in bat), default=0),
            "kv_bytes_per_token": max(
                (b.get("kv_bytes_per_token", 0) for b in bat), default=0
            ),
        }

    def best_of(dtype: str) -> dict:
        attempts = [run(dtype) for _ in range(trials)]
        for r in attempts[1:]:
            assert r["metrics"] == attempts[0]["metrics"]
            assert r["comparisons"] == attempts[0]["comparisons"]
        return min(attempts, key=lambda r: r["wall_s"])

    baseline = best_of("bf16")
    quant = best_of("int8")
    speedup = baseline["wall_s"] / quant["wall_s"]
    capacity_ratio = quant["pool_pages"] / max(1, baseline["pool_pages"])
    # value-plane quantization must not touch the token plane: in the
    # simulator texts are pure prompt functions, so every metric byte
    # (and every significance cell) must survive the dtype switch
    token_match = (
        quant["metrics"] == baseline["metrics"]
        and quant["comparisons"] == baseline["comparisons"]
    )

    # fixed dtype => byte-identical stats across replica counts and page
    # sizes (single-trial runs: identity is deterministic, only the wall
    # comparison above needs min-of-trials)
    identical = True
    parity: dict[str, dict] = {}
    for dtype, base in (("bf16", baseline), ("int8", quant)):
        for page in page_sizes:
            for n in counts:
                if page == 16 and n == 1:
                    continue  # == base, already run (min-of-trials)
                r = run(dtype, page=page, n_replicas=n)
                same = (
                    r["metrics"] == base["metrics"]
                    and r["comparisons"] == base["comparisons"]
                )
                identical = identical and same
                parity[f"{dtype}|page{page}|replicas{n}"] = {
                    "stats_identical": same,
                    "preemptions": r["preemptions"],
                }

    return {
        "n_rows": n_rows,
        "n_models": 2,
        "engine": {"model": SLOT_MODEL.model_name, **QUANT_SLOT_KW},
        "pool_bytes": QUANT_POOL_BYTES,
        "kv_page_size": 16,
        "bf16": {
            k: baseline[k]
            for k in ("wall_s", "pool_pages", "kv_bytes_per_token",
                      "preemptions", "preempted_tokens")
        },
        "int8": {
            k: quant[k]
            for k in ("wall_s", "pool_pages", "kv_bytes_per_token",
                      "preemptions", "preempted_tokens")
        },
        "capacity_ratio": capacity_ratio,
        "speedup": speedup,
        "preemptions_reduced": quant["preemptions"] < baseline["preemptions"],
        "token_match_ok": token_match,
        "parity": parity,
        "byte_identical_stats": identical,
        "ok": (
            capacity_ratio >= 1.8
            and speedup >= 1.5
            and quant["preemptions"] < baseline["preemptions"]
            and token_match
            and identical
        ),
    }


def _dedup(n_unique: int, repeats: int, n_workers: int) -> dict:
    unique = qa_examples(n_unique, seed=7)
    rows = [r for _ in range(repeats) for r in unique]  # chunk = unique set

    def run(use_service: bool) -> dict:
        task = _task(
            "dedup", model=API_MODEL, use_service=use_service,
            n_workers=n_workers, chunk=n_unique, window=repeats,
        )
        t0 = time.perf_counter()
        with EvalSession(engine_kwargs=API_KW) as session:
            res = session.run_task(iter(rows), task)
            acct = dataclasses.asdict(session.accounting)
            serving = session.serving_stats()
        return {
            "wall_s": time.perf_counter() - t0,
            "engine_calls": acct["engine_calls"],
            "coalesced": acct["coalesced_requests"],
            "metrics": _metric_dict(res),
            "service": serving[0] if serving else {},
        }

    baseline = run(False)
    service = run(True)
    svc = service["service"]
    return {
        "n_rows": len(rows),
        "n_unique_prompts": n_unique,
        "engine": {"model": API_MODEL.model_name, **API_KW},
        "baseline_engine_calls": baseline["engine_calls"],
        "service_engine_calls": service["engine_calls"],
        "coalesced": service["coalesced"],
        "dedup_rate": svc.get("dedup_rate", 0.0),
        "baseline_wall_s": baseline["wall_s"],
        "service_wall_s": service["wall_s"],
        "metrics_identical": baseline["metrics"] == service["metrics"],
    }


def run(*, smoke: bool = False, full: bool = False) -> list[str]:
    if smoke:
        n_per_task, n_tasks, chunk, window = 100, 3, 25, 4
        n_unique, repeats, n_workers = 60, 16, 8
        rs_per_task, rs_tasks, rs_chunk, rs_window = 150, 2, 30, 4
        sp_rows, sp_header = 24, 320
        qz_rows, qz_counts = 40, (1, 2)
    elif full:
        n_per_task, n_tasks, chunk, window = 600, 4, 75, 8
        n_unique, repeats, n_workers = 120, 16, 8
        rs_per_task, rs_tasks, rs_chunk, rs_window = 240, 3, 60, 8
        sp_rows, sp_header = 64, 600
        qz_rows, qz_counts = 64, (1, 2, 4)
    else:
        n_per_task, n_tasks, chunk, window = 250, 3, 50, 4
        n_unique, repeats, n_workers = 60, 16, 8
        rs_per_task, rs_tasks, rs_chunk, rs_window = 150, 2, 30, 4
        sp_rows, sp_header = 40, 600
        qz_rows, qz_counts = 48, (1, 2, 4)

    lines = []
    mt = _multi_task(n_per_task, n_tasks, chunk, window)
    lines.append(
        f"serving_multi_task,{mt['service_wall_s'] * 1e6 / mt['n_examples_total']:.1f},"
        f"speedup={mt['speedup']:.2f}x "
        f"occupancy={mt['slot_occupancy']} "
        f"tok/step={mt['tokens_per_step']} "
        f"identical={mt['metrics_identical']}"
    )
    de = _dedup(n_unique, repeats, n_workers)
    lines.append(
        f"serving_dedup,{de['service_wall_s'] * 1e6 / de['n_rows']:.1f},"
        f"dedup={de['dedup_rate']:.1%} "
        f"calls={de['service_engine_calls']}/{de['baseline_engine_calls']} "
        f"identical={de['metrics_identical']}"
    )

    rs = _replica_scaling(rs_per_task, rs_tasks, rs_chunk, rs_window)
    rs_us = rs["per_replica"]["4"]["wall_s"] * 1e6 / rs["n_examples_total"]
    lines.append(
        f"serving_replicas,{rs_us:.1f},"
        f"speedup@2={rs['speedup_2']:.2f}x speedup@4={rs['speedup_4']:.2f}x "
        f"identical={rs['byte_identical_stats']}"
    )

    sp = _shared_prefix(sp_rows, sp_header)
    lines.append(
        f"serving_shared_prefix,{sp['shared_wall_s'] * 1e6 / sp['n_rows']:.1f},"
        f"speedup={sp['speedup']:.2f}x "
        f"reuse={sp['prefix_reuse']:.1%} "
        f"identical={sp['byte_identical_stats']}"
    )

    qz = _quantized(qz_rows, counts=qz_counts)
    lines.append(
        f"serving_quantized,{qz['int8']['wall_s'] * 1e6 / qz['n_rows']:.1f},"
        f"speedup={qz['speedup']:.2f}x "
        f"capacity={qz['capacity_ratio']:.2f}x "
        f"preempt={qz['int8']['preemptions']}/{qz['bf16']['preemptions']} "
        f"identical={qz['byte_identical_stats']}"
    )

    ok = (
        mt["speedup"] >= 2.0
        and mt["metrics_identical"]
        and de["dedup_rate"] >= 0.9
        and de["metrics_identical"]
        and rs["ok"]
        and sp["ok"]
        and qz["ok"]
    )
    payload = {
        "mode": "smoke" if smoke else ("full" if full else "default"),
        "multi_task": mt,
        "dedup": de,
        "replica_scaling": rs,
        "shared_prefix": sp,
        "quantized": qz,
        "speedup": mt["speedup"],
        "dedup_rate": de["dedup_rate"],
        "ok": ok,
    }
    artifacts.write_bench("BENCH_serving.json", payload)
    lines.append(
        f"serving_accept,0,speedup={mt['speedup']:.2f}x "
        f"dedup={de['dedup_rate']:.1%} "
        f"replicas@2={rs['speedup_2']:.2f}x @4={rs['speedup_4']:.2f}x "
        f"prefix={sp['speedup']:.2f}x "
        f"quant={qz['speedup']:.2f}x "
        f"ok={ok}"
    )
    if not ok:
        raise RuntimeError(f"serving acceptance checks failed: {payload}")
    return lines


def main() -> None:
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--full", action="store_true")
    args = p.parse_args()
    for line in run(smoke=args.smoke, full=args.full):
        print(line)
    print(f"wrote {artifacts.bench_path('BENCH_serving.json')}")


if __name__ == "__main__":
    main()
