"""Adaptive vs exhaustive evaluation (ISSUE 6 tentpole).

A suite of clearly-separated simulated models is evaluated twice:

* **exhaustive** — every example of every task under every model, the
  paper's baseline regime;
* **adaptive** — :func:`repro.core.budget.run_adaptive_suite` with a
  budget large enough to never bind: tasks stop the moment their pairwise
  verdict is certified by the anytime-valid confidence sequence.

Acceptance (hard-fail): the adaptive run certifies the **same verdicts**
the exhaustive run's significance tests reach, while consuming
**>= 40% fewer examples** (and correspondingly less wall-clock).

Emits ``BENCH_adaptive.json``.

  PYTHONPATH=src python -m benchmarks.adaptive_eval [--smoke|--full]
"""

from __future__ import annotations

import json
import time

from repro.core import (
    BudgetConfig,
    EngineModelConfig,
    EvalSession,
    EvalSuite,
    EvalTask,
    InferenceConfig,
    MetricConfig,
    StatisticsConfig,
    run_adaptive_suite,
)
from repro.data import iter_qa_examples, iter_summarization_examples

from benchmarks import artifacts

M_STRONG = EngineModelConfig(provider="openai", model_name="gpt-4o")
M_WEAK = EngineModelConfig(provider="openai", model_name="gpt-3.5-turbo")
ALPHA = 0.05
#: acceptance floor: adaptive must consume this fraction fewer examples
MIN_SAVINGS = 0.40


def _task(task_id: str, chunk: int, spill: str) -> EvalTask:
    return EvalTask(
        task_id=task_id,
        inference=InferenceConfig(batch_size=32, n_workers=2, cache_dir=""),
        metrics=(MetricConfig("token_f1"),),
        statistics=StatisticsConfig(
            bootstrap_iterations=200, ci_method="percentile"
        ),
    ).with_streaming(max_memory_rows=chunk, spill_dir=spill)


def _suite(name: str, n: int, chunk: int, spill_root: str) -> EvalSuite:
    return (
        EvalSuite(name)
        .add_task(
            _task("qa", chunk, f"{spill_root}/qa"),
            lambda: iter_qa_examples(n),
        )
        .add_task(
            _task("summarization", chunk, f"{spill_root}/sum"),
            lambda: iter_summarization_examples(n),
        )
        .sweep_models([M_STRONG, M_WEAK])
    )


def _verdict_from_comparison(cmp) -> str:
    """The exhaustive regime's answer, in adaptive vocabulary."""
    if cmp.test.p_value >= ALPHA:
        return "undecided"
    return "a_better" if cmp.diff > 0 else "b_better"


def run(*, smoke: bool = False, full: bool = False) -> list[str]:
    import tempfile

    if smoke:
        n, chunk, seed_round = 2500, 128, 256
    elif full:
        n, chunk, seed_round = 20_000, 512, 512
    else:
        n, chunk, seed_round = 8000, 256, 256
    pair = f"{M_STRONG.model_name} vs {M_WEAK.model_name}"

    with tempfile.TemporaryDirectory() as root:
        t0 = time.perf_counter()
        with EvalSession() as session:
            ex = session.run_suite(_suite("exhaustive", n, chunk, f"{root}/ex"))
            exhaustive_examples = session.accounting.engine_calls
        exhaustive_wall = time.perf_counter() - t0

        budget = BudgetConfig(
            total_examples=4 * n,           # never binds: savings come from
            round_examples=seed_round,      # certification, not rationing
            min_examples=seed_round,
            alpha=ALPHA,
            metric="token_f1",
        )
        t0 = time.perf_counter()
        with EvalSession() as session:
            ad = run_adaptive_suite(
                session, _suite("adaptive", n, chunk, f"{root}/ad"), budget
            )
            adaptive_examples = session.accounting.engine_calls
        adaptive_wall = time.perf_counter() - t0

    tasks = {}
    verdicts_match = True
    for tid in ex.tasks:
        want = _verdict_from_comparison(
            ex.comparison(tid, "token_f1", M_STRONG.model_name,
                          M_WEAK.model_name)
        )
        got = ad.adaptive["tasks"][tid]["verdicts"].get(pair, "undecided")
        verdicts_match = verdicts_match and want == got
        tasks[tid] = {
            "exhaustive_verdict": want,
            "adaptive_verdict": got,
            "consumed": ad.adaptive["tasks"][tid]["consumed"],
            "available": n,
            "n_at_stop": ad.adaptive["tasks"][tid]["n_at_stop"],
            "half_width": ad.adaptive["tasks"][tid]["half_width"],
            "reason": ad.adaptive["tasks"][tid]["reason"],
        }

    savings = 1.0 - adaptive_examples / exhaustive_examples
    wall_savings = 1.0 - adaptive_wall / exhaustive_wall
    ok = verdicts_match and savings >= MIN_SAVINGS
    payload = {
        "mode": "smoke" if smoke else ("full" if full else "default"),
        "n_per_task": n,
        "exhaustive_examples": exhaustive_examples,
        "adaptive_examples": adaptive_examples,
        "example_savings": savings,
        "exhaustive_wall_s": exhaustive_wall,
        "adaptive_wall_s": adaptive_wall,
        "wall_savings": wall_savings,
        "rounds": ad.adaptive["budget"]["rounds"],
        "verdicts_match": verdicts_match,
        "tasks": tasks,
        "min_savings_floor": MIN_SAVINGS,
        "ok": ok,
    }
    artifacts.write_bench("BENCH_adaptive.json", payload)

    lines = [
        f"adaptive_eval,{adaptive_wall * 1e6 / max(adaptive_examples, 1):.1f},"
        f"examples={adaptive_examples}/{exhaustive_examples} "
        f"savings={savings:.1%} wall_savings={wall_savings:.1%} "
        f"verdicts_match={verdicts_match}",
        f"adaptive_accept,0,savings={savings:.1%} "
        f"floor={MIN_SAVINGS:.0%} ok={ok}",
    ]
    if not ok:
        raise RuntimeError(f"adaptive acceptance checks failed: {payload}")
    return lines


def main() -> None:
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--full", action="store_true")
    args = p.parse_args()
    for line in run(smoke=args.smoke, full=args.full):
        print(line)
    print(f"wrote {artifacts.bench_path('BENCH_adaptive.json')}")


if __name__ == "__main__":
    main()
