"""Paper Table 6: provider cost comparison for a fixed evaluation task
(10,000 examples, 400 input / 150 output tokens)."""

from __future__ import annotations

import time

from repro.core.engines import PRICE_BOOK, api_cost

TASK = {"examples": 10_000, "in_tokens": 400, "out_tokens": 150}

TABLE6 = [
    ("openai", "gpt-4o"),
    ("openai", "gpt-4o-mini"),
    ("anthropic", "claude-3-5-sonnet"),
    ("anthropic", "claude-3-haiku"),
    ("google", "gemini-1.5-pro"),
]


def run() -> list[str]:
    lines = []
    n = TASK["examples"]
    for provider, model in TABLE6:
        t0 = time.perf_counter()
        total = api_cost(
            provider, model, n * TASK["in_tokens"], n * TASK["out_tokens"]
        )
        pin, pout = PRICE_BOOK[(provider, model)]
        in_cost = n * TASK["in_tokens"] * pin / 1e6
        out_cost = n * TASK["out_tokens"] * pout / 1e6
        us = (time.perf_counter() - t0) * 1e6
        lines.append(
            f"table6_cost_{provider}_{model},{us:.1f},"
            f"input=${in_cost:.2f} output=${out_cost:.2f} total=${total:.2f}"
        )
    # paper: 1M examples at gpt-4o vs mini — the 20x regression-testing gap
    m = 1_000_000
    big = api_cost("openai", "gpt-4o", m * 400, m * 150)
    small = api_cost("openai", "gpt-4o-mini", m * 400, m * 150)
    lines.append(
        f"table6_cost_1M_scale,0,gpt4o=${big:.0f} mini=${small:.0f} "
        f"ratio={big/small:.1f}x"
    )
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
