"""Paper Table 4: caching effectiveness — initial run populates the cache,
three metric iterations replay it with zero engine calls."""

from __future__ import annotations

import dataclasses as dc
import tempfile
import time

from repro.core import (
    CachePolicy,
    EngineModelConfig,
    EvalRunner,
    EvalTask,
    InferenceConfig,
    MetricConfig,
    StatisticsConfig,
)
from repro.data import mixed_examples


def run(n_examples: int = 400) -> list[str]:
    tmp = tempfile.mkdtemp()
    rows = mixed_examples(n_examples, seed=1)
    base = EvalTask(
        task_id="caching-bench",
        model=EngineModelConfig(provider="openai", model_name="gpt-4o"),
        inference=InferenceConfig(
            batch_size=50, n_workers=4, cache_dir=tmp + "/cache"
        ),
        metrics=(MetricConfig("token_f1"),),
        statistics=StatisticsConfig(bootstrap_iterations=200, ci_method="percentile"),
    )
    runner = EvalRunner()
    lines = []

    t0 = time.perf_counter()
    r0 = runner.evaluate(rows, base)
    dt0 = time.perf_counter() - t0
    cost0 = r0.engine_stats["total_cost"]
    lines.append(
        f"table4_initial,{dt0*1e6/n_examples:.0f},"
        f"hits=0% api_calls={n_examples} cost=${cost0:.2f} time={dt0:.1f}s"
    )

    iter_metrics = [
        (MetricConfig("token_f1"), MetricConfig("rouge_l")),
        (MetricConfig("token_f1"), MetricConfig("rouge_l"), MetricConfig("bleu")),
        (MetricConfig("exact_match"), MetricConfig("embedding_similarity")),
    ]
    total_cost, total_time = cost0, dt0
    for i, metrics in enumerate(iter_metrics, 1):
        task = dc.replace(
            base,
            metrics=metrics,
            inference=dc.replace(base.inference, cache_policy=CachePolicy.REPLAY),
        )
        t0 = time.perf_counter()
        r = runner.evaluate(rows, task)
        dt = time.perf_counter() - t0
        assert r.cache_stats["hit_rate"] == 1.0
        total_time += dt
        lines.append(
            f"table4_metric_change_{i},{dt*1e6/n_examples:.0f},"
            f"hits=100% api_calls=0 cost=$0.00 time={dt:.1f}s"
        )
    no_cache_cost = cost0 * 4
    lines.append(
        f"table4_total,{total_time*1e6/n_examples:.0f},"
        f"cost=${total_cost:.2f} vs_without_cache=${no_cache_cost:.2f} "
        f"saving={100*(1-total_cost/no_cache_cost):.0f}%"
    )
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
