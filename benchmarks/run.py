"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` restores the paper's
original sample counts (slower); the default sizes finish in minutes on CPU.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig2_scaling,...]
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true")
    p.add_argument("--only", default="")
    args = p.parse_args()

    from benchmarks import (
        caching,
        cost,
        coverage,
        kernels_bench,
        scaling,
        suite_overhead,
        throughput,
        type1,
    )

    suites = {
        "fig2_scaling": lambda: scaling.run(),
        "table3_throughput": lambda: throughput.run(),
        "table4_caching": lambda: caching.run(),
        "table5_coverage": lambda: coverage.run(full=args.full),
        "type1_error": lambda: type1.run(full=args.full),
        "table6_cost": lambda: cost.run(),
        "kernels": lambda: kernels_bench.run(),
        "suite_overhead": lambda: suite_overhead.run(),
    }
    only = {s.strip() for s in args.only.split(",") if s.strip()}

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            for line in fn():
                print(line)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0,ERROR={e!r}", file=sys.stderr)
        print(f"# {name} finished in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
