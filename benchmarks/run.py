"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` restores the paper's
original sample counts (slower); the default sizes finish in minutes on
CPU; ``--smoke`` shrinks every suite to CI-friendly sizes (a couple of
minutes total) while still emitting the ``BENCH_*.json`` artifacts.

  PYTHONPATH=src python -m benchmarks.run [--full|--smoke] [--out DIR] \
      [--only fig2_scaling,...]
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true")
    p.add_argument("--smoke", action="store_true",
                   help="CI sizes: fast run of every suite + BENCH artifacts")
    p.add_argument("--only", default="")
    p.add_argument("--out", default="",
                   help="directory for BENCH_*.json artifacts "
                        "(default: current directory)")
    args = p.parse_args()
    if args.full and args.smoke:
        p.error("--full and --smoke are mutually exclusive")
    smoke = args.smoke
    if args.out:
        from benchmarks import artifacts

        print(f"# artifacts -> {artifacts.set_out_dir(args.out)}",
              file=sys.stderr)

    from benchmarks import (
        adaptive_eval,
        bootstrap_stats,
        caching,
        concurrent_streaming,
        cost,
        coverage,
        kernels_bench,
        scaling,
        serving_chaos,
        serving_throughput,
        streaming_scale,
        suite_overhead,
        throughput,
        type1,
    )

    suites = {
        "fig2_scaling": lambda: scaling.run(
            n_examples=2_000 if smoke else 20_000
        ),
        "table3_throughput": lambda: throughput.run(
            sizes=(1_000, 5_000) if smoke else (1_000, 10_000, 50_000, 100_000)
        ),
        "table4_caching": lambda: caching.run(n_examples=100 if smoke else 400),
        "table5_coverage": lambda: (
            coverage.run(n_datasets=50, n_boot=150)
            if smoke
            else coverage.run(full=args.full)
        ),
        "type1_error": lambda: (
            type1.run(n_sims=300) if smoke else type1.run(full=args.full)
        ),
        "table6_cost": lambda: cost.run(),
        "kernels": lambda: kernels_bench.run(smoke=smoke),
        "suite_overhead": lambda: suite_overhead.run(n_tasks=2 if smoke else 3),
        "streaming_scale": lambda: streaming_scale.run(
            smoke=smoke, full=args.full
        ),
        "concurrent_streaming": lambda: concurrent_streaming.run(
            smoke=smoke, full=args.full
        ),
        "bootstrap_stats": lambda: bootstrap_stats.run(smoke=smoke),
        "serving_throughput": lambda: serving_throughput.run(
            smoke=smoke, full=args.full
        ),
        # after serving_throughput: merges its chaos block into the same
        # BENCH_serving.json artifact (read-modify-write)
        "serving_chaos": lambda: serving_chaos.run(
            smoke=smoke, full=args.full
        ),
        "adaptive_eval": lambda: adaptive_eval.run(
            smoke=smoke, full=args.full
        ),
    }
    only = {s.strip() for s in args.only.split(",") if s.strip()}

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            for line in fn():
                print(line)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0,ERROR={e!r}", file=sys.stderr)
        print(f"# {name} finished in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
