"""Paper Table 3: throughput by dataset size (8 executors) — scheduling
overhead amortizes above ~10k examples."""

from __future__ import annotations

import time

from benchmarks.simkit import simulate_eval


def run(sizes: tuple[int, ...] = (1_000, 10_000, 50_000, 100_000)) -> list[str]:
    lines = []
    for n in sizes:
        t0 = time.perf_counter()
        res = simulate_eval(n, 8)
        us = (time.perf_counter() - t0) * 1e6
        lines.append(
            f"table3_throughput_n{n},{us:.0f},"
            f"throughput={res.throughput_per_min:.0f}/min "
            f"p50={res.latency_p50_ms:.0f}ms p99={res.latency_p99_ms:.0f}ms "
            f"total={res.wall_s:.1f}s"
        )
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
