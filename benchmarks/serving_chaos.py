"""Serving chaos benchmark (ISSUE 9 tentpole).

Drives the full evaluation pipeline — suite, session, replica services,
continuous batchers, paged KV caches — under a deterministic fault
schedule (:class:`~repro.ft.failure_sim.ServingFaultSchedule`) and proves
the robustness contract end to end:

* **chaos suite** — a two-model suite on 3-replica fleets with a small
  page pool, hit by replica crashes, forced page-pressure preemptions
  and engine hangs.  Acceptance: the faulted run completes with **zero
  lost requests** and its metrics, CIs and pairwise significance cells
  are **byte-identical** to the fault-free run — faults cost work
  (restarts, recomputes), never statistics.
* **deadline hedging** — a 2-replica fleet where one replica wedges
  permanently (accepts work, never completes, never raises — invisible
  to everything except deadlines).  Per-request deadlines re-issue the
  stuck tickets to the healthy replica; the hedge leg wins every race
  and the metrics still match the fault-free run byte for byte.

Merges a ``chaos`` block into ``BENCH_serving.json`` (read-modify-write:
``serving_throughput`` owns the rest of the artifact).

  PYTHONPATH=src python -m benchmarks.serving_chaos [--smoke|--full]
"""

from __future__ import annotations

import json
import time

from repro.core import (
    EngineModelConfig,
    EvalSession,
    EvalSuite,
    EvalTask,
    InferenceConfig,
    MetricConfig,
    StatisticsConfig,
)
from repro.data import iter_qa_examples
from repro.ft import ServingFault, ServingFaultSchedule

from benchmarks import artifacts

SLOT_MODEL = EngineModelConfig(provider="slotsim", model_name="slot-sim")
SLOT_MODEL_B = EngineModelConfig(provider="slotsim", model_name="slot-sim-b")

#: fast virtual-time slot engine; the chaos suite measures correctness
#: under faults, not wall clock, so decode steps cost nothing
SLOT_KW = {"n_slots": 4, "step_ms": 0.0}


def _task(task_id: str, **inf_kw) -> EvalTask:
    return EvalTask(
        task_id=task_id,
        model=SLOT_MODEL,
        inference=InferenceConfig(
            batch_size=16, n_workers=4, cache_dir="", **inf_kw
        ),
        metrics=(MetricConfig("exact_match"), MetricConfig("token_f1")),
        statistics=StatisticsConfig(
            bootstrap_iterations=200, ci_method="percentile"
        ),
    )


def _metric_dict(res) -> dict:
    return {
        m: {"value": mv.value, "ci": list(mv.ci), "n": mv.n}
        for m, mv in res.metrics.items()
    }


def _cmp_cell(c) -> dict:
    return {
        "diff": c.diff, "diff_ci": list(c.diff_ci),
        "p_value": c.test.p_value, "effect": c.effect.value,
    }


def _suite_fingerprint(res) -> dict:
    """Every number the statistics plane emits, JSON-comparable."""
    return {
        "metrics": {
            f"{model}|{task_id}": _metric_dict(res.results[(model, task_id)])
            for (model, task_id) in res.results
        },
        "comparisons": {
            task_id: {
                metric: {
                    "|".join(pair): _cmp_cell(cell)
                    for pair, cell in cells.items()
                }
                for metric, cells in metrics_.items()
            }
            for task_id, metrics_ in res.comparisons.items()
        },
    }


def _chaos_suite(n_rows: int) -> dict:
    """Crash + page pressure + hang across two 3-replica fleets: the
    faulted run must finish every request and match the fault-free run
    byte for byte."""

    def build_plan() -> ServingFaultSchedule:
        # replicas attach in engine-creation order: 0-2 = model A fleet,
        # 3-5 = model B fleet (parallel_jobs=1 keeps the order fixed)
        return ServingFaultSchedule(
            [
                ServingFault(0, 3, "page_pressure", duration=2),
                ServingFault(0, 7, "slow_step", delay_s=0.0),
                ServingFault(1, 4, "replica_crash"),
                ServingFault(2, 2, "hang", duration=5),
                ServingFault(3, 5, "replica_crash"),
                ServingFault(4, 4, "page_pressure"),
                ServingFault(5, 3, "hang", duration=4),
            ]
        )

    inf_kw = dict(
        n_replicas=3, routing="round_robin", kv_page_size=4,
        health_probe_steps=50, max_replica_restarts=2,
        restart_backoff_s=0.001,
    )
    suite = EvalSuite("chaos").add_task(
        _task("served", **inf_kw), (lambda: iter_qa_examples(n_rows, seed=41))
    ).sweep_models([SLOT_MODEL, SLOT_MODEL_B])

    def run(plan: ServingFaultSchedule | None) -> dict:
        kw = dict(SLOT_KW, page_pool=48)
        if plan is not None:
            kw["fault_plan"] = plan
        t0 = time.perf_counter()
        with EvalSession(engine_kwargs=kw) as session:
            res = session.run_suite(suite, parallel_jobs=1)
            serving = session.serving_stats()
        return {
            "wall_s": time.perf_counter() - t0,
            "fingerprint": _suite_fingerprint(res),
            "serving": serving,
            "markdown": res.to_markdown(),
        }

    baseline = run(None)
    plan = build_plan()
    chaos = run(plan)

    submitted = sum(s["submitted"] for s in chaos["serving"])
    completed = sum(s["completed"] for s in chaos["serving"])
    coalesced = sum(s["coalesced"] for s in chaos["serving"])
    errors = sum(s["errors"] for s in chaos["serving"])
    restarts = sum(s["restarts"] for s in chaos["serving"])
    preemptions = sum(
        s.get("batcher", {}).get("preemptions", 0) for s in chaos["serving"]
    )
    zero_lost = errors == 0 and completed + coalesced == submitted
    identical = chaos["fingerprint"] == baseline["fingerprint"]
    return {
        "n_rows": n_rows,
        "n_models": 2,
        "n_replicas": 3,
        "engine": {"model": SLOT_MODEL.model_name, **SLOT_KW, "page_pool": 48},
        "faults_scheduled": len(plan.faults),
        "faults_injected": len(plan.injected),
        "injected": [list(f) for f in plan.injected],
        "submitted": submitted,
        "completed": completed,
        "coalesced": coalesced,
        "errors": errors,
        "restarts": restarts,
        "preemptions": preemptions,
        "baseline_wall_s": baseline["wall_s"],
        "chaos_wall_s": chaos["wall_s"],
        "zero_lost_requests": zero_lost,
        "byte_identical_under_faults": identical,
        "markdown_reports_faults": (
            "| preempt |" in chaos["markdown"]
            and "| restarts |" in chaos["markdown"]
        ),
        "ok": (
            zero_lost
            and identical
            and restarts >= 1      # the crashes fired and were recovered
            and preemptions >= 1   # the pressure fired and was absorbed
        ),
    }


def _deadline_hedge(n_rows: int, deadline_s: float = 0.05) -> dict:
    """One replica wedges permanently at its first pump; per-request
    deadlines hedge its tickets to the healthy replica."""
    inf_kw = dict(
        n_replicas=2, routing="round_robin",
        request_deadline_s=deadline_s,
    )
    suite = EvalSuite("hedge").add_task(
        _task("hedged", **inf_kw), (lambda: iter_qa_examples(n_rows, seed=43))
    )

    def run(plan: ServingFaultSchedule | None) -> dict:
        kw = dict(SLOT_KW)
        if plan is not None:
            kw["fault_plan"] = plan
        t0 = time.perf_counter()
        with EvalSession(engine_kwargs=kw) as session:
            res = session.run_suite(suite)
            serving = session.serving_stats()
        return {
            "wall_s": time.perf_counter() - t0,
            "fingerprint": _suite_fingerprint(res),
            "snap": serving[0],
        }

    baseline = run(None)
    wedged = run(
        ServingFaultSchedule(
            [ServingFault(0, 1, "hang", duration=1_000_000_000)]
        )
    )
    snap = wedged["snap"]
    zero_lost = (
        snap["errors"] == 0
        and snap["completed"] + snap["coalesced"] == snap["submitted"]
    )
    identical = wedged["fingerprint"] == baseline["fingerprint"]
    return {
        "n_rows": n_rows,
        "deadline_s": deadline_s,
        "engine": {"model": SLOT_MODEL.model_name, **SLOT_KW},
        "submitted": snap["submitted"],
        "deadline_expiries": snap["deadline_expiries"],
        "hedges_issued": snap["hedges_issued"],
        "hedges_won": snap["hedges_won"],
        "errors": snap["errors"],
        "baseline_wall_s": baseline["wall_s"],
        "hedged_wall_s": wedged["wall_s"],
        "zero_lost_requests": zero_lost,
        "byte_identical_under_faults": identical,
        "ok": zero_lost and identical and snap["hedges_won"] >= 1,
    }


def run(*, smoke: bool = False, full: bool = False) -> list[str]:
    if smoke:
        n_rows, hedge_rows = 40, 16
    elif full:
        n_rows, hedge_rows = 150, 48
    else:
        n_rows, hedge_rows = 80, 24

    cs = _chaos_suite(n_rows)
    de = _deadline_hedge(hedge_rows)

    completed_fraction = (
        (cs["completed"] + cs["coalesced"]) / cs["submitted"]
        if cs["submitted"]
        else 0.0
    )
    chaos_block = {
        "suite": cs,
        "deadline_hedge": de,
        "completed_fraction": completed_fraction,
        "zero_lost_requests": (
            cs["zero_lost_requests"] and de["zero_lost_requests"]
        ),
        "byte_identical_under_faults": (
            cs["byte_identical_under_faults"]
            and de["byte_identical_under_faults"]
        ),
        "ok": cs["ok"] and de["ok"],
    }

    # read-modify-write: serving_throughput owns the rest of the artifact
    path = artifacts.bench_path("BENCH_serving.json")
    payload = json.loads(path.read_text()) if path.exists() else {}
    payload["chaos"] = chaos_block
    artifacts.write_bench("BENCH_serving.json", payload)

    lines = [
        (
            f"serving_chaos,{cs['chaos_wall_s'] * 1e6 / max(1, cs['submitted']):.1f},"
            f"faults={cs['faults_injected']} restarts={cs['restarts']} "
            f"preempt={cs['preemptions']} lost=0 "
            f"identical={cs['byte_identical_under_faults']}"
        ),
        (
            f"serving_deadline_hedge,{de['hedged_wall_s'] * 1e6 / max(1, de['submitted']):.1f},"
            f"expired={de['deadline_expiries']} "
            f"hedges={de['hedges_issued']}/{de['hedges_won']} "
            f"identical={de['byte_identical_under_faults']}"
        ),
        (
            f"serving_chaos_accept,0,zero_lost={chaos_block['zero_lost_requests']} "
            f"identical={chaos_block['byte_identical_under_faults']} "
            f"ok={chaos_block['ok']}"
        ),
    ]
    if not chaos_block["ok"]:
        raise RuntimeError(
            f"serving chaos acceptance checks failed: {chaos_block}"
        )
    return lines


def main() -> None:
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--full", action="store_true")
    args = p.parse_args()
    for line in run(smoke=args.smoke, full=args.full):
        print(line)
    print(f"wrote {artifacts.bench_path('BENCH_serving.json')}")


if __name__ == "__main__":
    main()
