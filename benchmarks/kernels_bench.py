"""Kernel-layer microbenchmarks (CPU wall time of the jnp twin paths +
derived arithmetic intensity).  Interpret-mode Pallas timings are not
hardware-representative, so the jnp oracle is what we time on CPU; the
dry-run roofline covers the TPU projection."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bertscore.ref import bertscore_ref
from repro.kernels.bootstrap.ref import bootstrap_means_ref
from repro.kernels.decode_attention import (
    kv_page_bytes,
    paged_decode_attention_ref,
    quant_paged_decode_attention_ref,
    quantize_pages,
)
from repro.models.attention import chunked_attention
from repro.models.ssm import ssd_chunked


def _time(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(smoke: bool = False) -> list[str]:
    rng = np.random.RandomState(0)
    lines = []

    b, s, h, kh, d = 1, (512 if smoke else 2048), 8, 2, 64
    q = jnp.asarray(rng.randn(b, s, h, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, kh, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, kh, d), jnp.float32)
    fn = jax.jit(lambda q, k, v: chunked_attention(q, k, v, causal=True, scale=0.125))
    us = _time(fn, q, k, v)
    flops = 4 * s * s * h * d * 0.5
    lines.append(
        f"kernel_flash_attention_jnp_s{s},{us:.0f},gflops={flops/us/1e3:.1f}"
    )

    bb, slen, hh, p, n = 2, (256 if smoke else 1024), 8, 64, 64
    x = jnp.asarray(rng.randn(bb, slen, hh, p) * 0.3, jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(bb, slen, hh)) * 0.3 + 0.1, jnp.float32)
    a = jnp.asarray(-np.abs(rng.randn(hh)) - 0.2, jnp.float32)
    bm = jnp.asarray(rng.randn(bb, slen, hh, n) * 0.3, jnp.float32)
    cm = jnp.asarray(rng.randn(bb, slen, hh, n) * 0.3, jnp.float32)
    fn2 = jax.jit(lambda *xs: ssd_chunked(*xs, 256)[0])
    us = _time(fn2, x, dt, a, bm, cm)
    lines.append(f"kernel_ssd_jnp_l{slen},{us:.0f},tokens_per_s={bb*slen/us*1e6:.0f}")

    nboot_data = 10_000 if smoke else 100_000
    data = jnp.asarray(rng.randn(nboot_data), jnp.float32)
    fn3 = jax.jit(lambda d: bootstrap_means_ref(d, 256, 0))
    us = _time(fn3, data)
    lines.append(
        f"kernel_bootstrap_jnp_n{nboot_data // 1000}k_B256,{us:.0f},"
        f"resample_elems_per_s={256 * nboot_data / us * 1e6:.2e}"
    )

    # paged decode attention (bf16/f32 pages) vs int8 block-quantized
    # pages with dequant fused into the gather — same jnp-oracle timing
    # methodology; the interesting derived number is KV bytes per token
    # resident in the pool, which the quantized path roughly halves.
    pb, pkh, pg, pd, pps = 8, 2, 4, 64, 16
    npg = 4 if smoke else 16  # pages per sequence (seq len = npg * ps)
    pool = pb * npg + 1       # +1 trash page (page 0 by convention)
    qd = jnp.asarray(rng.randn(pb, pkh, pg, pd), jnp.float32)
    kp = jnp.asarray(rng.randn(pool, pkh, pps, pd), jnp.float32)
    vp = jnp.asarray(rng.randn(pool, pkh, pps, pd), jnp.float32)
    tables = jnp.arange(1, pool, dtype=jnp.int32).reshape(pb, npg)
    lengths = jnp.asarray(
        [npg * pps - (i * 7) % (npg * pps - 1) for i in range(pb)], jnp.int32
    )
    fn5 = jax.jit(paged_decode_attention_ref)
    us = _time(fn5, qd, kp, vp, tables, lengths)
    f32_bpt = 2 * pkh * pd * 4  # K+V bytes per resident token, f32 pages
    lines.append(
        f"kernel_paged_decode_jnp_b{pb}_p{npg * pps},{us:.0f},"
        f"kv_bytes_per_token={f32_bpt}"
    )
    kq, ks = quantize_pages(kp)
    vq, vs = quantize_pages(vp)
    fn6 = jax.jit(quant_paged_decode_attention_ref)
    us_q = _time(fn6, qd, kq, vq, ks, vs, tables, lengths)
    int8_bpt = 2 * pkh * pd * 1 + 2 * pkh * 4 // pps  # + amortized scales
    lines.append(
        f"kernel_quant_paged_decode_jnp_b{pb}_p{npg * pps},{us_q:.0f},"
        f"kv_bytes_per_token={int8_bpt} "
        f"capacity_ratio={f32_bpt / int8_bpt:.2f} "
        f"page_bytes_int8={kv_page_bytes(pps, pkh, pd, 1, 'int8')}"
    )

    nb = 16 if smoke else 64
    cand = jnp.asarray(rng.randn(nb, 48, 128), jnp.float32)
    ref = jnp.asarray(rng.randn(nb, 48, 128), jnp.float32)
    mask = jnp.ones((nb, 48))
    fn4 = jax.jit(lambda c, r, m: bertscore_ref(c, r, m, m)[2])
    us = _time(fn4, cand, ref, mask)
    lines.append(
        f"kernel_bertscore_jnp_b{nb},{us:.0f},pairs_per_s={nb / us * 1e6:.0f}"
    )
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
