"""Paper §5.4: Type-I error of the significance tests under the null
(identical model outputs + noise) stays at the nominal 5% level."""

from __future__ import annotations

import time

import numpy as np

from repro.stats import mcnemar_test, paired_t_test, wilcoxon_signed_rank


def run(n_sims: int = 2000, n: int = 100, full: bool = False) -> list[str]:
    if full:
        n_sims = 10_000
    rng = np.random.default_rng(1)
    rejections = {"mcnemar": 0, "paired_t": 0, "wilcoxon": 0}
    t0 = time.perf_counter()
    for _ in range(n_sims):
        base_p = rng.uniform(0.3, 0.8)
        # binary: same per-example success probability for both models
        a_bin = rng.random(n) < base_p
        b_bin = rng.random(n) < base_p
        rejections["mcnemar"] += int(mcnemar_test(a_bin, b_bin).p_value < 0.05)
        # continuous: same distribution
        a = rng.normal(0.0, 1.0, n)
        b = a + rng.normal(0.0, 0.5, n)  # paired noise, zero true shift
        rejections["paired_t"] += int(paired_t_test(a, b).p_value < 0.05)
        rejections["wilcoxon"] += int(wilcoxon_signed_rank(a, b).p_value < 0.05)
    dt = time.perf_counter() - t0
    return [
        f"type1_{name},{dt*1e6/n_sims:.0f},rate={cnt/n_sims:.4f} nominal=0.05"
        for name, cnt in rejections.items()
    ]


if __name__ == "__main__":
    print("\n".join(run()))
