"""The paper's replay workflow (Table 4) on the stage-pipeline API: one
paid inference run, then iterate on metric definitions at zero engine
cost — first via strict REPLAY cache mode, then via a stage swap
(``rescore_stages``) that re-scores the captured responses without
touching the engine at all — plus time-travel back to the exact table
version of the first run.

  PYTHONPATH=src python examples/replay_iteration.py
"""

import dataclasses as dc
import tempfile

from repro.core import (
    CachePolicy,
    EngineModelConfig,
    EvalSession,
    EvalTask,
    InferenceConfig,
    MetricConfig,
    StatisticsConfig,
    rescore_stages,
)
from repro.data import mixed_examples
from repro.storage import DeltaLite


def main() -> None:
    cache_dir = tempfile.mkdtemp() + "/cache"
    rows = mixed_examples(150, seed=8)
    base = EvalTask(
        task_id="replay-demo",
        model=EngineModelConfig(provider="anthropic", model_name="claude-3-haiku"),
        inference=InferenceConfig(batch_size=25, n_workers=4, cache_dir=cache_dir),
        metrics=(MetricConfig("token_f1"),),
        statistics=StatisticsConfig(bootstrap_iterations=500, ci_method="percentile"),
    )

    with EvalSession() as session:
        r0 = session.run_task(rows, base)
        print(f"initial run: {len(rows)} inferences, "
              f"cost=${r0.engine_stats['total_cost']:.4f}, "
              f"token_f1={r0.metrics['token_f1']}")

        # --- metric iteration in strict replay: zero API calls ----------------
        for i, metrics in enumerate(
            [
                (MetricConfig("token_f1"), MetricConfig("bleu")),
                (MetricConfig("rouge_l"), MetricConfig("embedding_similarity")),
            ],
            1,
        ):
            task = dc.replace(
                base, metrics=metrics,
                inference=dc.replace(
                    base.inference, cache_policy=CachePolicy.REPLAY
                ),
            )
            r = session.run_task(rows, task)
            names = ", ".join(f"{n}={mv.value:.3f}" for n, mv in r.metrics.items())
            print(f"iteration {i} (replay, 100% cache hits): {names}")

        # --- stage swap: re-score captured responses, no engine, no cache ------
        re_task = base.with_metrics(
            MetricConfig("exact_match"), MetricConfig("contains")
        )
        r = session.run_task(
            rows, re_task, stages=rescore_stages(r0.responses)
        )
        names = ", ".join(f"{n}={mv.value:.3f}" for n, mv in r.metrics.items())
        print(f"iteration 3 (stage swap, {r.engine_stats['calls']} engine "
              f"calls): {names}")

        print(f"\nsession totals: {session.accounting.as_dict()}")

    # --- Delta-style table inspection ----------------------------------------
    table = DeltaLite(cache_dir, key_column="prompt_hash")
    print(f"\ncache table: version={table.latest_version()}, "
          f"{len(table.read())} rows")
    print("history:")
    for h in table.history():
        print(f"  v{h['version']}: +{len(h['added'])} segment(s)")
    v0 = table.read(version=0)
    print(f"time travel to v0: {len(v0)} rows (first committed segment)")


if __name__ == "__main__":
    main()
