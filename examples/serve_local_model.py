"""Evaluate a model served ON the accelerator substrate: the LocalJaxEngine
runs a (reduced) assigned architecture through the continuous-batching
scheduler, and the paper's evaluation pipeline treats it exactly like any
API provider — same caching, rate limiting and statistics.

This is the end-to-end serving driver (deliverable (b)): batched requests
against a locally-served model.

  PYTHONPATH=src python examples/serve_local_model.py [--arch mamba2-2.7b]
"""

import argparse
import tempfile

from repro.core import (
    EngineModelConfig,
    EvalRunner,
    EvalTask,
    InferenceConfig,
    MetricConfig,
    SimulatedAPIEngine,
    StatisticsConfig,
)
from repro.data import qa_examples


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-4b")
    p.add_argument("--examples", type=int, default=24)
    args = p.parse_args()

    rows = qa_examples(args.examples, seed=1)
    task = EvalTask(
        task_id=f"serve-local-{args.arch}",
        model=EngineModelConfig(
            provider="local", model_name=args.arch, max_tokens=12, reduced=True
        ),
        inference=InferenceConfig(
            batch_size=8, n_workers=2, cache_dir=tempfile.mkdtemp() + "/cache"
        ),
        metrics=(
            MetricConfig("token_f1"),
            MetricConfig("llm_judge", type="llm_judge",
                         params={"rubric": "fluency", "scale": 5}),
        ),
        statistics=StatisticsConfig(bootstrap_iterations=300, ci_method="percentile"),
    )
    judge = SimulatedAPIEngine(
        EngineModelConfig(provider="openai", model_name="gpt-4o")
    )
    judge.initialize()

    result = EvalRunner(judge_engine=judge).evaluate(rows, task)
    print(f"served {len(rows)} requests on a reduced {args.arch} "
          f"(continuous batching, greedy decode)\n")
    for name, mv in result.metrics.items():
        print(f"  {name:12s} {mv}")
    print(f"\nthroughput: {result.throughput_per_min:.1f} examples/min (CPU)")
    print(f"cache: {result.cache_stats}")


if __name__ == "__main__":
    main()
