"""Paper §5.6 end-to-end: instruction-following evaluation comparing two
models with lexical, semantic and LLM-judge metrics, bootstrap CIs and the
full significance-test pipeline.

This example intentionally stays on the legacy ``EvalRunner`` shim to
document backward compatibility: it delegates to a fresh single-task
``EvalSession`` per call, so pre-session code keeps working unchanged
(see examples/quickstart.py for the session/suite API).

  PYTHONPATH=src python examples/instruction_following.py
"""

import tempfile

from repro.core import (
    EngineModelConfig,
    EvalRunner,
    EvalTask,
    InferenceConfig,
    MetricConfig,
    StatisticsConfig,
    compare_results,
)
from repro.data import instruction_examples


def make_task(model_name: str, cache_root: str) -> EvalTask:
    return EvalTask(
        task_id=f"instruction-following-{model_name}",
        model=EngineModelConfig(provider="openai", model_name=model_name),
        inference=InferenceConfig(
            batch_size=50, n_workers=4,
            cache_dir=f"{cache_root}/{model_name}",
            rate_limit_rpm=10_000,
        ),
        metrics=(
            MetricConfig("exact_match", type="lexical"),
            MetricConfig("bertscore", type="semantic"),
            MetricConfig(
                "llm_judge", type="llm_judge",
                params={"rubric": "Rate helpfulness 1-5", "scale": 5},
            ),
        ),
        statistics=StatisticsConfig(
            confidence_level=0.95, bootstrap_iterations=1000, ci_method="bca"
        ),
    )


def main() -> None:
    rows = instruction_examples(200, seed=4)
    cache_root = tempfile.mkdtemp()
    runner = EvalRunner()

    res_a = runner.evaluate(rows, make_task("gpt-4o", cache_root))
    res_b = runner.evaluate(rows, make_task("gpt-4o-mini", cache_root))

    print("=== gpt-4o ===")
    for name, mv in res_a.metrics.items():
        print(f"  {name:12s} {mv}")
    unparseable = len(res_a.logs.get("judge_unparseable", []))
    print(f"  judge unparseable: {unparseable} "
          f"({unparseable/len(rows)*100:.2f}%) logged for review")

    print("\n=== gpt-4o-mini ===")
    for name, mv in res_b.metrics.items():
        print(f"  {name:12s} {mv}")

    print("\n=== comparison (test selected per metric type, Table 2) ===")
    for name, cmp in compare_results(res_a, res_b).items():
        print(f"  {cmp.summary()}")
        print(f"    selected because: {cmp.recommendation.reason}")


if __name__ == "__main__":
    main()
