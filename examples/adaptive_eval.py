"""Adaptive evaluation: stop when the statistics say so.

Two simulated models are compared on two streaming tasks.  Instead of
scoring every example, the budget scheduler samples in rounds, watches the
anytime-valid confidence sequence on the paired score difference, and
stops each task the moment a verdict is certified — then a per-task
stopping rule is shown on its own, including the bit-identical resume of
a stopped run.

  PYTHONPATH=src python examples/adaptive_eval.py
"""

import tempfile

from repro.core import (
    BudgetConfig,
    EngineModelConfig,
    EvalSession,
    EvalSuite,
    EvalTask,
    InferenceConfig,
    MetricConfig,
    StatisticsConfig,
    run_adaptive_suite,
)
from repro.data import iter_qa_examples, iter_summarization_examples

N_AVAILABLE = 20_000  # per task per model — far more than needed


def _task(task_id: str, spill_root: str) -> EvalTask:
    return EvalTask(
        task_id=task_id,
        inference=InferenceConfig(batch_size=32, n_workers=4, cache_dir=""),
        metrics=(MetricConfig("token_f1"),),
        statistics=StatisticsConfig(
            bootstrap_iterations=500, ci_method="percentile"
        ),
    ).with_streaming(
        max_memory_rows=256, spill_dir=f"{spill_root}/{task_id}"
    )


def main() -> None:
    spill_root = tempfile.mkdtemp()

    # -- suite-level budget scheduler -----------------------------------------
    suite = (
        EvalSuite("adaptive-demo")
        .add_task(_task("qa", spill_root), lambda: iter_qa_examples(N_AVAILABLE))
        .add_task(
            _task("summarization", spill_root),
            lambda: iter_summarization_examples(N_AVAILABLE),
        )
        .sweep_models([
            EngineModelConfig(provider="openai", model_name="gpt-4o"),
            EngineModelConfig(provider="openai", model_name="gpt-3.5-turbo"),
        ])
    )
    budget = BudgetConfig(
        total_examples=10_000,   # fresh-inference budget across all arms
        round_examples=512,
        min_examples=512,
        metric="token_f1",
    )
    with EvalSession() as session:
        res = run_adaptive_suite(session, suite, budget)

    b = res.adaptive["budget"]
    print(f"budget: {b['spent']} / {b['total_examples']} examples "
          f"over {b['rounds']} round(s)\n")
    for tid, t in res.adaptive["tasks"].items():
        consumed = max(t["consumed"].values())
        print(f"  {tid:15s} {t['reason']:10s} "
              f"consumed {consumed}/{N_AVAILABLE} per arm "
              f"({1 - consumed / N_AVAILABLE:.0%} saved)  {t['verdicts']}")

    # -- per-task stopping rule, and resume of a stopped run ------------------
    task = _task("solo", spill_root).with_stopping(
        target_half_width=0.02, min_examples=512
    )
    with EvalSession() as session:
        first = session.run_task(iter_qa_examples(N_AVAILABLE), task)
    ad = first.logs["adaptive"]
    print(f"\nsolo task stopped: {ad['reason']} at n={ad['n_examples']} "
          f"(half-width {ad['half_width']:.4f})")

    with EvalSession() as session:
        again = session.run_task(iter_qa_examples(N_AVAILABLE), task)
        replay_calls = session.accounting.engine_calls
    same = all(
        again.metrics[m].value == mv.value and again.metrics[m].ci == mv.ci
        for m, mv in first.metrics.items()
    )
    print(f"resume: {replay_calls} new engine calls, "
          f"bit-identical={same}, stop replayed at "
          f"chunk {again.logs['adaptive']['stop_chunk']}")


if __name__ == "__main__":
    main()
