"""Quickstart: evaluate models on a synthetic QA set with full statistical
accounting — the paper's minimal workflow, on the EvalSession API.

A session owns the shared resources (engine registry, response caches,
rate limiters, worker pools), so evaluating several tasks or models pays
setup cost once.  ``run_suite`` adds cross-model pairwise significance
testing on top.

  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

from repro.core import (
    EngineModelConfig,
    EvalSession,
    EvalSuite,
    EvalTask,
    InferenceConfig,
    MetricConfig,
    StatisticsConfig,
)
from repro.data import qa_examples


def main() -> None:
    rows = qa_examples(100, seed=0)
    task = EvalTask(
        task_id="quickstart-qa",
        model=EngineModelConfig(provider="openai", model_name="gpt-4o-mini"),
        inference=InferenceConfig(
            batch_size=25,
            n_workers=4,
            cache_dir=tempfile.mkdtemp() + "/cache",
        ),
        metrics=(
            MetricConfig("exact_match"),
            MetricConfig("token_f1"),
            MetricConfig("rouge_l"),
            MetricConfig("embedding_similarity", type="semantic"),
        ),
        statistics=StatisticsConfig(
            confidence_level=0.95, bootstrap_iterations=1000, ci_method="bca"
        ),
    )

    with EvalSession() as session:
        # -- single task ------------------------------------------------------
        result = session.run_task(rows, task)
        print(f"evaluated {len(rows)} examples "
              f"({result.throughput_per_min:.0f} examples/min)\n")
        for name, mv in result.metrics.items():
            print(f"  {name:24s} {mv}")
        print(f"\ncache: {result.cache_stats}")
        print(f"engine cost: ${result.engine_stats['total_cost']:.4f}")
        print(f"stage timing: "
              f"{ {k: round(v, 3) for k, v in result.timing.items()} }")

        # -- model sweep with pairwise significance ---------------------------
        suite = (
            EvalSuite("quickstart-sweep")
            .add_task(task, rows)
            .sweep_models([
                EngineModelConfig(provider="openai", model_name="gpt-4o-mini"),
                EngineModelConfig(provider="openai", model_name="gpt-4o"),
            ])
        )
        suite_res = session.run_suite(suite)
        print("\n" + suite_res.summary())
        print(f"\nsession accounting: {session.accounting.as_dict()}")


if __name__ == "__main__":
    main()
