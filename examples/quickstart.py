"""Quickstart: evaluate a model on a synthetic QA set with full statistical
accounting — the paper's minimal workflow.

  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

from repro.core import (
    EngineModelConfig,
    EvalRunner,
    EvalTask,
    InferenceConfig,
    MetricConfig,
    StatisticsConfig,
)
from repro.data import qa_examples


def main() -> None:
    rows = qa_examples(100, seed=0)
    task = EvalTask(
        task_id="quickstart-qa",
        model=EngineModelConfig(provider="openai", model_name="gpt-4o-mini"),
        inference=InferenceConfig(
            batch_size=25,
            n_workers=4,
            cache_dir=tempfile.mkdtemp() + "/cache",
        ),
        metrics=(
            MetricConfig("exact_match"),
            MetricConfig("token_f1"),
            MetricConfig("rouge_l"),
            MetricConfig("embedding_similarity", type="semantic"),
        ),
        statistics=StatisticsConfig(
            confidence_level=0.95, bootstrap_iterations=1000, ci_method="bca"
        ),
    )

    result = EvalRunner().evaluate(rows, task)

    print(f"evaluated {len(rows)} examples "
          f"({result.throughput_per_min:.0f} examples/min)\n")
    for name, mv in result.metrics.items():
        print(f"  {name:24s} {mv}")
    print(f"\ncache: {result.cache_stats}")
    print(f"engine cost: ${result.engine_stats['total_cost']:.4f}")
    print(f"stage timing: { {k: round(v, 3) for k, v in result.timing.items()} }")


if __name__ == "__main__":
    main()
