from repro.metrics.judge import JudgeOutcome, pairwise_judge, pointwise_judge
from repro.metrics.lexical import (
    bleu,
    contains,
    exact_match,
    normalize,
    rouge_l,
    token_f1,
)
from repro.metrics.registry import (
    BINARY_METRICS,
    MetricContext,
    available_metrics,
    get_metric,
)
from repro.metrics.semantic import HashEmbedder, bertscore_f1, embedding_similarity

__all__ = [
    "BINARY_METRICS", "HashEmbedder", "JudgeOutcome", "MetricContext",
    "available_metrics", "bertscore_f1", "bleu", "contains",
    "embedding_similarity", "exact_match", "get_metric", "normalize",
    "pairwise_judge", "pointwise_judge", "rouge_l", "token_f1",
]
