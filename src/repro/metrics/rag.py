"""RAG metrics (paper §4.1, following RAGAS): faithfulness, context
relevance, answer relevance, context precision, context recall."""

from __future__ import annotations

import numpy as np

from repro.core.engines import InferenceEngine
from repro.metrics.judge import pointwise_judge
from repro.metrics.lexical import normalize, token_f1
from repro.metrics.semantic import HashEmbedder, embedding_similarity


def faithfulness(
    engine: InferenceEngine,
    answers: list[str],
    contexts: list[list[str]],
    *,
    scale: int = 5,
) -> np.ndarray:
    """Judge-verified grounding: is the answer supported by the context?"""
    questions = [
        "Is the response fully supported by this context? Context: "
        + " ".join(ctx)
        for ctx in contexts
    ]
    outcome = pointwise_judge(
        engine, questions, answers,
        rubric="groundedness: every claim must appear in the context",
        scale=scale,
    )
    return (outcome.scores - 1.0) / (scale - 1.0)  # -> [0, 1]


def context_relevance(
    engine: InferenceEngine,
    questions: list[str],
    contexts: list[list[str]],
    *,
    scale: int = 5,
) -> np.ndarray:
    outcome = pointwise_judge(
        engine,
        questions,
        [" ".join(ctx) for ctx in contexts],
        rubric="relevance of the retrieved context to the question",
        scale=scale,
    )
    return (outcome.scores - 1.0) / (scale - 1.0)


def answer_relevance(
    questions: list[str],
    answers: list[str],
    embedder: HashEmbedder | None = None,
) -> np.ndarray:
    """Embedding cosine between question and answer (RAGAS-style)."""
    return embedding_similarity(answers, questions, embedder)


def context_precision(
    contexts: list[list[str]],
    references: list[str],
    *,
    overlap_threshold: float = 0.35,
) -> np.ndarray:
    """Mean-precision@k over the retrieval ranking: are relevant chunks
    ranked early?  A chunk is relevant if its token-F1 with the reference
    clears the threshold."""
    out = np.zeros(len(contexts))
    for i, (chunks, ref) in enumerate(zip(contexts, references)):
        rel = [token_f1(c, ref) >= overlap_threshold for c in chunks]
        if not any(rel):
            out[i] = 0.0
            continue
        hits = 0
        precisions = []
        for k, r in enumerate(rel, 1):
            if r:
                hits += 1
                precisions.append(hits / k)
        out[i] = float(np.mean(precisions))
    return out


def context_recall(
    contexts: list[list[str]],
    references: list[str],
) -> np.ndarray:
    """Fraction of reference tokens covered by the retrieved context."""
    out = np.zeros(len(contexts))
    for i, (chunks, ref) in enumerate(zip(contexts, references)):
        ref_tokens = set(normalize(ref).split())
        if not ref_tokens:
            out[i] = 1.0
            continue
        ctx_tokens = set(normalize(" ".join(chunks)).split())
        out[i] = len(ref_tokens & ctx_tokens) / len(ref_tokens)
    return out
