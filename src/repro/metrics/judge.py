"""LLM-as-judge metrics (paper §4.1): pointwise grading and pairwise
comparison via a judge engine, with regex score extraction and unparseable
logging (§A.3).

Judge prompts follow the MT-Bench structure (Zheng et al., 2023): rubric,
the material to grade, and an explicit "Score: <int>" answer format.
"""

from __future__ import annotations

import dataclasses
import re

import numpy as np

from repro.core.engines import InferenceEngine, InferenceRequest

POINTWISE_TEMPLATE = (
    "[Judge] Rate the following response on a 1-{scale} scale.\n"
    "Rubric: {rubric}\n"
    "Question: {question}\n"
    "Response: {response}\n"
    "Answer with 'Score: <number>' then a one-sentence explanation."
)

PAIRWISE_TEMPLATE = (
    "[Judge] Compare two responses to the question below.\n"
    "Rubric: {rubric}\n"
    "Question: {question}\n"
    "Response A: {response_a}\n"
    "Response B: {response_b}\n"
    "Answer with 'Winner: A' or 'Winner: B' then one sentence."
)

_SCORE_RE = re.compile(r"score\s*[:=]?\s*(\d+(?:\.\d+)?)", re.IGNORECASE)
_WINNER_RE = re.compile(r"winner\s*[:=]?\s*([AB])", re.IGNORECASE)


@dataclasses.dataclass
class JudgeOutcome:
    scores: np.ndarray           # (n,) float, NaN where unparseable
    unparseable: list[dict]      # logged for review (paper §5.6)

    @property
    def unparseable_rate(self) -> float:
        return len(self.unparseable) / max(len(self.scores), 1)


def extract_score(text: str, scale: int) -> float | None:
    m = _SCORE_RE.search(text)
    if m is None:
        return None
    val = float(m.group(1))
    if not 1.0 <= val <= scale:
        return None
    return val


def pointwise_judge(
    engine: InferenceEngine,
    questions: list[str],
    responses: list[str],
    *,
    rubric: str = "helpfulness and accuracy",
    scale: int = 5,
    max_tokens: int = 48,
) -> JudgeOutcome:
    prompts = [
        POINTWISE_TEMPLATE.format(
            scale=scale, rubric=rubric, question=q, response=r
        )
        for q, r in zip(questions, responses)
    ]
    outs = engine.infer_batch(
        [InferenceRequest(p, max_tokens=max_tokens) for p in prompts]
    )
    scores = np.full(len(prompts), np.nan)
    bad: list[dict] = []
    for i, o in enumerate(outs):
        val = extract_score(o.text, scale) if o.error is None else None
        if val is None:
            bad.append({"index": i, "raw": o.text[:200], "error": o.error})
        else:
            scores[i] = val
    return JudgeOutcome(scores=scores, unparseable=bad)


def pairwise_judge(
    engine: InferenceEngine,
    questions: list[str],
    responses_a: list[str],
    responses_b: list[str],
    *,
    rubric: str = "helpfulness and accuracy",
    max_tokens: int = 32,
    debias_position: bool = True,
) -> JudgeOutcome:
    """Returns 1.0 where A wins, 0.0 where B wins, NaN unparseable.

    ``debias_position`` runs each comparison in both orders and averages —
    the standard mitigation for position bias (paper §6.1 limitation).
    """

    def run(order_ab: bool) -> list[float | None]:
        prompts = [
            PAIRWISE_TEMPLATE.format(
                rubric=rubric, question=q,
                response_a=a if order_ab else b,
                response_b=b if order_ab else a,
            )
            for q, a, b in zip(questions, responses_a, responses_b)
        ]
        outs = engine.infer_batch(
            [InferenceRequest(p, max_tokens=max_tokens) for p in prompts]
        )
        vals: list[float | None] = []
        for o in outs:
            m = _WINNER_RE.search(o.text) if o.error is None else None
            if m is None:
                vals.append(None)
                continue
            a_won = m.group(1).upper() == "A"
            vals.append(float(a_won if order_ab else not a_won))
        return vals

    first = run(True)
    second = run(False) if debias_position else first
    scores = np.full(len(questions), np.nan)
    bad: list[dict] = []
    for i, (x, y) in enumerate(zip(first, second)):
        if x is None and y is None:
            bad.append({"index": i, "raw": "", "error": "unparseable"})
        else:
            vals = [v for v in (x, y) if v is not None]
            scores[i] = float(np.mean(vals))
    return JudgeOutcome(scores=scores, unparseable=bad)
