"""Metric registry: MetricConfig.name -> batch scorer.

A metric is ``fn(rows, responses, ctx) -> np.ndarray`` of per-example
scores (NaN = unscorable, excluded from aggregation with counts reported).
``ctx`` carries shared resources (judge engine, embedder) so engines are
constructed once per evaluation, not per metric.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import numpy as np

from repro.core.config import MetricConfig
from repro.metrics import lexical, rag, semantic
from repro.metrics.judge import pointwise_judge


@dataclasses.dataclass
class MetricContext:
    judge_engine: Any = None
    embedder: semantic.HashEmbedder | None = None
    logs: dict = dataclasses.field(default_factory=dict)


Scorer = Callable[[list[dict], list[str], MetricContext], np.ndarray]
_REGISTRY: dict[str, Scorer] = {}
#: metrics whose scores are 0/1 (drives Wilson CIs + McNemar selection)
BINARY_METRICS = {"exact_match", "contains"}
#: metrics that call ctx.judge_engine (drives lazy engine setup in ScoreStage)
JUDGE_METRICS = {"llm_judge", "faithfulness", "context_relevance"}


def register(name: str):
    def deco(fn: Scorer) -> Scorer:
        _REGISTRY[name] = fn
        return fn

    return deco


def get_metric(cfg: MetricConfig) -> Scorer:
    if cfg.name not in _REGISTRY:
        raise KeyError(
            f"unknown metric {cfg.name!r}; available: {sorted(_REGISTRY)}"
        )
    base = _REGISTRY[cfg.name]
    if cfg.params:
        return lambda rows, resp, ctx: base(rows, resp, ctx, **cfg.params)
    return base


def resolve_metrics(
    cfgs: "Sequence[MetricConfig]",
) -> list[tuple[str, Scorer]]:
    """Resolve a task's metric configs to bound scorers in one pass.

    This is the single resolution point used by the pipeline: ScoreStage
    resolves to scorers, and PrepareStage calls it for validation so
    unknown-metric errors surface before any paid inference happens.
    """
    return [(cfg.name, get_metric(cfg)) for cfg in cfgs]


def _refs(rows: list[dict]) -> list[str]:
    return [str(r.get("reference", "")) for r in rows]


def _questions(rows: list[dict]) -> list[str]:
    return [str(r.get("question", "")) for r in rows]


# -- lexical ------------------------------------------------------------------

for _name in ("exact_match", "contains", "token_f1", "bleu", "rouge_l"):
    def _make(name: str) -> Scorer:
        def scorer(rows, responses, ctx, **kw):
            return lexical.batch_lexical(name, responses, _refs(rows), **kw)

        return scorer

    _REGISTRY[_name] = _make(_name)


# -- semantic ------------------------------------------------------------------


@register("embedding_similarity")
def _embed_sim(rows, responses, ctx, **kw):
    return semantic.embedding_similarity(responses, _refs(rows), ctx.embedder)


@register("bertscore")
def _bertscore(rows, responses, ctx, **kw):
    return semantic.bertscore_f1(responses, _refs(rows), ctx.embedder, **kw)


# -- LLM judge ------------------------------------------------------------------


@register("llm_judge")
def _judge(rows, responses, ctx, *, rubric: str = "helpfulness", scale: int = 5):
    assert ctx.judge_engine is not None, "llm_judge needs a judge engine"
    outcome = pointwise_judge(
        ctx.judge_engine, _questions(rows), responses, rubric=rubric, scale=scale
    )
    ctx.logs.setdefault("judge_unparseable", []).extend(outcome.unparseable)
    return outcome.scores


# -- RAG -------------------------------------------------------------------------


def _contexts(rows: list[dict]) -> list[list[str]]:
    return [list(r.get("contexts", [])) for r in rows]


@register("faithfulness")
def _faith(rows, responses, ctx, **kw):
    assert ctx.judge_engine is not None
    return rag.faithfulness(ctx.judge_engine, responses, _contexts(rows), **kw)


@register("context_relevance")
def _ctx_rel(rows, responses, ctx, **kw):
    assert ctx.judge_engine is not None
    return rag.context_relevance(
        ctx.judge_engine, _questions(rows), _contexts(rows), **kw
    )


@register("answer_relevance")
def _ans_rel(rows, responses, ctx, **kw):
    return rag.answer_relevance(_questions(rows), responses, ctx.embedder)


@register("context_precision")
def _ctx_prec(rows, responses, ctx, **kw):
    return rag.context_precision(_contexts(rows), _refs(rows), **kw)


@register("context_recall")
def _ctx_rec(rows, responses, ctx, **kw):
    return rag.context_recall(_contexts(rows), _refs(rows))


def available_metrics() -> list[str]:
    return sorted(_REGISTRY)
