"""Lexical metrics (paper §4.1): exact match, contains, token F1, BLEU,
ROUGE-L.  Scalar reference implementations plus vectorized batch fronts.

Normalization and tokenization are memoized (bounded LRU): a scoring pass
runs several lexical metrics over the same response/reference strings, so
without the cache ``normalize()``'s three regex passes re-run 2–3x per
example across exact_match / token_f1 / ROUGE-L.  The cache key is the
raw string; entries are shared across metrics and across streaming chunks
(references repeat across examples far more often than they miss)."""

from __future__ import annotations

import functools
import math
import re
import string
from collections import Counter

import numpy as np

_PUNCT = str.maketrans("", "", string.punctuation)
_ARTICLES = re.compile(r"\b(a|an|the)\b")
_WS = re.compile(r"\s+")
#: entry bound: cross-metric reuse needs 2n entries (pred + ref per
#: example) to survive the metric-by-metric sequential scan, so this
#: covers streaming chunks always and in-memory batches up to ~32k
#: examples; beyond that the scan pattern degrades to the uncached cost
_MEMO_SIZE = 65536
#: byte bound: strings longer than this bypass the cache entirely —
#: multi-KB responses (streaming summarization) never pin heap, and their
#: scoring cost is dominated by LCS/n-grams, not the regex passes anyway
_MEMO_MAX_LEN = 512


def _normalize_impl(text: str) -> str:
    text = text.lower().translate(_PUNCT)
    text = _ARTICLES.sub(" ", text)
    return _WS.sub(" ", text).strip()


_normalize_cached = functools.lru_cache(maxsize=_MEMO_SIZE)(_normalize_impl)


def normalize(text: str) -> str:
    """SQuAD-style normalization: lowercase, strip punctuation/articles."""
    if len(text) > _MEMO_MAX_LEN:
        return _normalize_impl(text)
    return _normalize_cached(text)


def _tokens_impl(text: str) -> tuple[str, ...]:
    return tuple(normalize(text).split())


_norm_tokens_cached = functools.lru_cache(maxsize=_MEMO_SIZE)(_tokens_impl)


def _norm_tokens(text: str) -> tuple[str, ...]:
    """Normalized token tuple (immutable, so it can live in the LRU)."""
    if len(text) > _MEMO_MAX_LEN:
        return _tokens_impl(text)
    return _norm_tokens_cached(text)


def exact_match(pred: str, ref: str, *, normalized: bool = True) -> float:
    if normalized:
        return float(normalize(pred) == normalize(ref))
    return float(pred == ref)


def contains(pred: str, ref: str, *, normalized: bool = True) -> float:
    if normalized:
        return float(normalize(ref) in normalize(pred))
    return float(ref in pred)


def token_f1(pred: str, ref: str) -> float:
    """Token-level F1 (Rajpurkar et al., 2016)."""
    p_toks = _norm_tokens(pred)
    r_toks = _norm_tokens(ref)
    if not p_toks or not r_toks:
        return float(p_toks == r_toks)
    common = Counter(p_toks) & Counter(r_toks)
    n_common = sum(common.values())
    if n_common == 0:
        return 0.0
    precision = n_common / len(p_toks)
    recall = n_common / len(r_toks)
    return 2 * precision * recall / (precision + recall)


def _ngrams(tokens: tuple[str, ...], n: int) -> Counter:
    return Counter(tuple(tokens[i : i + n]) for i in range(len(tokens) - n + 1))


def bleu(pred: str, ref: str, *, max_n: int = 4, smooth: float = 1.0) -> float:
    """Sentence BLEU with brevity penalty and add-k smoothing
    (Papineni et al., 2002; Lin & Och smoothing)."""
    p_toks = _norm_tokens(pred)
    r_toks = _norm_tokens(ref)
    if not p_toks:
        return 0.0
    log_precisions = []
    for n in range(1, max_n + 1):
        p_ng = _ngrams(p_toks, n)
        r_ng = _ngrams(r_toks, n)
        overlap = sum((p_ng & r_ng).values())
        total = max(sum(p_ng.values()), 0)
        if total == 0:
            log_precisions.append(math.log(1e-9))
            continue
        num = overlap + (smooth if n > 1 else 0.0)
        den = total + (smooth if n > 1 else 0.0)
        log_precisions.append(math.log(num / den) if num > 0 else math.log(1e-9))
    geo = math.exp(sum(log_precisions) / max_n)
    bp = 1.0 if len(p_toks) >= len(r_toks) else math.exp(1 - len(r_toks) / len(p_toks))
    return bp * geo


def _lcs_len(a: tuple[str, ...], b: tuple[str, ...]) -> int:
    if not a or not b:
        return 0
    prev = [0] * (len(b) + 1)
    for x in a:
        cur = [0]
        for j, y in enumerate(b, 1):
            cur.append(prev[j - 1] + 1 if x == y else max(prev[j], cur[-1]))
        prev = cur
    return prev[-1]


def rouge_l(pred: str, ref: str) -> float:
    """ROUGE-L F1 (longest common subsequence; Lin 2004)."""
    p_toks = _norm_tokens(pred)
    r_toks = _norm_tokens(ref)
    lcs = _lcs_len(p_toks, r_toks)
    if lcs == 0:
        return 0.0
    prec = lcs / len(p_toks)
    rec = lcs / len(r_toks)
    return 2 * prec * rec / (prec + rec)


# -- batch fronts -----------------------------------------------------------------

_SCALAR = {
    "exact_match": exact_match,
    "contains": contains,
    "token_f1": token_f1,
    "bleu": bleu,
    "rouge_l": rouge_l,
}


def batch_lexical(name: str, preds: list[str], refs: list[str], **kw) -> np.ndarray:
    fn = _SCALAR[name]
    return np.asarray([fn(p, r, **kw) for p, r in zip(preds, refs)], np.float64)
