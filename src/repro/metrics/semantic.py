"""Semantic metrics (paper §4.1): embedding cosine similarity + BERTScore.

Offline substitute for sentence-transformers / roberta-large: a
deterministic **feature-hashing embedder** (char-n-gram + word hashing into
a fixed-dimension space, L2-normalized).  It preserves the property the
metrics need — similar surface forms map to nearby vectors — and is
identical across processes/hosts.  On a real deployment the embedder is
swappable for model-based encoders (the LocalJaxEngine exposes hidden
states; see ``model_embedder``).

BERTScore greedy matching runs through ``repro/kernels/bertscore`` (Pallas
on TPU, jnp oracle on CPU).
"""

from __future__ import annotations

import hashlib

import numpy as np

from repro.kernels.bertscore.ref import bertscore_ref


class HashEmbedder:
    """Deterministic n-gram feature-hashing embedder."""

    def __init__(self, dim: int = 256, ngram: tuple[int, int] = (3, 5)):
        self.dim = dim
        self.ngram = ngram

    def _features(self, text: str) -> list[str]:
        text = " ".join(text.lower().split())
        feats = text.split()
        padded = f" {text} "
        lo, hi = self.ngram
        for n in range(lo, hi + 1):
            feats.extend(padded[i : i + n] for i in range(len(padded) - n + 1))
        return feats

    def _bucket(self, feat: str) -> tuple[int, float]:
        h = hashlib.md5(feat.encode()).digest()
        idx = int.from_bytes(h[:4], "little") % self.dim
        sign = 1.0 if h[4] & 1 else -1.0
        return idx, sign

    def embed(self, text: str) -> np.ndarray:
        v = np.zeros(self.dim, np.float32)
        for f in self._features(text):
            idx, sign = self._bucket(f)
            v[idx] += sign
        n = np.linalg.norm(v)
        return v / n if n > 0 else v

    def embed_batch(self, texts: list[str]) -> np.ndarray:
        return np.stack([self.embed(t) for t in texts])

    def embed_tokens(self, text: str, max_len: int) -> tuple[np.ndarray, np.ndarray]:
        """Per-word embeddings (for BERTScore): (max_len, dim), mask."""
        words = text.lower().split()[:max_len]
        out = np.zeros((max_len, self.dim), np.float32)
        mask = np.zeros(max_len, np.float32)
        for i, w in enumerate(words):
            out[i] = self.embed(w)
            mask[i] = 1.0
        return out, mask


_DEFAULT = HashEmbedder()


def embedding_similarity(
    preds: list[str], refs: list[str], embedder: HashEmbedder | None = None
) -> np.ndarray:
    emb = embedder or _DEFAULT
    p = emb.embed_batch(preds)
    r = emb.embed_batch(refs)
    return np.clip(np.sum(p * r, axis=1), -1.0, 1.0).astype(np.float64)


def bertscore_f1(
    preds: list[str],
    refs: list[str],
    embedder: HashEmbedder | None = None,
    *,
    max_len: int = 64,
    use_pallas: bool = False,
) -> np.ndarray:
    emb = embedder or _DEFAULT
    cand = np.zeros((len(preds), max_len, emb.dim), np.float32)
    ref = np.zeros((len(refs), max_len, emb.dim), np.float32)
    cmask = np.zeros((len(preds), max_len), np.float32)
    rmask = np.zeros((len(refs), max_len), np.float32)
    for i, (p, r) in enumerate(zip(preds, refs)):
        cand[i], cmask[i] = emb.embed_tokens(p, max_len)
        ref[i], rmask[i] = emb.embed_tokens(r, max_len)
    if use_pallas:
        from repro.kernels.bertscore import bertscore

        _, _, f1 = bertscore(
            cand, ref, cmask, rmask, use_pallas=True, interpret=True
        )
    else:
        _, _, f1 = bertscore_ref(cand, ref, cmask, rmask)
    return np.asarray(f1, np.float64)
