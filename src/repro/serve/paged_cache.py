"""Host-side paged KV-cache manager with hash-chain prefix sharing.

The device cache is a **pool of fixed-size pages** instead of contiguous
per-slot slabs (DESIGN.md §8).  This module owns the bookkeeping only —
no JAX, no device arrays — so the same manager drives both the real
``ContinuousBatcher`` (which gathers pool pages through page tables) and
the ``SimulatedSlotEngine`` (which only charges simulated prefill cost).

Sharing model
-------------
Each *full* page of a prompt is identified by a rolling hash chain

    h_0 = H(tokens[0:ps]),   h_i = H(h_{i-1} || tokens[i*ps:(i+1)*ps])

so a page hash commits to the entire token prefix up to and including
that page — two prompts share page *i* iff their first ``(i+1)*ps``
tokens are identical.  ``acquire`` walks the chain against the prefix
index and ref-counts every resident match; the suffix (first divergent
page onward) gets fresh pages and a normal prefill.

Sharing is capped at ``(len(tokens) - 1) // page_size`` pages: the page
holding the **final** prompt token is never shared, so every request
prefills at least one token (prefill must produce last-position logits)
and decode always writes into a private page.  Copy-on-write at the
first divergent page is therefore structurally unreachable in the
batcher; ``ensure_position`` still implements it as a defensive
invariant (a page that is shared *or* indexed is never written in
place).

Page lifecycle: ``free`` → ``active`` (ref > 0) → on release either
``free`` (never indexed) or ``cached`` (ref == 0 but still in the
prefix index, LRU-evicted on pool pressure).
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Hashable, Sequence


class PagePoolExhausted(RuntimeError):
    """No free or evictable page in the pool.

    The typed form of pool pressure: the scheduler catches this to
    preempt a decode slot (or defer a prefill) instead of letting the
    allocation failure kill the whole replica (DESIGN.md §9)."""


def kv_page_bytes(
    page_size: int,
    kv_heads: int,
    head_dim: int,
    n_layers: int,
    kv_cache_dtype: str = "bf16",
) -> int:
    """HBM bytes one KV page costs, **including** the scale buffer.

    K and V each store ``n_layers * kv_heads * page_size * head_dim``
    elements per page; ``"int8"`` stores them as one byte each plus one
    f32 absmax scale per (layer, kv head, K/V) per page (DESIGN.md §10).
    This single formula is shared by the real batcher's byte-budgeted
    pool sizing, the slot simulator, and the analytical serve cells
    (``launch/cells.py``), so benchmark capacity ratios and napkin math
    agree by construction.
    """
    elems = 2 * n_layers * kv_heads * page_size * head_dim  # K + V
    if kv_cache_dtype == "int8":
        return elems + 2 * n_layers * kv_heads * 4  # payload + f32 scales
    if kv_cache_dtype == "bf16":
        return elems * 2
    raise ValueError(
        f"kv_cache_dtype must be 'bf16' or 'int8', got {kv_cache_dtype!r}"
    )


def pages_for_budget(pool_bytes: int, page_bytes: int) -> int:
    """Pages a byte budget admits; raises if it cannot hold even one."""
    n = pool_bytes // page_bytes
    if n <= 0:
        raise ValueError(
            f"pool budget {pool_bytes} B below one page ({page_bytes} B)"
        )
    return n


def page_hash_chain(tokens: Sequence, page_size: int) -> list[bytes]:
    """One digest per *full* page; ``h_i`` commits to ``tokens[:(i+1)*ps]``."""
    chain: list[bytes] = []
    prev = b""
    for i in range(len(tokens) // page_size):
        page = tokens[i * page_size : (i + 1) * page_size]
        payload = prev + "\x1f".join(str(t) for t in page).encode()
        prev = hashlib.sha256(payload).digest()
        chain.append(prev)
    return chain


@dataclasses.dataclass
class PrefixMatch:
    """Result of :meth:`PagedCacheManager.acquire`."""

    page_ids: list[int]      #: full page table for the prompt, in order
    n_shared_pages: int      #: leading entries reused from the prefix index
    n_shared_tokens: int     #: ``n_shared_pages * page_size``


@dataclasses.dataclass
class PageWrite:
    """Result of :meth:`PagedCacheManager.ensure_position`."""

    page_id: int             #: pool page to write into
    page_index: int          #: index of that page in the owner's table
    offset: int              #: row within the page
    allocated: bool = False  #: page was appended to the table by this call
    cow_src: int | None = None  #: device must copy this page into page_id


@dataclasses.dataclass
class PagedCacheStats:
    lookups: int = 0
    prefix_pages_hit: int = 0
    prefix_tokens_saved: int = 0
    pages_allocated: int = 0
    cow_copies: int = 0
    evictions: int = 0


class PagedCacheManager:
    """Refcounted page pool + prefix index.  Single-threaded by design:
    callers (the batcher loop / the sim engine under its lock) serialize
    access."""

    def __init__(
        self,
        n_pages: int,
        page_size: int,
        *,
        prefix_cache: bool = True,
        page_bytes: int = 0,
    ):
        if page_size <= 0:
            raise ValueError(f"page_size must be positive, got {page_size}")
        if n_pages <= 0:
            raise ValueError(f"n_pages must be positive, got {n_pages}")
        self.n_pages = n_pages
        self.page_size = page_size
        self.prefix_cache = prefix_cache
        #: HBM bytes one page costs *including* its quantization-scale
        #: buffer (0 = caller never asked for byte accounting).  Pure
        #: metadata: allocation is in pages; bytes exist so pool budgets,
        #: leak checks and ``kv_bytes_per_token`` stats agree on one number.
        self.page_bytes = page_bytes
        self._free: list[int] = list(range(n_pages - 1, -1, -1))
        self._ref = [0] * n_pages
        #: page id -> chain hash for indexed pages (and the reverse map)
        self._hash_of: dict[int, bytes] = {}
        self._index: dict[bytes, int] = {}
        #: ref == 0 but still indexed, in LRU order (oldest first)
        self._cached: OrderedDict[int, None] = OrderedDict()
        self._tables: dict[Hashable, list[int]] = {}
        self.stats = PagedCacheStats()

    # -- introspection (used by leak tests) -----------------------------------

    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def pages_cached(self) -> int:
        return len(self._cached)

    @property
    def pages_active(self) -> int:
        return self.n_pages - len(self._free) - len(self._cached)

    @property
    def pool_bytes(self) -> int:
        return self.n_pages * self.page_bytes

    @property
    def bytes_free(self) -> int:
        return self.pages_free * self.page_bytes

    @property
    def bytes_cached(self) -> int:
        return self.pages_cached * self.page_bytes

    @property
    def bytes_active(self) -> int:
        return self.pages_active * self.page_bytes

    @property
    def kv_bytes_per_token(self) -> int:
        """Bytes one cached token costs, scale buffer included."""
        return self.page_bytes // self.page_size

    def refcount(self, page_id: int) -> int:
        return self._ref[page_id]

    def table(self, owner: Hashable) -> list[int]:
        return list(self._tables[owner])

    # -- page state transitions -----------------------------------------------

    def _alloc(self) -> int:
        if self._free:
            pid = self._free.pop()
        elif self._cached:
            pid, _ = self._cached.popitem(last=False)  # LRU eviction
            self._unindex(pid)
            self.stats.evictions += 1
        else:
            raise PagePoolExhausted(
                f"page pool exhausted: all {self.n_pages} pages are active"
            )
        self._ref[pid] = 1
        self.stats.pages_allocated += 1
        return pid

    def _retain(self, pid: int) -> None:
        if self._ref[pid] == 0:
            del self._cached[pid]
        self._ref[pid] += 1

    def _release_page(self, pid: int) -> None:
        assert self._ref[pid] > 0, f"double release of page {pid}"
        self._ref[pid] -= 1
        if self._ref[pid] == 0:
            if pid in self._hash_of:
                self._cached[pid] = None  # retain content for future matches
            else:
                self._free.append(pid)

    def _unindex(self, pid: int) -> None:
        h = self._hash_of.pop(pid, None)
        if h is not None and self._index.get(h) == pid:
            del self._index[h]

    # -- public API -----------------------------------------------------------

    def acquire(self, owner: Hashable, tokens: Sequence) -> PrefixMatch:
        """Build ``owner``'s page table for ``tokens``: match the leading
        hash chain against resident pages (never the final token's page),
        then allocate fresh pages for the suffix."""
        if owner in self._tables:
            raise ValueError(f"owner {owner!r} already holds a page table")
        if not tokens:
            raise ValueError("cannot acquire pages for an empty prompt")
        ps = self.page_size
        n_total = -(-len(tokens) // ps)  # ceil
        shared: list[int] = []
        if self.prefix_cache:
            self.stats.lookups += 1
            max_share = (len(tokens) - 1) // ps
            chain = page_hash_chain(tokens[: max_share * ps], ps)
            for h in chain:
                pid = self._index.get(h)
                if pid is None:
                    break
                # retain immediately so a later _alloc cannot LRU-evict a
                # page this very walk already matched
                self._retain(pid)
                shared.append(pid)
                self._cached.pop(pid, None)
        fresh: list[int] = []
        try:
            for _ in range(n_total - len(shared)):
                fresh.append(self._alloc())
        except PagePoolExhausted:
            # roll back: a partial acquire must not leak retained shared
            # pages or the fresh pages allocated before the failure
            for pid in shared + fresh:
                self._release_page(pid)
            raise
        self._tables[owner] = shared + fresh
        self.stats.prefix_pages_hit += len(shared)
        self.stats.prefix_tokens_saved += len(shared) * ps
        return PrefixMatch(
            page_ids=shared + fresh,
            n_shared_pages=len(shared),
            n_shared_tokens=len(shared) * ps,
        )

    def register(self, owner: Hashable, tokens: Sequence) -> int:
        """Index every *full* page of ``tokens`` after its prefill has
        populated the owner's pages.  Returns the number of pages newly
        indexed.  When two identical prompts prefilled concurrently the
        second registration is a no-op for already-indexed hashes (its
        duplicate pages simply free on release)."""
        if not self.prefix_cache:
            return 0
        table = self._tables[owner]
        chain = page_hash_chain(tokens, self.page_size)
        newly = 0
        for i, h in enumerate(chain):
            pid = table[i]
            if h in self._index:
                continue  # first registration wins
            if pid in self._hash_of:
                continue  # page already committed to a different chain
            self._index[h] = pid
            self._hash_of[pid] = h
            newly += 1
        return newly

    def ensure_position(self, owner: Hashable, pos: int) -> PageWrite:
        """Return a *privately writable* page for token position ``pos``,
        extending the owner's table or copy-on-writing a shared/indexed
        page as needed."""
        table = self._tables[owner]
        page_index, offset = divmod(pos, self.page_size)
        if page_index > len(table):
            raise ValueError(
                f"non-contiguous write: pos {pos} needs page {page_index} "
                f"but owner {owner!r} holds {len(table)} pages"
            )
        if page_index == len(table):
            pid = self._alloc()
            table.append(pid)
            return PageWrite(pid, page_index, offset, allocated=True)
        pid = table[page_index]
        if self._ref[pid] == 1 and pid not in self._hash_of:
            return PageWrite(pid, page_index, offset)
        # shared or indexed: writing in place would corrupt other readers
        # or leave a stale hash in the index — copy-on-write
        new = self._alloc()
        self._release_page(pid)
        table[page_index] = new
        self.stats.cow_copies += 1
        return PageWrite(new, page_index, offset, cow_src=pid)

    def release(self, owner: Hashable) -> None:
        """Drop the owner's table; each page frees or parks in the LRU
        prefix cache depending on whether it is indexed."""
        for pid in self._tables.pop(owner):
            self._release_page(pid)

    def release_all(self) -> int:
        """Drop every outstanding owner table (replica-restart reset hook).
        Returns the number of owners released."""
        owners = list(self._tables)
        for owner in owners:
            self.release(owner)
        return len(owners)

    def check_no_leaks(self) -> None:
        """Raise unless every page is accounted for and, with no owners
        outstanding, nothing is active."""
        if self._tables:
            raise AssertionError(f"outstanding owners: {list(self._tables)}")
        if self.pages_active != 0:
            held = [p for p in range(self.n_pages) if self._ref[p] > 0]
            raise AssertionError(f"leaked pages with nonzero refcount: {held}")
        if len(self._free) + len(self._cached) != self.n_pages:
            raise AssertionError("free + cached does not cover the pool")
        if self.bytes_free + self.bytes_cached + self.bytes_active \
                != self.pool_bytes:
            raise AssertionError(
                "byte partition (free + cached + active) does not cover "
                "the pool budget — scale-buffer bytes miscounted"
            )
