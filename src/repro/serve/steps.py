"""Jit-able serving steps: prefill / decode / greedy sampling.

``make_serve_step`` builds the function the dry-run lowers for the
``decode_32k`` / ``long_500k`` shapes: ONE new token for every sequence in
the batch against a ``seq_len``-long cache.  Returning the sampled token id
(not the logits) keeps the step's output tiny — on a real pod the (B, V)
logits never leave the chips.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

PyTree = Any


def greedy_sample(logits: jax.Array, vocab_size: int) -> jax.Array:
    """Argmax over the un-padded vocab region. logits (B, Vp) -> (B,) int32."""
    vp = logits.shape[-1]
    if vp > vocab_size:
        logits = jnp.where(jnp.arange(vp) < vocab_size, logits, -jnp.inf)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(
    logits: jax.Array, vocab_size: int, temperature: float, key: jax.Array
) -> jax.Array:
    vp = logits.shape[-1]
    if vp > vocab_size:
        logits = jnp.where(jnp.arange(vp) < vocab_size, logits, -jnp.inf)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(
        jnp.int32
    )


def make_prefill_fn(model: Any, cfg: ModelConfig) -> Callable:
    """(params, batch, cache) -> (last_logits (B, Vp), cache)."""

    def prefill_fn(params: PyTree, batch: dict, cache: PyTree):
        return model.prefill(params, batch, cache)

    return prefill_fn


def make_decode_fn(model: Any, cfg: ModelConfig) -> Callable:
    """(params, tokens (B,1), cache, positions (B,)) -> (logits (B,Vp), cache)."""

    def decode_fn(params: PyTree, tokens: jax.Array, cache: PyTree, positions):
        return model.decode_step(params, tokens, cache, positions)

    return decode_fn


def make_serve_step(model: Any, cfg: ModelConfig) -> Callable:
    """The dry-run target: one decode token + greedy sample for the batch."""

    def serve_step(
        params: PyTree,
        cache: PyTree,
        tokens: jax.Array,  # (B, 1) int32 — the tokens sampled last step
        positions: jax.Array,  # (B,) int32 — their positions
    ) -> tuple[jax.Array, PyTree]:
        logits, cache = model.decode_step(params, tokens, cache, positions)
        next_tokens = greedy_sample(logits, cfg.vocab_size)
        return next_tokens, cache

    return serve_step
