"""Continuous-batching scheduler (Orca-style iteration-level batching).

A fixed pool of ``n_slots`` decode slots steps in lock-step (SPMD gang
scheduling — see DESIGN.md §2: Spark's work-stealing does not transfer to a
jitted step, so slots are the unit of multiplexing instead).  Each iteration:

1. finished slots (EOS / max_tokens) emit their completion and free up,
2. free slots are refilled from the request queue (admission-controlled),
3. a single batched decode step advances every active slot by one token.

Prefill is **exact-length**: each distinct prompt length compiles one
prefill program (``prefill_recompiles`` counts them).  Right-padding to
power-of-two buckets would bound recompiles for attention caches (padding
is never attended) but corrupts SSM recurrent state, so callers that need
bounded compiles bucket prompt lengths at the data layer instead.

With ``page_size`` > 0 the KV cache is **paged** (DESIGN.md §8): device
leaves become page pools, each slot holds a page table, and a host-side
:class:`~repro.serve.paged_cache.PagedCacheManager` shares prompt-prefix
pages across requests by hash chain — a prompt whose leading pages are
resident skips prefill for them (suffix prefill picks up at the first
non-shared token).  Decode gathers each slot's pages into the contiguous
view the decode step already understands, then scatters the one new KV
row back to its pool page.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engines import BatcherStats
from repro.kernels.decode_attention.quant import absmax_quantize
from repro.models.params import init_params, is_spec
from repro.serve import steps as steps_lib
from repro.serve.paged_cache import (
    PagedCacheManager,
    PagePoolExhausted,
    pages_for_budget,
)
from repro.sharding import ShardingRules, use_rules

PyTree = Any

#: model families whose caches are pure attention KV (batch x seq leaves)
#: and whose prefill supports the suffix ``start`` offset
_PAGEABLE_FAMILIES = ("dense", "moe")


@dataclasses.dataclass
class Request:
    request_id: int
    prompt_tokens: list[int]
    max_new_tokens: int = 32
    extras: dict | None = None  # e.g. {"frames": ...} for enc-dec archs


@dataclasses.dataclass
class Completion:
    request_id: int
    tokens: list[int]
    prompt_len: int
    finished_reason: str  # "eos" | "length" | "truncated"
    latency_s: float = 0.0


def batch_axis_tree(cache_specs: PyTree) -> PyTree:
    """Index of the logical ``batch`` axis for every cache leaf."""
    return jax.tree.map(
        lambda s: s.axes.index("batch"), cache_specs, is_leaf=is_spec
    )


def paged_pool_specs(
    cache_specs: PyTree, n_pages: int, page_size: int
) -> PyTree:
    """Rewrite per-slot cache specs into page-pool specs: the ``batch``
    axis becomes the pool's page axis and ``cache_seq`` shrinks to one
    page.  Requires ``cache_seq`` directly after ``batch`` on every leaf
    (true for all attention KV caches) so a page is a contiguous block."""

    def to_pool(spec):
        if "cache_seq" not in spec.axes:
            raise ValueError(
                f"cache leaf {spec.axes} has no cache_seq axis — paged KV "
                f"does not support recurrent-state caches"
            )
        b_ax = spec.axes.index("batch")
        s_ax = spec.axes.index("cache_seq")
        if s_ax != b_ax + 1:
            raise ValueError(
                f"cache leaf {spec.axes}: cache_seq must follow batch"
            )
        shape = list(spec.shape)
        shape[b_ax] = n_pages
        shape[s_ax] = page_size
        axes = tuple("kv_pages" if a == "batch" else a for a in spec.axes)
        return dataclasses.replace(spec, shape=tuple(shape), axes=axes)

    return jax.tree.map(to_pool, cache_specs, is_leaf=is_spec)


def _pool_rest_shape(spec) -> tuple[int, ...]:
    """Leaf dims other than (pages, page row), in normalized order — what
    the moveaxis helpers see as the trailing ``...`` of ``(P, ps, ...)``."""
    ax = spec.axes.index("kv_pages" if "kv_pages" in spec.axes else "batch")
    return tuple(
        d for i, d in enumerate(spec.shape) if i not in (ax, ax + 1)
    )


def paged_scale_specs(pool_specs: PyTree) -> PyTree:
    """Per-page quantization-scale specs for an int8 pool: one f32 scale
    per (page, *rest[:-1]) group — the trailing axis (head_dim) and the
    page-row axis are reduced away by the absmax.  Stored pre-normalized
    as ``(P, ...)`` so the movement helpers index them without moveaxis.
    Init is "ones", matching the all-zero-group convention of
    ``absmax_quantize`` (zero bytes at scale 1.0 dequantize to exact 0)."""

    def to_scale(spec):
        ax = spec.axes.index("kv_pages")
        rest = _pool_rest_shape(spec)
        rest_axes = tuple(
            a for i, a in enumerate(spec.axes) if i not in (ax, ax + 1)
        )
        return dataclasses.replace(
            spec,
            shape=(spec.shape[ax],) + rest[:-1],
            axes=("kv_pages",) + rest_axes[:-1],
            dtype=jnp.float32,
            init="ones",
        )

    return jax.tree.map(to_scale, pool_specs, is_leaf=is_spec)


def paged_page_bytes(
    cache_specs: PyTree, page_size: int, kv_cache_dtype: str
) -> int:
    """HBM bytes one pool page costs across every cache leaf, including
    the f32 scale buffer in int8 mode (the spec-tree counterpart of
    ``paged_cache.kv_page_bytes``, exact for any leaf layout)."""
    total = 0
    for spec in jax.tree.leaves(cache_specs, is_leaf=is_spec):
        rest = _pool_rest_shape(spec)
        elems = page_size * int(np.prod(rest)) if rest else page_size
        if kv_cache_dtype == "int8":
            total += elems  # one byte per element
            total += int(np.prod(rest[:-1])) * 4 if rest else 4  # f32 scales
        else:
            total += elems * jnp.dtype(spec.dtype).itemsize
    return total


class ContinuousBatcher:
    """Slot-multiplexed decode loop around jitted prefill/decode steps."""

    def __init__(
        self,
        model: Any,
        cfg: ModelConfig,
        params: PyTree,
        *,
        n_slots: int = 8,
        max_len: int = 512,
        eos_id: int = 1,
        temperature: float = 0.0,
        admission: Callable[[int], float] | None = None,
        cache_dtype: Any = jnp.float32,
        max_prefills_per_step: int = 0,
        device: Any = None,
        rules: ShardingRules | None = None,
        page_size: int = 0,
        prefix_cache: bool = True,
        page_pool: int = 0,
        kv_cache_dtype: str = "bf16",
        page_pool_bytes: int = 0,
    ):
        self.model, self.cfg, self.params = model, cfg, params
        self.n_slots, self.max_len, self.eos_id = n_slots, max_len, eos_id
        self.temperature = temperature
        self.admission = admission
        #: 0 = unlimited; otherwise at most this many prompts are prefilled
        #: per step() — prefill/decode disaggregation: a long-prompt backlog
        #: waits for a prefill slot instead of stalling every decode step
        #: behind a wall of back-to-back prefills
        self.max_prefills_per_step = max_prefills_per_step
        #: single-device placement (one replica per host device) or, for a
        #: multi-device replica, logical-axis rules over its mesh — the two
        #: are mutually exclusive
        self.device = device
        self.rules = rules
        self.prefix = cfg.n_vision_tokens if cfg.family == "vlm" else 0
        #: 0 = contiguous per-slot cache; > 0 = paged pool with this page size
        self.page_size = page_size
        #: "bf16" = full-precision pool pages (the pre-quantization path);
        #: "int8" = absmax block-quantized pages + per-(page, head) scales,
        #: dequantized in-kernel / at gather time (DESIGN.md §10)
        if kv_cache_dtype not in ("bf16", "int8"):
            raise ValueError(
                f"kv_cache_dtype must be 'bf16' or 'int8', got "
                f"{kv_cache_dtype!r}"
            )
        if kv_cache_dtype == "int8" and not page_size:
            raise ValueError(
                "kv_cache_dtype='int8' requires a paged cache (page_size > 0)"
            )
        self.kv_cache_dtype = kv_cache_dtype
        self.quantized = kv_cache_dtype == "int8"
        self.scales: PyTree | None = None

        cache_specs = model.cache_specs(n_slots, max_len, cache_dtype)
        self._batch_axes = batch_axis_tree(cache_specs)
        if page_size:
            if cfg.family not in _PAGEABLE_FAMILIES or getattr(
                cfg, "use_mla", False
            ):
                raise ValueError(
                    f"paged KV cache supports GQA attention families "
                    f"{_PAGEABLE_FAMILIES}, not {cfg.family}"
                    + (" with MLA" if getattr(cfg, "use_mla", False) else "")
                )
            if max_len % page_size:
                raise ValueError(
                    f"max_len {max_len} must be a multiple of page_size "
                    f"{page_size}"
                )
            if rules is not None:
                raise ValueError(
                    "paged KV cache does not compose with sharding rules yet"
                )
            self.pages_per_slot = max_len // page_size
            #: default pool is worst case: every slot full + one defensive
            #: CoW per slot — it never exhausts.  ``page_pool`` pins it
            #: smaller (must still cover the longest single request, or
            #: that request thrashes preempt/recompute forever); decode
            #: pressure then triggers preemption instead of death.  One
            #: extra trailing page absorbs decode writes from inactive
            #: slots (their stale positions must scatter *somewhere* valid)
            self._page_bytes = paged_page_bytes(
                cache_specs, page_size, kv_cache_dtype
            )
            if page_pool_bytes:
                if page_pool:
                    raise ValueError(
                        "page_pool and page_pool_bytes are mutually exclusive"
                    )
                #: byte-budgeted pool: same HBM budget admits ~2x pages at
                #: int8 — this is where quantization buys capacity
                n_pool = pages_for_budget(page_pool_bytes, self._page_bytes)
            else:
                n_pool = page_pool or (n_slots * self.pages_per_slot + n_slots)
            self._trash_page = n_pool
            self.manager = PagedCacheManager(
                n_pool, page_size, prefix_cache=prefix_cache,
                page_bytes=self._page_bytes,
            )
            pool_specs = paged_pool_specs(cache_specs, n_pool + 1, page_size)
            if self.quantized:
                pool_specs = jax.tree.map(
                    lambda s: dataclasses.replace(
                        s, dtype=jnp.int8, init="zeros"
                    ),
                    pool_specs, is_leaf=is_spec,
                )
                self.cache = init_params(jax.random.key(0), pool_specs)
                self.scales = init_params(
                    jax.random.key(0), paged_scale_specs(pool_specs)
                )
            else:
                self.cache = init_params(jax.random.key(0), pool_specs)
        else:
            self.cache = init_params(jax.random.key(0), cache_specs)
        if rules is not None:
            self.params = jax.device_put(
                self.params, rules.param_shardings(model.param_specs())
            )
            self.cache = jax.device_put(
                self.cache, rules.param_shardings(cache_specs)
            )
        elif device is not None:
            self.params = jax.device_put(self.params, device)
            self.cache = jax.device_put(self.cache, device)
            if self.scales is not None:
                self.scales = jax.device_put(self.scales, device)
        row_specs = model.cache_specs(1, max_len, cache_dtype)
        self._row_specs = row_specs

        self._decode_fn = steps_lib.make_decode_fn(model, cfg)
        self._decode = jax.jit(self._decode_fn)
        if page_size:
            self._prefill = jax.jit(
                lambda params, batch, cache, start: model.prefill(
                    params, batch, cache, start=start
                ),
                static_argnums=(3,),
            )
            if self.quantized:
                self._paged_decode_q = jax.jit(self._paged_decode_q_impl)
                self._read_prefix_q = jax.jit(self._read_prefix_q_impl)
                self._write_pages_q = jax.jit(
                    self._write_pages_q_impl, static_argnums=(4,)
                )
                self._copy_page_q = jax.jit(self._copy_page_q_impl)
            else:
                self._paged_decode = jax.jit(self._paged_decode_impl)
                self._read_prefix = jax.jit(self._read_prefix_impl)
                self._write_pages = jax.jit(
                    self._write_pages_impl, static_argnums=(3,)
                )
                self._copy_page = jax.jit(self._copy_page_impl)
        else:
            self._prefill = jax.jit(
                lambda params, batch, cache: model.prefill(params, batch, cache)
            )
        self._insert = jax.jit(self._insert_impl)

        # slot state (host side)
        self.slot_free = [True] * n_slots
        self.slot_req: list[Request | None] = [None] * n_slots
        self.slot_tokens: list[list[int]] = [[] for _ in range(n_slots)]
        self.slot_pos = np.zeros((n_slots,), np.int32)  # next position to write
        self.slot_started = np.zeros((n_slots,), np.float64)
        self.cur_tokens = np.zeros((n_slots, 1), np.int32)
        self.queue: list[Request] = []
        self.completions: list[Completion] = []
        self.steps_run = 0
        self.key = jax.random.key(0)
        #: occupancy/throughput counters for the persistent streaming mode
        #: (surfaced through the InferenceService into session accounting)
        self.stats = BatcherStats(n_slots=n_slots)
        if page_size:
            self.stats.kv_bytes_per_token = self._page_bytes // page_size
            self.stats.pool_pages = self.manager.n_pages
        #: prompt shapes already compiled: lengths in contiguous mode,
        #: (shared_prefix, suffix_len) pairs in paged mode
        self._seen_prefill_shapes: set = set()
        #: deterministic chaos hook: called with ``steps_run`` at the top
        #: of every step(); may raise (replica_crash), sleep (slow_step),
        #: or return a kind string — "page_pressure" forces a preemption,
        #: "hang" skips this decode step (see ServingFaultSchedule.as_hook)
        self.fault_hook: Callable[[int], str | None] | None = None

    # -- cache row insertion ---------------------------------------------------

    def _insert_impl(self, cache: PyTree, row: PyTree, slot: jax.Array) -> PyTree:
        return jax.tree.map(
            lambda full, r, ax: jax.lax.dynamic_update_slice_in_dim(
                full, r.astype(full.dtype), slot, axis=ax
            ),
            cache,
            row,
            self._batch_axes,
        )

    # -- paged cache movement ----------------------------------------------------
    #
    # Every helper normalizes a leaf to (pages, page_size, ...) /
    # (batch, seq, ...) with moveaxis and restores the leaf layout on the
    # way out, so one implementation serves every cache-leaf layout.

    def _read_prefix_impl(
        self, row: PyTree, pools: PyTree, shared_ids: jax.Array
    ) -> PyTree:
        """Gather shared prefix pages into positions [0, n*ps) of a B=1 row."""

        def read(r, pool, ax):
            p = jnp.moveaxis(pool, (ax, ax + 1), (0, 1))
            pref = p[shared_ids].reshape((-1,) + p.shape[2:])
            rr = jnp.moveaxis(r, (ax, ax + 1), (0, 1))
            rr = rr.at[0, : pref.shape[0]].set(pref.astype(rr.dtype))
            return jnp.moveaxis(rr, (0, 1), (ax, ax + 1))

        return jax.tree.map(read, row, pools, self._batch_axes)

    def _write_pages_impl(
        self, pools: PyTree, row: PyTree, fresh_ids: jax.Array, start_page: int
    ) -> PyTree:
        """Scatter row positions [start_page*ps, (start_page+n)*ps) into
        the pool pages that the prefill just produced."""
        ps = self.page_size
        n = fresh_ids.shape[0]

        def write(pool, r, ax):
            rr = jnp.moveaxis(r, (ax, ax + 1), (0, 1))
            chunk = rr[0, start_page * ps : (start_page + n) * ps]
            chunk = chunk.reshape((n, ps) + rr.shape[2:])
            p = jnp.moveaxis(pool, (ax, ax + 1), (0, 1))
            p = p.at[fresh_ids].set(chunk.astype(p.dtype))
            return jnp.moveaxis(p, (0, 1), (ax, ax + 1))

        return jax.tree.map(write, pools, row, self._batch_axes)

    def _copy_page_impl(
        self, pools: PyTree, src: jax.Array, dst: jax.Array
    ) -> PyTree:
        def cp(pool, ax):
            p = jnp.moveaxis(pool, (ax, ax + 1), (0, 1))
            p = p.at[dst].set(p[src])
            return jnp.moveaxis(p, (0, 1), (ax, ax + 1))

        return jax.tree.map(cp, pools, self._batch_axes)

    def _paged_decode_impl(
        self,
        params: PyTree,
        tokens: jax.Array,
        pools: PyTree,
        tables: jax.Array,       # (B, pages_per_slot) int32
        positions: jax.Array,    # (B,)
        write_pages: jax.Array,  # (B,) pool page receiving each slot's new KV
        write_offsets: jax.Array,  # (B,) row within that page
    ) -> tuple[jax.Array, PyTree]:
        """Gather page tables into the contiguous (B, max_len) view the
        decode step understands, run it, scatter the one new KV row per
        slot back to its pool page.  Inactive slots' write targets point
        at the trash page, so stale positions never corrupt live pages."""
        b = tokens.shape[0]

        def gather(pool, ax):
            p = jnp.moveaxis(pool, (ax, ax + 1), (0, 1))
            g = p[tables]  # (B, nP, ps, ...)
            g = g.reshape((b, -1) + p.shape[2:])
            return jnp.moveaxis(g, (0, 1), (ax, ax + 1))

        view = jax.tree.map(gather, pools, self._batch_axes)
        logits, view = self._decode_fn(params, tokens, view, positions)

        def scatter(pool, leaf, ax):
            v = jnp.moveaxis(leaf, (ax, ax + 1), (0, 1))
            rows = v[jnp.arange(b), positions]  # (B, ...) the new KV rows
            p = jnp.moveaxis(pool, (ax, ax + 1), (0, 1))
            p = p.at[write_pages, write_offsets].set(rows.astype(p.dtype))
            return jnp.moveaxis(p, (0, 1), (ax, ax + 1))

        pools = jax.tree.map(scatter, pools, view, self._batch_axes)
        return logits, pools

    # -- quantized paged movement (kv_cache_dtype == "int8") ---------------------
    #
    # Same normalized (pages, page_size, ...) layout as above, but pool
    # leaves hold int8 bytes and the separate ``self.scales`` tree holds
    # one f32 absmax scale per (page, *rest[:-1]) group — the page-row and
    # head_dim axes are the reduced ones.  Dequantization happens at
    # gather time; every write re-quantizes from full-precision values
    # with stale rows masked to zero, so stored bytes are a pure function
    # of the valid token history (the fixed-dtype determinism contract).

    @staticmethod
    def _expand_scale(s: jax.Array) -> jax.Array:
        """(n, *rest[:-1]) scale -> broadcastable over (n, ps, *rest)."""
        return jnp.expand_dims(s, (1, s.ndim + 1))

    def _map_pool_scale(self, fn, pools, scales, extra=None):
        """Map ``fn(pool, scale, extra, ax) -> (pool', scale')`` over the
        cache trees, unzipping the per-leaf pairs back into two trees."""
        p_leaves, tdef = jax.tree.flatten(pools)
        s_leaves = tdef.flatten_up_to(scales)
        a_leaves = tdef.flatten_up_to(self._batch_axes)
        e_leaves = (
            tdef.flatten_up_to(extra) if extra is not None
            else [None] * len(p_leaves)
        )
        pairs = [
            fn(p, s, e, a)
            for p, s, e, a in zip(p_leaves, s_leaves, e_leaves, a_leaves)
        ]
        return (
            tdef.unflatten([p for p, _ in pairs]),
            tdef.unflatten([s for _, s in pairs]),
        )

    def _read_prefix_q_impl(
        self, row: PyTree, pools: PyTree, scales: PyTree, shared_ids: jax.Array
    ) -> PyTree:
        """Quantized twin of ``_read_prefix_impl``: dequantize the shared
        prefix pages while gathering them into the full-precision row."""

        def read(r, pool, sc, ax):
            p = jnp.moveaxis(pool, (ax, ax + 1), (0, 1))
            deq = p[shared_ids].astype(jnp.float32) * self._expand_scale(
                sc[shared_ids]
            )
            pref = deq.reshape((-1,) + p.shape[2:])
            rr = jnp.moveaxis(r, (ax, ax + 1), (0, 1))
            rr = rr.at[0, : pref.shape[0]].set(pref.astype(rr.dtype))
            return jnp.moveaxis(rr, (0, 1), (ax, ax + 1))

        return jax.tree.map(read, row, pools, scales, self._batch_axes)

    def _write_pages_q_impl(
        self,
        pools: PyTree,
        scales: PyTree,
        row: PyTree,
        fresh_ids: jax.Array,
        start_page: int,
        n_valid: jax.Array,
    ) -> tuple[PyTree, PyTree]:
        """Quantized twin of ``_write_pages_impl``: quantize the prefill's
        fresh pages on write.  Rows past ``n_valid`` (the prompt's tail
        inside its final, partially filled page) are masked out of both
        the absmax and the stored bytes, so stale prefill-buffer content
        never reaches the pool."""
        ps = self.page_size
        n = fresh_ids.shape[0]

        def write(pool, sc, r, ax):
            rr = jnp.moveaxis(r, (ax, ax + 1), (0, 1))
            chunk = rr[0, start_page * ps : (start_page + n) * ps]
            chunk = chunk.reshape((n, ps) + rr.shape[2:])
            mask = (jnp.arange(n * ps) < n_valid).reshape(
                (n, ps) + (1,) * (chunk.ndim - 2)
            )
            q, s = absmax_quantize(chunk, (1, chunk.ndim - 1), mask=mask)
            p = jnp.moveaxis(pool, (ax, ax + 1), (0, 1))
            p = p.at[fresh_ids].set(q)
            return (
                jnp.moveaxis(p, (0, 1), (ax, ax + 1)),
                sc.at[fresh_ids].set(s),
            )

        return self._map_pool_scale(write, pools, scales, extra=row)

    def _copy_page_q_impl(
        self, pools: PyTree, scales: PyTree, src: jax.Array, dst: jax.Array
    ) -> tuple[PyTree, PyTree]:
        """CoW for quantized pages: bytes and scales copy verbatim — the
        copy is bit-identical to its source, never a requantization."""

        def cp(pool, sc, _e, ax):
            p = jnp.moveaxis(pool, (ax, ax + 1), (0, 1))
            p = p.at[dst].set(p[src])
            return (
                jnp.moveaxis(p, (0, 1), (ax, ax + 1)),
                sc.at[dst].set(sc[src]),
            )

        return self._map_pool_scale(cp, pools, scales)

    def _paged_decode_q_impl(
        self,
        params: PyTree,
        tokens: jax.Array,
        pools: PyTree,
        scales: PyTree,
        tables: jax.Array,
        positions: jax.Array,
        write_pages: jax.Array,
        write_offsets: jax.Array,
    ) -> tuple[jax.Array, PyTree, PyTree]:
        """Quantized twin of ``_paged_decode_impl``: dequantize at gather,
        decode on the full-precision view, then re-quantize each slot's
        *whole* write page from the updated view (valid rows only — the
        new token and everything before it in that page).  The page scale
        tracks its absmax as tokens land, so earlier rows re-round at most
        once per scale increase: bounded, deterministic drift that the
        end-to-end token-match gate bounds."""
        b = tokens.shape[0]
        ps = self.page_size

        def gather(pool, sc, ax):
            p = jnp.moveaxis(pool, (ax, ax + 1), (0, 1))
            scg = sc[tables]                        # (B, nP, *rest[:-1])
            g = p[tables].astype(jnp.float32) * jnp.expand_dims(
                scg, (2, scg.ndim + 1)
            )                                       # (B, nP, ps, ...)
            g = g.reshape((b, -1) + p.shape[2:])
            return jnp.moveaxis(g, (0, 1), (ax, ax + 1))

        view = jax.tree.map(gather, pools, scales, self._batch_axes)
        logits, view = self._decode_fn(params, tokens, view, positions)

        page_start = positions - write_offsets
        rows = page_start[:, None] + jnp.arange(ps)[None, :]       # (B, ps)
        rows = jnp.clip(rows, 0, self.max_len - 1)
        valid = jnp.arange(ps)[None, :] <= write_offsets[:, None]  # (B, ps)

        def scatter(pool, sc, leaf, ax):
            v = jnp.moveaxis(leaf, (ax, ax + 1), (0, 1))  # (B, S, ...)
            pages = v[jnp.arange(b)[:, None], rows]       # (B, ps, ...)
            mask = valid.reshape((b, ps) + (1,) * (pages.ndim - 2))
            q, s = absmax_quantize(pages, (1, pages.ndim - 1), mask=mask)
            p = jnp.moveaxis(pool, (ax, ax + 1), (0, 1))
            p = p.at[write_pages].set(q)
            return (
                jnp.moveaxis(p, (0, 1), (ax, ax + 1)),
                sc.at[write_pages].set(s),
            )

        pools, scales = self._map_pool_scale(
            scatter, pools, scales, extra=view
        )
        return logits, pools, scales

    # -- public API --------------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    @property
    def slots_busy(self) -> int:
        """Number of decode slots currently occupied."""
        return sum(1 for f in self.slot_free if not f)

    def drain_completions(self) -> list[Completion]:
        """Pop (and return) completions accumulated so far — the streaming
        counterpart to reading ``self.completions`` after
        :meth:`run_to_completion`."""
        out = self.completions
        self.completions = []
        return out

    def _admit(self, req: Request) -> None:
        if self.admission is not None:
            est = len(req.prompt_tokens) + req.max_new_tokens
            self.admission(est)  # blocks until budget available

    def _compute_ctx(self):
        """Placement context for jitted prefill/decode: activation-sharding
        rules on a multi-device replica mesh, default-device pinning for a
        single-device replica, no-op otherwise."""
        if self.rules is not None:
            return use_rules(self.rules)
        if self.device is not None:
            return jax.default_device(self.device)
        return contextlib.nullcontext()

    def _contiguous_prefill(self, slot: int, req: Request) -> int:
        ptoks = req.prompt_tokens
        if len(ptoks) not in self._seen_prefill_shapes:
            self._seen_prefill_shapes.add(len(ptoks))
            self.stats.prefill_recompiles += 1
        batch = {"tokens": jnp.asarray(np.asarray(ptoks, np.int32)[None])}
        if req.extras:
            batch.update(
                {k: jnp.asarray(v)[None] for k, v in req.extras.items()}
            )
        with self._compute_ctx():
            row_cache = init_params(jax.random.key(1), self._row_specs)
            logits, row_cache = self._prefill(self.params, batch, row_cache)
            self.cache = self._insert(self.cache, row_cache, slot)
            return int(
                jax.device_get(
                    steps_lib.greedy_sample(logits, self.cfg.vocab_size)
                )[0]
            )

    def _paged_prefill(self, slot: int, req: Request) -> int:
        """Acquire pages (reusing any resident shared prefix), prefill only
        the suffix, scatter the fresh pages back into the pool, and index
        the prompt's full pages for future sharers."""
        ptoks = req.prompt_tokens
        match = self.manager.acquire(slot, ptoks)
        start = match.n_shared_tokens
        self.stats.prefix_pages_hit += match.n_shared_pages
        self.stats.prefix_tokens_saved += start
        if (start, len(ptoks) - start) not in self._seen_prefill_shapes:
            self._seen_prefill_shapes.add((start, len(ptoks) - start))
            self.stats.prefill_recompiles += 1
        suffix = np.asarray(ptoks[start:], np.int32)[None]
        batch = {"tokens": jnp.asarray(suffix)}
        if req.extras:
            batch.update(
                {k: jnp.asarray(v)[None] for k, v in req.extras.items()}
            )
        with self._compute_ctx():
            row = init_params(jax.random.key(1), self._row_specs)
            if match.n_shared_pages:
                shared = jnp.asarray(
                    match.page_ids[: match.n_shared_pages], jnp.int32
                )
                if self.quantized:
                    row = self._read_prefix_q(
                        row, self.cache, self.scales, shared
                    )
                else:
                    row = self._read_prefix(row, self.cache, shared)
            logits, row = self._prefill(self.params, batch, row, start)
            fresh = jnp.asarray(
                match.page_ids[match.n_shared_pages :], jnp.int32
            )
            if self.quantized:
                self.cache, self.scales = self._write_pages_q(
                    self.cache, self.scales, row, fresh,
                    match.n_shared_pages, len(ptoks) - start,
                )
            else:
                self.cache = self._write_pages(
                    self.cache, row, fresh, match.n_shared_pages
                )
            first_tok = int(
                jax.device_get(
                    steps_lib.greedy_sample(logits, self.cfg.vocab_size)
                )[0]
            )
        self.manager.register(slot, ptoks)
        return first_tok

    def _page_gate(self) -> bool:
        """Low-watermark admission gate: admit the queue head only if the
        pool covers its worst-case prompt-page need while keeping one page
        per busy slot in reserve for decode growth — prefills defer under
        pressure instead of overcommitting pages a decode will then have
        to preempt for.  A prompt larger than the whole pool is admitted
        anyway so ``acquire`` raises a clear error instead of the request
        deferring forever."""
        need = -(-len(self.queue[0].prompt_tokens) // self.page_size)
        if need >= self.manager.n_pages:
            return True
        reserve = sum(1 for f in self.slot_free if not f)
        avail = self.manager.pages_free + self.manager.pages_cached
        return avail >= need + reserve

    def _refill(self) -> None:
        admitted = 0
        for slot in range(self.n_slots):
            if not self.slot_free[slot] or not self.queue:
                continue
            if (
                self.max_prefills_per_step
                and admitted >= self.max_prefills_per_step
            ):
                # each still-queued request that a free slot could have
                # taken this step is deferred exactly once per step it
                # actually waits (not once per queue neighbour)
                free_left = sum(
                    1 for s in range(slot, self.n_slots) if self.slot_free[s]
                )
                self.stats.prefills_deferred += min(len(self.queue), free_left)
                break
            if self.page_size and not self._page_gate():
                free_left = sum(
                    1 for s in range(slot, self.n_slots) if self.slot_free[s]
                )
                self.stats.prefills_deferred += min(len(self.queue), free_left)
                break
            req = self.queue.pop(0)
            self._admit(req)
            ptoks = req.prompt_tokens
            self.stats.admissions += 1
            if self.page_size:
                first_tok = self._paged_prefill(slot, req)
            else:
                first_tok = self._contiguous_prefill(slot, req)
            admitted += 1

            self.slot_free[slot] = False
            self.slot_req[slot] = req
            self.slot_tokens[slot] = [first_tok]
            self.slot_pos[slot] = self.prefix + len(ptoks)
            self.slot_started[slot] = time.monotonic()
            self.cur_tokens[slot, 0] = first_tok

    def _finish(self, slot: int, reason: str) -> None:
        req = self.slot_req[slot]
        assert req is not None
        self.completions.append(
            Completion(
                request_id=req.request_id,
                tokens=list(self.slot_tokens[slot]),
                prompt_len=len(req.prompt_tokens),
                finished_reason=reason,
                latency_s=time.monotonic() - self.slot_started[slot],
            )
        )
        self._release_slot(slot)
        self.stats.completions += 1

    def _release_slot(self, slot: int) -> None:
        """Free a slot and its pages without emitting a completion."""
        self.slot_free[slot] = True
        self.slot_req[slot] = None
        self.slot_tokens[slot] = []
        if self.page_size:
            self.manager.release(slot)

    def _preempt(self, slot: int) -> None:
        """Evict a decoding slot under pool pressure: release its pages
        and requeue its request (same request id, queue front) for a full
        recompute.  Greedy prefill+decode are bitwise reproducible, so the
        preempted request's final output is byte-identical to an
        unpreempted run — preemption costs work, never correctness."""
        req = self.slot_req[slot]
        assert req is not None
        self.stats.preemptions += 1
        self.stats.preempted_tokens += len(self.slot_tokens[slot])
        self.queue.insert(0, req)
        self._release_slot(slot)

    def _preempt_victim(self) -> bool:
        """Pick and preempt the cheapest-to-recompute victim: fewest
        decoded tokens, slot-index tie-break."""
        active = [s for s in range(self.n_slots) if not self.slot_free[s]]
        if not active:
            return False
        victim = min(active, key=lambda s: (len(self.slot_tokens[s]), s))
        self._preempt(victim)
        return True

    def cancel(self, request_id: int) -> bool:
        """Abandon a request without a completion: dequeue it, or free its
        slot and release its pages (the service cancels the losing leg of
        a hedged request this way).  Returns True if found."""
        for i, req in enumerate(self.queue):
            if req.request_id == request_id:
                del self.queue[i]
                return True
        for slot in range(self.n_slots):
            req = self.slot_req[slot]
            if req is not None and req.request_id == request_id:
                self._release_slot(slot)
                return True
        return False

    def _reap(self) -> None:
        """Finish every slot whose latest sample terminated it."""
        for slot in range(self.n_slots):
            if self.slot_free[slot]:
                continue
            toks = self.slot_tokens[slot]
            req = self.slot_req[slot]
            assert req is not None
            if toks and toks[-1] == self.eos_id:
                self._finish(slot, "eos")
            elif len(toks) >= req.max_new_tokens:
                self._finish(slot, "length")

    def _paged_step_tables(
        self, active: list[int]
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-step page tables and write targets; extends/copy-on-writes
        the page holding each active slot's next position."""
        tables = np.zeros((self.n_slots, self.pages_per_slot), np.int32)
        write_pages = np.full((self.n_slots,), self._trash_page, np.int32)
        write_offsets = np.zeros((self.n_slots,), np.int32)
        for slot in active:
            pos = int(self.slot_pos[slot])
            if pos < self.max_len:
                pw = self.manager.ensure_position(slot, pos)
                if pw.cow_src is not None:
                    # defensive: unreachable while sharing stops short of
                    # the final prompt token (see paged_cache docstring)
                    if self.quantized:
                        self.cache, self.scales = self._copy_page_q(
                            self.cache, self.scales, pw.cow_src, pw.page_id
                        )
                    else:
                        self.cache = self._copy_page(
                            self.cache, pw.cow_src, pw.page_id
                        )
                    self.stats.cow_copies += 1
                write_pages[slot] = pw.page_id
                write_offsets[slot] = pw.offset
            table = self.manager.table(slot)
            tables[slot, : len(table)] = table
        return tables, write_pages, write_offsets

    def step(self) -> int:
        """One scheduler iteration; returns number of active slots stepped."""
        if self.fault_hook is not None:
            kind = self.fault_hook(self.steps_run)
            if kind == "page_pressure":
                self._preempt_victim()
            elif kind == "hang":
                return 0  # no admissions, no decode, no progress
        # finish-check *before* refill so a slot freed by the previous
        # iteration's sample is refillable in this very step, then check
        # again for fresh slots whose first token already terminated them
        self._reap()
        self._refill()
        self._reap()
        active = [s for s in range(self.n_slots) if not self.slot_free[s]]
        if not active:
            return 0

        with self._compute_ctx():
            if self.page_size:
                # decode-time pool pressure preempts the cheapest victim
                # and retries instead of killing the replica (DESIGN.md §9);
                # ensure_position is idempotent, so rebuilding the tables
                # after a preemption released pages is safe
                while True:
                    try:
                        tables, wpages, woffs = self._paged_step_tables(active)
                        break
                    except PagePoolExhausted:
                        self._preempt_victim()
                        active = [
                            s for s in range(self.n_slots)
                            if not self.slot_free[s]
                        ]
                        if not active:
                            return 0
            self.stats.steps += 1
            self.stats.active_slot_steps += len(active)
            self.stats.tokens_generated += len(active)
            tokens = jnp.asarray(self.cur_tokens)
            positions = jnp.asarray(self.slot_pos)
            if self.page_size and self.quantized:
                logits, self.cache, self.scales = self._paged_decode_q(
                    self.params, tokens, self.cache, self.scales,
                    jnp.asarray(tables), positions,
                    jnp.asarray(wpages), jnp.asarray(woffs),
                )
            elif self.page_size:
                logits, self.cache = self._paged_decode(
                    self.params, tokens, self.cache, jnp.asarray(tables),
                    positions, jnp.asarray(wpages), jnp.asarray(woffs),
                )
            else:
                logits, self.cache = self._decode(
                    self.params, tokens, self.cache, positions
                )
            if self.temperature > 0:
                self.key, sub = jax.random.split(self.key)
                nxt = steps_lib.temperature_sample(
                    logits, self.cfg.vocab_size, self.temperature, sub
                )
            else:
                nxt = steps_lib.greedy_sample(logits, self.cfg.vocab_size)
            nxt = np.asarray(jax.device_get(nxt))

        for slot in active:
            self.slot_tokens[slot].append(int(nxt[slot]))
            self.slot_pos[slot] += 1
            self.cur_tokens[slot, 0] = int(nxt[slot])
        self.steps_run += 1
        return len(active)

    def run_to_completion(self, max_steps: int = 10_000) -> list[Completion]:
        for _ in range(max_steps):
            busy = any(not f for f in self.slot_free)
            if not busy and not self.queue:
                break
            self.step()
        # flush slots the loop left behind: finished-but-unreported ones
        # emit normally; a slot still mid-generation at max_steps
        # exhaustion emits a "truncated" completion rather than silently
        # dropping the request
        self._reap()
        for slot in range(self.n_slots):
            if not self.slot_free[slot]:
                self._finish(slot, "truncated")
        return self.completions
