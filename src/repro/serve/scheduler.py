"""Continuous-batching scheduler (Orca-style iteration-level batching).

A fixed pool of ``n_slots`` decode slots steps in lock-step (SPMD gang
scheduling — see DESIGN.md §2: Spark's work-stealing does not transfer to a
jitted step, so slots are the unit of multiplexing instead).  Each iteration:

1. free slots are refilled from the request queue (admission-controlled),
2. a single batched decode step advances every active slot by one token,
3. finished slots (EOS / max_tokens) emit their completion and free up.

Refill inserts a B=1 prefilled cache row into the batched cache with
``dynamic_update_slice_in_dim`` along each leaf's batch axis (derived from
the logical ``batch`` axis on the cache ParamSpecs — no per-family special
cases).  Prompts are padded to power-of-two buckets to bound recompiles.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engines import BatcherStats
from repro.models.params import init_params, is_spec
from repro.serve import steps as steps_lib
from repro.sharding import ShardingRules, use_rules

PyTree = Any


@dataclasses.dataclass
class Request:
    request_id: int
    prompt_tokens: list[int]
    max_new_tokens: int = 32
    extras: dict | None = None  # e.g. {"frames": ...} for enc-dec archs


@dataclasses.dataclass
class Completion:
    request_id: int
    tokens: list[int]
    prompt_len: int
    finished_reason: str  # "eos" | "length"
    latency_s: float = 0.0


def _bucket(n: int, minimum: int = 16) -> int:
    b = minimum
    while b < n:
        b *= 2
    return b


def batch_axis_tree(cache_specs: PyTree) -> PyTree:
    """Index of the logical ``batch`` axis for every cache leaf."""
    return jax.tree.map(
        lambda s: s.axes.index("batch"), cache_specs, is_leaf=is_spec
    )


class ContinuousBatcher:
    """Slot-multiplexed decode loop around jitted prefill/decode steps."""

    def __init__(
        self,
        model: Any,
        cfg: ModelConfig,
        params: PyTree,
        *,
        n_slots: int = 8,
        max_len: int = 512,
        eos_id: int = 1,
        temperature: float = 0.0,
        admission: Callable[[int], float] | None = None,
        cache_dtype: Any = jnp.float32,
        max_prefills_per_step: int = 0,
        device: Any = None,
        rules: ShardingRules | None = None,
    ):
        self.model, self.cfg, self.params = model, cfg, params
        self.n_slots, self.max_len, self.eos_id = n_slots, max_len, eos_id
        self.temperature = temperature
        self.admission = admission
        #: 0 = unlimited; otherwise at most this many prompts are prefilled
        #: per step() — prefill/decode disaggregation: a long-prompt backlog
        #: waits for a prefill slot instead of stalling every decode step
        #: behind a wall of back-to-back prefills
        self.max_prefills_per_step = max_prefills_per_step
        #: single-device placement (one replica per host device) or, for a
        #: multi-device replica, logical-axis rules over its mesh — the two
        #: are mutually exclusive
        self.device = device
        self.rules = rules
        self.prefix = cfg.n_vision_tokens if cfg.family == "vlm" else 0

        cache_specs = model.cache_specs(n_slots, max_len, cache_dtype)
        self._batch_axes = batch_axis_tree(cache_specs)
        self.cache = init_params(jax.random.key(0), cache_specs)
        if rules is not None:
            self.params = jax.device_put(
                self.params, rules.param_shardings(model.param_specs())
            )
            self.cache = jax.device_put(
                self.cache, rules.param_shardings(cache_specs)
            )
        elif device is not None:
            self.params = jax.device_put(self.params, device)
            self.cache = jax.device_put(self.cache, device)
        row_specs = model.cache_specs(1, max_len, cache_dtype)
        self._row_specs = row_specs

        self._decode = jax.jit(steps_lib.make_decode_fn(model, cfg))
        self._prefill = jax.jit(
            lambda params, batch, cache: model.prefill(params, batch, cache)
        )
        self._insert = jax.jit(self._insert_impl)

        # slot state (host side)
        self.slot_free = [True] * n_slots
        self.slot_req: list[Request | None] = [None] * n_slots
        self.slot_tokens: list[list[int]] = [[] for _ in range(n_slots)]
        self.slot_pos = np.zeros((n_slots,), np.int32)  # next position to write
        self.slot_started = np.zeros((n_slots,), np.float64)
        self.cur_tokens = np.zeros((n_slots, 1), np.int32)
        self.queue: list[Request] = []
        self.completions: list[Completion] = []
        self.steps_run = 0
        self.key = jax.random.key(0)
        #: occupancy/throughput counters for the persistent streaming mode
        #: (surfaced through the InferenceService into session accounting)
        self.stats = BatcherStats(n_slots=n_slots)
        self._seen_prompt_lens: set[int] = set()

    # -- cache row insertion ---------------------------------------------------

    def _insert_impl(self, cache: PyTree, row: PyTree, slot: jax.Array) -> PyTree:
        return jax.tree.map(
            lambda full, r, ax: jax.lax.dynamic_update_slice_in_dim(
                full, r.astype(full.dtype), slot, axis=ax
            ),
            cache,
            row,
            self._batch_axes,
        )

    # -- public API --------------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    @property
    def slots_busy(self) -> int:
        """Number of decode slots currently occupied."""
        return sum(1 for f in self.slot_free if not f)

    def drain_completions(self) -> list[Completion]:
        """Pop (and return) completions accumulated so far — the streaming
        counterpart to reading ``self.completions`` after
        :meth:`run_to_completion`."""
        out = self.completions
        self.completions = []
        return out

    def _admit(self, req: Request) -> None:
        if self.admission is not None:
            est = len(req.prompt_tokens) + req.max_new_tokens
            self.admission(est)  # blocks until budget available

    def _compute_ctx(self):
        """Placement context for jitted prefill/decode: activation-sharding
        rules on a multi-device replica mesh, default-device pinning for a
        single-device replica, no-op otherwise."""
        if self.rules is not None:
            return use_rules(self.rules)
        if self.device is not None:
            return jax.default_device(self.device)
        return contextlib.nullcontext()

    def _refill(self) -> None:
        admitted = 0
        for slot in range(self.n_slots):
            if not self.slot_free[slot] or not self.queue:
                continue
            if (
                self.max_prefills_per_step
                and admitted >= self.max_prefills_per_step
            ):
                self.stats.prefills_deferred += len(self.queue)
                break
            req = self.queue.pop(0)
            self._admit(req)
            ptoks = req.prompt_tokens
            self.stats.admissions += 1
            if len(ptoks) not in self._seen_prompt_lens:
                # exact-length prefill: each new prompt length compiles a
                # fresh prefill program (callers bucket lengths to bound it)
                self._seen_prompt_lens.add(len(ptoks))
                self.stats.prefill_recompiles += 1
            # Exact-length prefill: bucketed (right-padded) prefill would be
            # fine for attention caches (padding is never attended) but
            # corrupts SSM recurrent state, so prompts are prefetched at their
            # true length; callers bound recompiles by bucketing prompt
            # lengths at the data layer.
            batch = {"tokens": jnp.asarray(np.asarray(ptoks, np.int32)[None])}
            if req.extras:
                batch.update(
                    {k: jnp.asarray(v)[None] for k, v in req.extras.items()}
                )
            with self._compute_ctx():
                row_cache = init_params(jax.random.key(1), self._row_specs)
                logits, row_cache = self._prefill(self.params, batch, row_cache)
                self.cache = self._insert(self.cache, row_cache, slot)
                first_tok = int(
                    jax.device_get(
                        steps_lib.greedy_sample(logits, self.cfg.vocab_size)
                    )[0]
                )
            admitted += 1

            self.slot_free[slot] = False
            self.slot_req[slot] = req
            self.slot_tokens[slot] = [first_tok]
            self.slot_pos[slot] = self.prefix + len(ptoks)
            self.slot_started[slot] = time.monotonic()
            self.cur_tokens[slot, 0] = first_tok

    def _finish(self, slot: int, reason: str) -> None:
        req = self.slot_req[slot]
        assert req is not None
        self.completions.append(
            Completion(
                request_id=req.request_id,
                tokens=list(self.slot_tokens[slot]),
                prompt_len=len(req.prompt_tokens),
                finished_reason=reason,
                latency_s=time.monotonic() - self.slot_started[slot],
            )
        )
        self.slot_free[slot] = True
        self.slot_req[slot] = None
        self.slot_tokens[slot] = []
        self.stats.completions += 1

    def step(self) -> int:
        """One scheduler iteration; returns number of active slots stepped."""
        self._refill()
        active = [s for s in range(self.n_slots) if not self.slot_free[s]]
        if not active:
            return 0

        # check EOS/length finishes from the previous iteration's samples
        for slot in list(active):
            toks = self.slot_tokens[slot]
            req = self.slot_req[slot]
            assert req is not None
            if toks and toks[-1] == self.eos_id:
                self._finish(slot, "eos")
            elif len(toks) >= req.max_new_tokens:
                self._finish(slot, "length")
        active = [s for s in range(self.n_slots) if not self.slot_free[s]]
        if not active:
            return 0

        self.stats.steps += 1
        self.stats.active_slot_steps += len(active)
        self.stats.tokens_generated += len(active)
        with self._compute_ctx():
            tokens = jnp.asarray(self.cur_tokens)
            positions = jnp.asarray(self.slot_pos)
            logits, self.cache = self._decode(
                self.params, tokens, self.cache, positions
            )
            if self.temperature > 0:
                self.key, sub = jax.random.split(self.key)
                nxt = steps_lib.temperature_sample(
                    logits, self.cfg.vocab_size, self.temperature, sub
                )
            else:
                nxt = steps_lib.greedy_sample(logits, self.cfg.vocab_size)
            nxt = np.asarray(jax.device_get(nxt))

        for slot in active:
            self.slot_tokens[slot].append(int(nxt[slot]))
            self.slot_pos[slot] += 1
            self.cur_tokens[slot, 0] = int(nxt[slot])
        self.steps_run += 1
        return len(active)

    def run_to_completion(self, max_steps: int = 10_000) -> list[Completion]:
        for _ in range(max_steps):
            busy = any(not f for f in self.slot_free)
            if not busy and not self.queue:
                break
            self.step()
        # flush any finished-but-unreported slots
        for slot in range(self.n_slots):
            if not self.slot_free[slot]:
                toks = self.slot_tokens[slot]
                req = self.slot_req[slot]
                if toks and (
                    toks[-1] == self.eos_id or len(toks) >= req.max_new_tokens
                ):
                    self._finish(slot, "eos" if toks[-1] == self.eos_id else "length")
        return self.completions
