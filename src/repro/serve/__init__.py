from repro.serve.scheduler import Completion, ContinuousBatcher, Request
from repro.serve.steps import (
    greedy_sample,
    make_decode_fn,
    make_prefill_fn,
    make_serve_step,
    temperature_sample,
)

__all__ = [
    "Completion",
    "ContinuousBatcher",
    "Request",
    "greedy_sample",
    "make_decode_fn",
    "make_prefill_fn",
    "make_serve_step",
    "temperature_sample",
]
