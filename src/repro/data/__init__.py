from repro.data.datasets import (
    instruction_examples,
    iter_chunks,
    iter_instruction_examples,
    iter_mixed_examples,
    iter_qa_examples,
    iter_summarization_examples,
    mixed_examples,
    qa_examples,
    rag_examples,
    summarization_examples,
    token_stream,
)
from repro.data.templates import render, render_all
from repro.data.tokenizer import HashTokenizer

__all__ = [
    "HashTokenizer",
    "instruction_examples",
    "iter_chunks",
    "iter_instruction_examples",
    "iter_mixed_examples",
    "iter_qa_examples",
    "iter_summarization_examples",
    "mixed_examples",
    "qa_examples",
    "rag_examples",
    "render",
    "render_all",
    "summarization_examples",
    "token_stream",
]
