from repro.data.datasets import (
    instruction_examples,
    mixed_examples,
    qa_examples,
    rag_examples,
    summarization_examples,
    token_stream,
)
from repro.data.templates import render, render_all
from repro.data.tokenizer import HashTokenizer

__all__ = [
    "HashTokenizer",
    "instruction_examples",
    "mixed_examples",
    "qa_examples",
    "rag_examples",
    "render",
    "render_all",
    "summarization_examples",
    "token_stream",
]
