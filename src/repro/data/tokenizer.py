"""Deterministic hash tokenizer (no external vocab files; DESIGN.md §4.1).

Word-level feature hashing into the architecture's exact vocab size, so
embedding/unembedding *cost* is faithful to the assigned configs.  Decoding
uses a process-local inverse memory (hash tokenizers are not invertible in
general); round-trips hold for any word the process has encoded — which is
all the evaluation pipeline needs.
"""

from __future__ import annotations

import hashlib
import re

_WORD_RE = re.compile(r"\w+|[^\w\s]")

PAD_ID, EOS_ID, BOS_ID, UNK_ID = 0, 1, 2, 3
N_SPECIAL = 4


class HashTokenizer:
    def __init__(self, vocab_size: int):
        assert vocab_size > N_SPECIAL + 1
        self.vocab_size = vocab_size
        self.pad_id, self.eos_id, self.bos_id, self.unk_id = (
            PAD_ID, EOS_ID, BOS_ID, UNK_ID,
        )
        self._inverse: dict[int, str] = {}

    def token_id(self, word: str) -> int:
        h = int.from_bytes(
            hashlib.md5(word.encode()).digest()[:8], "little"
        )
        tid = N_SPECIAL + h % (self.vocab_size - N_SPECIAL)
        self._inverse.setdefault(tid, word)
        return tid

    def encode(self, text: str, *, add_bos: bool = True) -> list[int]:
        ids = [self.token_id(w) for w in _WORD_RE.findall(text)]
        return ([self.bos_id] + ids) if add_bos else ids

    def decode(self, ids: list[int]) -> str:
        words = []
        for t in ids:
            if t == self.eos_id:
                break
            if t < N_SPECIAL:
                continue
            words.append(self._inverse.get(int(t), f"<{int(t)}>"))
        return " ".join(words)
