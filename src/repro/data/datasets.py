"""Synthetic evaluation datasets (paper §5.1: factual QA, summarization,
instruction-following) and a token-stream source for the training examples.

Everything is deterministic in the seed: benchmarks and the caching
workflow need identical prompts across runs to observe cache hits.

Two access styles per dataset:

* list builders (``qa_examples`` …) — materialize ``n`` rows, the classic
  in-memory path;
* streaming iterators (``iter_qa_examples`` …) — yield the *same* rows
  one at a time, O(1) memory, for the chunked execution path.  Feed them
  through :func:`iter_chunks` to get fixed-size example chunks.
"""

from __future__ import annotations

import itertools
import random
from typing import Iterable, Iterator

_TOPICS = [
    "gravity", "photosynthesis", "volcanoes", "enzymes", "galaxies",
    "antibodies", "semiconductors", "glaciers", "neurons", "polymers",
    "currents", "isotopes", "ecosystems", "algorithms", "satellites",
]
_FACTS = [
    "was discovered in {year}", "operates through {n} distinct phases",
    "depends critically on temperature", "transfers energy between systems",
    "exhibits periodic behavior", "varies across {n} orders of magnitude",
]
_INSTR = [
    "Summarize the role of {topic} in two sentences.",
    "List {n} key properties of {topic}.",
    "Explain {topic} to a ten year old.",
    "Compare {topic} with {topic2} and highlight one difference.",
    "Write a short quiz question about {topic}.",
]


# -- streaming iterators ------------------------------------------------------


def iter_chunks(rows: Iterable[dict], chunk_size: int) -> Iterator[list[dict]]:
    """Yield fixed-size chunks from any example iterable (last may be short).

    This is the unit of work for the streaming pipeline: only one chunk of
    examples is ever resident, regardless of dataset size.
    """
    if chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    it = iter(rows)
    while chunk := list(itertools.islice(it, chunk_size)):
        yield chunk


def iter_qa_examples(n: int, seed: int = 0) -> Iterator[dict]:
    rng = random.Random(seed)
    for i in range(n):
        topic = rng.choice(_TOPICS)
        fact = rng.choice(_FACTS).format(year=1800 + rng.randint(0, 220),
                                         n=rng.randint(2, 9))
        yield {
            "id": f"qa-{seed}-{i}",
            "question": f"What is known about {topic} (case {i})?",
            "reference": f"{topic} {fact}",
            "domain": "qa",
        }


def iter_summarization_examples(n: int, seed: int = 0) -> Iterator[dict]:
    rng = random.Random(seed + 1)
    for i in range(n):
        topic = rng.choice(_TOPICS)
        sents = [
            f"{topic} "
            + rng.choice(_FACTS).format(year=1900 + rng.randint(0, 120),
                                        n=rng.randint(2, 9))
            + "."
            for _ in range(rng.randint(4, 8))
        ]
        doc = " ".join(sents)
        yield {
            "id": f"sum-{seed}-{i}",
            "question": f"Summarize: {doc}",
            "reference": sents[0],
            "domain": "summarization",
        }


def iter_instruction_examples(n: int, seed: int = 0) -> Iterator[dict]:
    rng = random.Random(seed + 2)
    for i in range(n):
        topic, topic2 = rng.sample(_TOPICS, 2)
        instr = rng.choice(_INSTR).format(topic=topic, topic2=topic2,
                                          n=rng.randint(2, 5))
        yield {
            "id": f"instr-{seed}-{i}",
            "question": instr,
            "reference": f"A helpful response about {topic}.",
            "domain": "instruction",
        }


def iter_mixed_examples(n: int, seed: int = 0) -> Iterator[dict]:
    """Streaming multi-domain mix: deterministic weighted interleave of the
    three domain streams, O(1) memory.

    Note: the interleave order differs from :func:`mixed_examples` (which
    shuffles the materialized list — impossible without O(n) memory); the
    example *set* per domain is identical.
    """
    per = n // 3
    streams = [
        iter_qa_examples(per, seed),
        iter_summarization_examples(per, seed),
        iter_instruction_examples(n - 2 * per, seed),
    ]
    remaining = [per, per, n - 2 * per]
    rng = random.Random(seed + 3)
    while any(remaining):
        total = sum(remaining)
        pick = rng.randrange(total)
        for d in range(3):
            if pick < remaining[d]:
                remaining[d] -= 1
                yield next(streams[d])
                break
            pick -= remaining[d]


# -- list builders ------------------------------------------------------------


def qa_examples(n: int, seed: int = 0) -> list[dict]:
    return list(iter_qa_examples(n, seed))


def summarization_examples(n: int, seed: int = 0) -> list[dict]:
    return list(iter_summarization_examples(n, seed))


def instruction_examples(n: int, seed: int = 0) -> list[dict]:
    return list(iter_instruction_examples(n, seed))


def mixed_examples(n: int, seed: int = 0) -> list[dict]:
    """The paper's multi-domain evaluation mix (§5.1)."""
    per = n // 3
    out = (
        qa_examples(per, seed)
        + summarization_examples(per, seed)
        + instruction_examples(n - 2 * per, seed)
    )
    rng = random.Random(seed + 3)
    rng.shuffle(out)
    return out


def rag_examples(n: int, seed: int = 0) -> list[dict]:
    """QA with retrieved-context chunks for the RAG metric family."""
    rng = random.Random(seed + 4)
    out = []
    for i, ex in enumerate(qa_examples(n, seed)):
        relevant = ex["reference"]
        distractors = [
            f"{rng.choice(_TOPICS)} {rng.choice(_FACTS).format(year=1950, n=3)}"
            for _ in range(2)
        ]
        chunks = distractors[:1] + [relevant] + distractors[1:]
        ex.update(
            {
                "id": f"rag-{seed}-{i}",
                "contexts": chunks,
                "relevant_index": 1,
                "domain": "rag",
            }
        )
        out.append(ex)
    return out


def token_stream(
    tokenizer, seq_len: int, batch: int, seed: int = 0
) -> Iterator[dict]:
    """Deterministic LM training batches: tokens + next-token labels."""
    import numpy as np

    rng = random.Random(seed)
    while True:
        rows = []
        for _ in range(batch):
            text = " ".join(
                f"{rng.choice(_TOPICS)} {rng.choice(_FACTS).format(year=2000, n=4)}"
                for _ in range(seq_len // 6 + 2)
            )
            ids = tokenizer.encode(text)[:seq_len]
            ids = ids + [tokenizer.pad_id] * (seq_len - len(ids))
            rows.append(ids)
        tokens = np.asarray(rows, np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], np.full((batch, 1), -1, np.int32)], axis=1
        )
        labels = np.where(tokens[:, :] == tokenizer.pad_id, -1, labels)
        yield {"tokens": tokens, "labels": labels}
