"""Prompt templating (stage 1 of the runner: prompt preparation).

The paper uses Jinja2; offline we support the ``{column}`` subset via
``str.format_map`` with strict missing-key errors — enough for every paper
workflow, zero dependencies.
"""

from __future__ import annotations

from typing import Mapping


class _Strict(dict):
    def __missing__(self, key: str) -> str:
        raise KeyError(
            f"prompt template references missing column {key!r}"
        )


def render(template: str, row: Mapping) -> str:
    return template.format_map(_Strict(row))


def render_all(template: str, rows: list[Mapping]) -> list[str]:
    return [render(template, r) for r in rows]
