"""Render the §Dry-run / §Roofline tables from experiments/dryrun/*.json.

  PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load_records(d: str) -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def fmt_bytes(b: float) -> str:
    return f"{b/1e9:.2f}GB"


def dryrun_table(records: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | compile | peak HBM/dev | params | notes |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in records:
        fits = "✓ fits" if r["memory"]["peak_bytes_est"] < 16e9 else "✗ >16GB"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['timing']['compile_s']:.0f}s "
            f"| {fmt_bytes(r['memory']['peak_bytes_est'])} "
            f"| {r['meta']['params']/1e9:.1f}B | {fits} |"
        )
    return "\n".join(lines)


def roofline_table(records: list[dict]) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| bound | MODEL_FLOPS | useful | MFU-bound |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if r["mesh"] != "16x16":
            continue
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {rl['compute_s']:.3f} | {rl['memory_s']:.3f} "
            f"| {rl['collective_s']:.3f} | **{rl['dominant']}** "
            f"| {rl['bound_s']*1e3:.1f}ms | {rl['model_flops']:.2e} "
            f"| {rl['useful_ratio']:.2f} | {rl['mfu_bound']:.3f} |"
        )
    return "\n".join(lines)


def worst_cells(records: list[dict], k: int = 5) -> list[tuple]:
    single = [r for r in records if r["mesh"] == "16x16"]
    ranked = sorted(single, key=lambda r: r["roofline"]["mfu_bound"])
    out = []
    for r in ranked[:k]:
        out.append(
            (r["arch"], r["shape"], r["roofline"]["dominant"],
             round(r["roofline"]["mfu_bound"], 4))
        )
    return out


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default="experiments/dryrun")
    args = p.parse_args()
    records = load_records(args.dir)
    print(f"## Dry-run ({len(records)} cells)\n")
    print(dryrun_table(records))
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(records))
    print("\n## Worst cells (hillclimb candidates)\n")
    for row in worst_cells(records):
        print("  ", row)


if __name__ == "__main__":
    main()
