import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb: hypothesis -> change -> measure -> verdict on the three
selected cells (see EXPERIMENTS.md §Perf for the full log):

  1. qwen2.5-32b x prefill_32k   — most collective-bound cell
  2. deepseek-v2-236b x decode_32k — worst memory cell (96 GB/dev, unfit)
  3. paligemma-3b x prefill_32k  — worst useful-compute ratio

Each iteration lowers a real variant (sharding-rule table / storage dtype)
and re-derives the roofline terms with the unrolled accounting pass.  The
"kernelized attention" iteration swaps the measured jnp-path attention HBM
traffic (quadratic coefficient of the bytes fit) for the Pallas flash
kernel's analytic traffic — the kernel exists (repro/kernels) but Mosaic
cannot lower on CPU, so its memory behaviour enters analytically.
"""

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config
from repro.launch.accounting import account_cell
from repro.launch.cells import build_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline
from repro.models.model import active_param_count, build_model

PREFILL_PTS = (2048, 4096, 6144)


def memory_pass(arch, shape, mesh, **cell_kw):
    cell = build_cell(arch, shape, mesh, **cell_kw)
    with mesh:
        compiled = (
            jax.jit(
                cell.fn,
                in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=cell.donate,
            )
            .lower(*cell.args)
            .compile()
        )
        m = compiled.memory_analysis()
    return (
        m.argument_size_in_bytes + m.output_size_in_bytes + m.temp_size_in_bytes
        - m.alias_size_in_bytes
    )


def flash_ratio(cfg, block_q: int = 512) -> float:
    """analytic quadratic-bytes ratio: flash kernel vs jnp chunked path.

    jnp path writes the (bq x bk) f32 score block + ~3 elementwise copies
    per (q-head, block pair): ~16 B/elem x H.  The flash kernel's only
    quadratic HBM traffic is re-reading K,V (bf16) once per q block:
    4 x Kv x dh / bq bytes per (row, position^2)."""
    jnp_quad = 16.0 * cfg.n_heads
    kv = cfg.n_kv_heads if not cfg.use_mla else 1
    dh = cfg.head_dim if not cfg.use_mla else (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
    flash_quad = 4.0 * kv * dh / block_q
    return flash_quad / jnp_quad


_ACCT_CACHE: dict = {}
_PEAK_CACHE: dict = {}


def run_iteration(
    name, hypothesis, arch, shape, mesh, *, kernelized=False, fit_points=None,
    **cell_kw,
):
    cfg = get_config(arch)
    scfg = SHAPES[shape]
    print(f"\n--- {name} ---")
    print(f"hypothesis: {hypothesis}")
    key = (arch, shape, fit_points, tuple(sorted(map(str, cell_kw.items()))))
    acct = _ACCT_CACHE.get(key)
    if acct is None:
        acct = account_cell(
            arch, shape, mesh,
            fit_points=fit_points
            or (PREFILL_PTS if scfg.kind == "prefill" else None),
            **cell_kw,
        )
        _ACCT_CACHE[key] = acct
    bytes_dev = acct.bytes_per_device
    kern_note = ""
    if kernelized and len(acct.fit_points) >= 3:
        xs = [p["seq_len"] for p in acct.fit_points]
        ys = [p["bytes"] for p in acct.fit_points]
        a, b, c = np.polyfit(xs, ys, 2)[::-1]
        ratio = flash_ratio(cfg)
        s = scfg.seq_len
        bytes_dev = max(a + b * s + c * ratio * s * s, 0.0)
        kern_note = (
            f" [kernelized: quad coeff x{ratio:.4f} "
            f"(jnp {c:.3e} -> flash {c*ratio:.3e})]"
        )
    peak = _PEAK_CACHE.get(key)
    if peak is None:
        peak = memory_pass(arch, shape, mesh, **cell_kw)
        _PEAK_CACHE[key] = peak
    active = active_param_count(cfg, build_model(cfg).param_specs())
    rl = roofline(
        cfg=cfg, scfg=scfg, chips=mesh.size,
        hlo_flops_per_device=acct.flops_per_device,
        hlo_bytes_per_device=bytes_dev,
        wire_bytes_per_device=acct.wire_bytes_per_device,
        active_params=active,
    )
    rec = {
        "iteration": name, "hypothesis": hypothesis, "arch": arch,
        "shape": shape, "kernelized": kernelized,
        "cell_kw": {k: str(v) for k, v in cell_kw.items()},
        "peak_bytes": peak,
        "flops_per_device": acct.flops_per_device,
        "bytes_per_device": bytes_dev,
        "wire_per_device": acct.wire_bytes_per_device,
        "roofline": {
            "compute_s": rl.compute_s, "memory_s": rl.memory_s,
            "collective_s": rl.collective_s, "dominant": rl.dominant,
            "bound_s": rl.bound_s, "mfu_bound": rl.mfu_bound,
        },
        "fit_points": acct.fit_points,
    }
    print(
        f"measured: peak={peak/1e9:.1f}GB/dev  compute={rl.compute_s:.3f}s  "
        f"memory={rl.memory_s:.3f}s{kern_note}  collective={rl.collective_s:.3f}s"
    )
    print(
        f"  -> dominant={rl.dominant}  bound={rl.bound_s*1e3:.1f}ms  "
        f"mfu_bound={rl.mfu_bound:.3f}"
    )
    return rec


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--cell", choices=["qwen25", "deepseek", "pali", "all"],
                   default="all")
    p.add_argument("--out", default="experiments/perf")
    args = p.parse_args()
    os.makedirs(args.out, exist_ok=True)
    mesh = make_production_mesh()
    records = []

    if args.cell in ("deepseek", "all"):
        arch, shape = "deepseek-v2-236b", "decode_32k"
        records.append(run_iteration(
            "deepseek-decode/0-baseline",
            "serve rules shard expert weights only over model(16): 472GB bf16 "
            "/16 = ~30GB experts/dev -> memory term and HBM blow up",
            arch, shape, mesh,
        ))
        records.append(run_iteration(
            "deepseek-decode/1-ep2d",
            "2D expert sharding (expert x d_model over model x data) cuts "
            "expert bytes 16x; dispatch contraction adds only a tiny "
            "partial-sum all-reduce (napkin: E_loc x B x C x ff bytes/step)",
            arch, shape, mesh, rules_variant="serve_ep2d",
        ))
        records.append(run_iteration(
            "deepseek-decode/2-ep2d+int8",
            "int8 weight+cache storage halves remaining HBM reads (cache "
            "bytes charge the serving pool's per-page f32 absmax scales too "
            "-- <1% overhead, repro.serve kv_page_bytes); decode is pure "
            "memory-bound so the bound should halve again",
            arch, shape, mesh, rules_variant="serve_ep2d",
            weights_dtype=jnp.int8, cache_dtype=jnp.int8,
        ))

    if args.cell in ("qwen25", "all"):
        arch, shape = "qwen2.5-32b", "prefill_32k"
        records.append(run_iteration(
            "qwen25-prefill/0-baseline",
            "TP-16 prefill pays 2 all-reduces of full activations per layer: "
            "napkin 2 x 2 x (2x32768x5120x2B) x 64L x 15/16 = ~160GB/dev wire",
            arch, shape, mesh,
        ))
        records.append(run_iteration(
            "qwen25-prefill/1-kernelized",
            "flash kernel removes score-matrix HBM traffic (quadratic bytes "
            "coeff drops ~80x analytically); collective stays dominant",
            arch, shape, mesh, kernelized=True,
        ))
        records.append(run_iteration(
            "qwen25-prefill/2-context-parallel",
            "shard activations (batch x seq) over (data x model), fully shard "
            "weight storage and let XLA gather weights per layer: wire becomes "
            "~one weight gather (65GB bf16) + KV gathers (~9GB) instead of "
            "160GB of activation all-reduces",
            arch, shape, mesh, kernelized=True, rules_variant="prefill_cp",
        ))

    if args.cell in ("pali", "all"):
        arch, shape = "paligemma-3b", "prefill_32k"
        records.append(run_iteration(
            "pali-prefill/0-baseline",
            "re-account with larger fit points (2k/4k/6k): the old 512-1536 "
            "quadratic fit extrapolated x445 and amplified XLA fusion noise",
            arch, shape, mesh,
        ))
        records.append(run_iteration(
            "pali-prefill/1-kernelized",
            "MQA kv=1: flash quadratic traffic is 4x1x256/512 = 2B/elem vs "
            "jnp 16x8=128B/elem -> memory term drops ~64x on the attention "
            "share; compute should become dominant",
            arch, shape, mesh, kernelized=True,
        ))

    with open(os.path.join(args.out, f"hillclimb_{args.cell}.json"), "w") as f:
        json.dump(records, f, indent=1)
    print(f"\nwrote {len(records)} iteration records")


if __name__ == "__main__":
    main()
