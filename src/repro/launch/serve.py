"""Serving driver: continuous-batching generation for any assigned arch.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \\
      --requests 12 --slots 4 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.core.ratelimit import TokenBucket
from repro.data import HashTokenizer, qa_examples
from repro.models import params as pm
from repro.models.model import build_model
from repro.serve import ContinuousBatcher, Request


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-4b")
    p.add_argument("--reduced", action="store_true", default=True)
    p.add_argument("--requests", type=int, default=12)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--max-len", type=int, default=128)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--admission-tpm", type=float, default=0.0,
                   help=">0 enables token-bucket admission control")
    args = p.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, remat="none")
    params = pm.init_params(jax.random.key(0), model.param_specs())
    tok = HashTokenizer(cfg.vocab_size)

    admission = None
    if args.admission_tpm > 0:
        bucket = TokenBucket(1e9, args.admission_tpm, 1)
        admission = bucket.acquire

    sched = ContinuousBatcher(
        model, cfg, params,
        n_slots=args.slots, max_len=args.max_len,
        eos_id=tok.eos_id, temperature=args.temperature, admission=admission,
    )
    rows = qa_examples(args.requests, seed=0)
    t0 = time.time()
    for i, row in enumerate(rows):
        toks = tok.encode(row["question"])[: args.max_len // 2]
        sched.submit(Request(i, prompt_tokens=toks, max_new_tokens=args.max_new))
    done = sched.run_to_completion()
    dt = time.time() - t0
    total_new = sum(len(c.tokens) for c in done)
    for c in sorted(done, key=lambda c: c.request_id)[:5]:
        print(f"req {c.request_id}: {len(c.tokens)} tokens ({c.finished_reason}) "
              f"-> {tok.decode(c.tokens)[:60]!r}")
    print(
        f"\n{len(done)} completions, {total_new} new tokens in {dt:.2f}s "
        f"({total_new/dt:.1f} tok/s, {sched.steps_run} scheduler iterations, "
        f"{args.slots} slots)"
    )


if __name__ == "__main__":
    main()
