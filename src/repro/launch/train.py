"""Training driver: train any assigned arch (reduced or full) end-to-end.

CPU-scale example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \\
      --steps 50 --batch 8 --seq 64

On a pod the same driver runs the full config under the production mesh
(sharding comes from the TRAIN_RULES table; data parallel over pod x data).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.ckpt import CheckpointManager
from repro.configs import get_config
from repro.data import HashTokenizer, token_stream
from repro.models import params as pm
from repro.models.model import build_model
from repro.train import OptimizerConfig, TrainConfig, init_opt_state, make_train_step


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-4b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-every", type=int, default=20)
    p.add_argument("--log-every", type=int, default=5)
    p.add_argument("--resume", action="store_true")
    args = p.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, remat="none" if args.reduced else "full")
    tok = HashTokenizer(cfg.vocab_size)

    tcfg = TrainConfig(
        optimizer=OptimizerConfig(
            learning_rate=args.lr, warmup_steps=max(2, args.steps // 20),
            total_steps=args.steps,
        ),
        microbatches=args.microbatches,
        compute_dtype=jnp.float32 if args.reduced else jnp.bfloat16,
    )
    step_fn = jax.jit(make_train_step(model, cfg, tcfg))

    params = pm.init_params(jax.random.key(0), model.param_specs())
    opt = init_opt_state(params)
    start = 0
    mgr = (
        CheckpointManager(args.ckpt_dir, every=args.ckpt_every)
        if args.ckpt_dir
        else None
    )
    if mgr and args.resume:
        from repro.ckpt import latest_step, restore_checkpoint

        last = latest_step(args.ckpt_dir)
        if last is not None:
            (params, opt), _ = restore_checkpoint(
                args.ckpt_dir, last, template=(params, opt)
            )
            start = last
            print(f"resumed from step {start}")

    stream = token_stream(tok, args.seq, args.batch, seed=0)
    n_params = pm.param_count(model.param_specs())
    print(f"training {cfg.name}: {n_params:,} params, {args.steps} steps")
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if (step + 1) % args.log_every == 0 or step == start:
            toks_s = args.batch * args.seq * (step + 1 - start) / (time.time() - t0)
            print(
                f"step {step+1:5d}  loss={float(metrics['loss']):.4f}  "
                f"nll={float(metrics['nll']):.4f}  "
                f"gnorm={float(metrics['grad_norm']):.3f}  "
                f"lr={float(metrics['lr']):.2e}  tok/s={toks_s:.0f}"
            )
        if mgr:
            mgr.maybe_save(step + 1, (params, opt))
    print(f"done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
