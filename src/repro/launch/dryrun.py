import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and record memory / cost / collective analysis.

The two lines above MUST stay first: jax locks the device count at first
init, and the dry-run needs 512 placeholder CPU devices to build the
2x16x16 production mesh.  Never set this in conftest.py — tests and
benchmarks see the real single device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --all-shapes
  PYTHONPATH=src python -m repro.launch.dryrun --all          # full 32-cell grid
Add --multi-pod for the 512-chip mesh (default: single-pod 16x16).
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, applicable_shapes, get_config
from repro.launch import hlo_analysis
from repro.launch.accounting import account_cell
from repro.launch.cells import all_cells, build_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline
from repro.models.model import active_param_count, build_model


def run_cell(
    arch: str,
    shape: str,
    *,
    multi_pod: bool = False,
    out_dir: str = "experiments/dryrun",
    microbatches: int | None = None,
    remat: str = "full",
    save_hlo: bool = False,
    tag: str = "",
    skip_accounting: bool = False,
) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    cell = build_cell(
        arch, shape, mesh, microbatches=microbatches, remat=remat
    )

    # --- memory pass: real scanned config -> compile proof + memory stats ---
    with mesh:
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate,
        )
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = hlo_analysis.normalize_cost_analysis(compiled.cost_analysis())
        hlo_text = compiled.as_text()

    coll = hlo_analysis.collective_stats(hlo_text, chips)
    cfg = get_config(arch)
    scfg = SHAPES[shape]
    active = active_param_count(cfg, build_model(cfg).param_specs())

    # --- accounting pass: unrolled reduced fit (loop-accurate) --------------
    # The roofline table is single-pod only (assignment): multi-pod runs are
    # compile-success + memory proofs, so they skip the accounting lowerings.
    if multi_pod:
        skip_accounting = True
    if skip_accounting:
        hlo_flops = float(cost.get("flops", 0.0))
        hlo_bytes = float(cost.get("bytes accessed", 0.0))
        wire = coll.wire_bytes
        acct_points = None
    else:
        acct = account_cell(arch, shape, mesh, remat=remat)
        hlo_flops = acct.flops_per_device
        hlo_bytes = acct.bytes_per_device
        wire = acct.wire_bytes_per_device
        acct_points = acct.fit_points

    rl = roofline(
        cfg=cfg,
        scfg=scfg,
        chips=chips,
        hlo_flops_per_device=hlo_flops,
        hlo_bytes_per_device=hlo_bytes,
        wire_bytes_per_device=wire,
        active_params=active,
    )

    record = {
        "arch": arch,
        "shape": shape,
        "kind": cell.kind,
        "mesh": mesh_name,
        "chips": chips,
        "ok": True,
        "meta": cell.meta,
        "timing": {"lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2)},
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_est": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "cost": {
            "flops_per_device": hlo_flops,
            "bytes_per_device": hlo_bytes,
            "structural_flops": float(cost.get("flops", 0.0)),
            "structural_bytes": float(cost.get("bytes accessed", 0.0)),
            "accounting_fit": acct_points,
        },
        "collectives": {
            "wire_bytes_per_device": wire,
            "structural_wire_bytes": coll.wire_bytes,
            "by_op": coll.by_op,
        },
        "roofline": {
            "compute_s": rl.compute_s,
            "memory_s": rl.memory_s,
            "collective_s": rl.collective_s,
            "dominant": rl.dominant,
            "bound_s": rl.bound_s,
            "model_flops": rl.model_flops,
            "useful_ratio": rl.useful_ratio,
            "mfu_bound": rl.mfu_bound,
            "active_params": active,
        },
    }

    os.makedirs(out_dir, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(out_dir, f"{arch}_{shape}_{mesh_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    if save_hlo:
        with open(path.replace(".json", ".hlo.txt"), "w") as f:
            f.write(hlo_text)

    hbm = record["memory"]["peak_bytes_est"] / 1e9
    print(
        f"[dryrun] {arch} x {shape} x {mesh_name}: OK  "
        f"compile={t_compile:.1f}s  peak≈{hbm:.2f}GB/dev  "
        f"flops/dev={hlo_flops:.3e}  wire/dev={wire:.3e}B  "
        f"dominant={rl.dominant}  bound={rl.bound_s*1e3:.2f}ms  "
        f"mfu_bound={rl.mfu_bound:.3f}"
    )
    print(f"  memory_analysis: {mem}")
    interesting = {
        k: v for k, v in cost.items() if k in ("flops", "bytes accessed")
    }
    print(f"  cost_analysis: {interesting}")
    return record


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", choices=sorted(ARCHS), default=None)
    p.add_argument("--shape", choices=sorted(SHAPES), default=None)
    p.add_argument("--all-shapes", action="store_true")
    p.add_argument("--all", action="store_true", help="full 32-cell grid")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--out-dir", default="experiments/dryrun")
    p.add_argument("--microbatches", type=int, default=None)
    p.add_argument("--remat", default="full", choices=["none", "full", "dots"])
    p.add_argument("--save-hlo", action="store_true")
    p.add_argument("--tag", default="")
    p.add_argument(
        "--skip-accounting",
        action="store_true",
        help="memory/compile pass only (no unrolled FLOP-fit lowerings)",
    )
    args = p.parse_args()

    if args.all:
        grid = all_cells()
    elif args.arch and args.all_shapes:
        grid = [(args.arch, s) for s in applicable_shapes(get_config(args.arch))]
    elif args.arch and args.shape:
        grid = [(args.arch, args.shape)]
    else:
        p.error("need --arch/--shape, --arch/--all-shapes, or --all")

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    failures = []
    for arch, shape in grid:
        for mp in meshes:
            try:
                run_cell(
                    arch,
                    shape,
                    multi_pod=mp,
                    out_dir=args.out_dir,
                    microbatches=args.microbatches,
                    remat=args.remat,
                    save_hlo=args.save_hlo,
                    tag=args.tag,
                    skip_accounting=args.skip_accounting,
                )
            except Exception as e:  # noqa: BLE001 — record and continue
                failures.append((arch, shape, mp, repr(e)))
                print(f"[dryrun] {arch} x {shape} multi_pod={mp}: FAIL {e}")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: {failures}")


if __name__ == "__main__":
    main()
