"""FLOP / byte / collective accounting via unrolled, reduced lowerings.

``compiled.cost_analysis()`` counts a while-loop body once regardless of
trip count (verified empirically; see ``repro.models.unroll``), so a scanned
model structurally under-reports compute and collective traffic.  The
accounting pass therefore lowers the SAME cell with every scan fully
unrolled — but unrolling an 80-layer model over a 32k-token attention block
grid would explode compile time, so two exact reductions are applied and
extrapolated:

* **Depth**: per-step cost is exactly linear in the repeated-layer count
  (layers for dense/MoE/SSM, shared-attention groups for the hybrid,
  enc+dec layer pairs for whisper).  Measure at two small depths, fit the
  line, evaluate at the true depth.
* **Sequence**: per-step cost is a polynomial of degree <= 2 in S (matmuls
  and embeddings linear; attention block grids and MoE dispatch — capacity
  proportional to S — quadratic; decode steps degree <= 1 in context).
  Measure at 2-3 reduced S points, fit, evaluate at the true S.  Fit points
  are multiples of 512 so MoE capacity rounding stays exactly linear.

Both reductions are exact-by-construction (polynomial interpolation of a
polynomial), not approximations.  Train cells use ``microbatches=1``:
FLOPs / bytes / wire are microbatch-invariant (same tokens, same collective
set); memory realism comes from the separate memory pass in ``dryrun.py``.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import hlo_analysis
from repro.launch.cells import build_cell
from repro.models.unroll import unroll_scans

PREFILL_FIT_POINTS = (2048, 4096, 8192)
METRICS = ("flops", "bytes", "wire")


@dataclasses.dataclass
class Accounting:
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    fit_points: list[dict]
    fit_seconds: float


def depth_variants(cfg: ModelConfig) -> tuple[ModelConfig, int, ModelConfig, int, int]:
    """(small_cfg, n_small, large_cfg, n_large, n_true) — n is the linear
    depth variable for this family."""
    if cfg.family == "hybrid":
        k = cfg.shared_attn_every
        g_true = cfg.n_layers // k
        tail = cfg.n_layers - g_true * k
        # g=1 compiles to a different SPMD strategy; 2 vs 3 is stabler
        return (
            cfg.replace(n_layers=2 * k + tail), 2,
            cfg.replace(n_layers=3 * k + tail), 3,
            g_true,
        )
    if cfg.family == "encdec":
        return (
            cfg.replace(n_layers=2, n_encoder_layers=2), 2,
            cfg.replace(n_layers=4, n_encoder_layers=4), 4,
            cfg.n_layers,
        )
    if cfg.n_experts and cfg.first_k_dense:
        fk = cfg.first_k_dense
        return (
            cfg.replace(n_layers=fk + 2), 2,
            cfg.replace(n_layers=fk + 4), 4,
            cfg.n_layers - fk,
        )
    return (
        cfg.replace(n_layers=2), 2,
        cfg.replace(n_layers=4), 4,
        cfg.n_layers,
    )


def _measure(
    arch: str, shape: str, mesh, scfg: ShapeConfig, cfg: ModelConfig, remat: str,
    **cell_kw,
) -> dict:
    cell = build_cell(
        arch, shape, mesh, scfg=scfg, cfg=cfg, microbatches=1, remat=remat,
        **cell_kw,
    )
    t0 = time.time()
    with mesh:
        with unroll_scans():
            lowered = jax.jit(
                cell.fn,
                in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
            ).lower(*cell.args)
        compiled = lowered.compile()
        cost = hlo_analysis.normalize_cost_analysis(compiled.cost_analysis())
        coll = hlo_analysis.collective_stats(compiled.as_text(), mesh.size)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire": coll.wire_bytes,
        "compile_s": round(time.time() - t0, 1),
    }


def measure_point(
    arch: str, shape: str, mesh, seq_len: int, *, remat: str = "full", **cell_kw
) -> dict:
    """Full-depth value at one S via two reduced-depth lowerings."""
    scfg0 = SHAPES[shape]
    scfg = ShapeConfig(scfg0.name, seq_len, scfg0.global_batch, scfg0.kind)
    cfg = get_config(arch)
    c_small, n_small, c_large, n_large, n_true = depth_variants(cfg)
    small = _measure(arch, shape, mesh, scfg, c_small, remat, **cell_kw)
    large = _measure(arch, shape, mesh, scfg, c_large, remat, **cell_kw)
    out = {"seq_len": seq_len, "depth_points": [small, large],
           "depths": [n_small, n_large, n_true]}
    for m in METRICS:
        slope = (large[m] - small[m]) / (n_large - n_small)
        if slope < 0:
            # compiler non-monotonicity at tiny depth (e.g. different SPMD
            # strategy at g=1): fall back to proportional scaling from the
            # larger, more representative depth
            out[m] = large[m] * n_true / max(n_large, 1)
        else:
            out[m] = small[m] + slope * (n_true - n_small)
    out["compile_s"] = small["compile_s"] + large["compile_s"]
    return out


def _fit_eval(xs, ys, deg: int, x_true: int) -> float:
    coeffs = np.polyfit(np.asarray(xs, np.float64), np.asarray(ys, np.float64), deg)
    return max(float(np.polyval(coeffs, x_true)), 0.0)


def account_cell(
    arch: str,
    shape: str,
    mesh,
    *,
    remat: str = "full",
    fit_points: tuple[int, ...] | None = None,
    **cell_kw,
) -> Accounting:
    scfg = SHAPES[shape]
    if scfg.kind == "decode":
        # decode has no attention-block loops: the unrolled lowering is
        # cheap even at the true context length -> measure directly
        pts, deg = fit_points or (scfg.seq_len,), 1
    elif scfg.kind == "train":
        # train_4k is 8x8 attention blocks per layer at reduced depth ->
        # also affordable directly (zero extrapolation error)
        pts, deg = fit_points or (scfg.seq_len,), 2
    else:
        pts, deg = fit_points or PREFILL_FIT_POINTS, 2
    pts = tuple(p for p in pts if p <= scfg.seq_len) or (scfg.seq_len,)
    if scfg.seq_len <= max(pts):
        pts = (scfg.seq_len,)

    t0 = time.time()
    samples = [
        measure_point(arch, shape, mesh, s, remat=remat, **cell_kw) for s in pts
    ]
    if len(samples) == 1:
        vals = {m: samples[0][m] for m in METRICS}
    else:
        xs = [s["seq_len"] for s in samples]
        d = min(deg, len(xs) - 1)
        vals = {
            m: _fit_eval(xs, [s[m] for s in samples], d, scfg.seq_len)
            for m in METRICS
        }
    return Accounting(
        flops_per_device=vals["flops"],
        bytes_per_device=vals["bytes"],
        wire_bytes_per_device=vals["wire"],
        fit_points=samples,
        fit_seconds=round(time.time() - t0, 1),
    )
