"""Roofline model for the TPU v5e target.

Three terms, all in seconds-per-step, derived from the compiled dry-run
artifact (per-device numbers — ``cost_analysis()`` reports the SPMD-
partitioned module of one participant):

  compute    = HLO_FLOPs / peak_FLOPs
  memory     = HLO_bytes / HBM_bw
  collective = wire_bytes / ICI_bw

The step cannot run faster than ``max`` of the three (no overlap) and the
*dominant* term is the optimization target for §Perf.  MODEL_FLOPS is the
napkin 6·N·D (train) / 2·N·D (inference) estimate with an explicit attention
term; ``MODEL_FLOPS / (HLO_FLOPs * chips)`` measures how much compiled
compute is useful (catching remat/dispatch waste).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeConfig

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link; DESIGN assumption: one link
                             # is the bottleneck direction per collective


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float
    #: max(three terms) — the roofline-optimal step time lower bound
    bound_s: float
    #: MODEL_FLOPS / (chips * PEAK * bound) — "roofline MFU" of the step
    mfu_bound: float


def model_flops(cfg: ModelConfig, scfg: ShapeConfig, active_params: int) -> float:
    """Napkin useful-FLOPs per step: matmul params + attention."""
    if scfg.kind == "train":
        tokens = scfg.tokens
        base = 6.0 * active_params * tokens
        attn_mult = 3.0  # fwd + 2x bwd
    elif scfg.kind == "prefill":
        tokens = scfg.tokens
        base = 2.0 * active_params * tokens
        attn_mult = 1.0
    else:  # decode: one token per sequence
        tokens = scfg.global_batch
        base = 2.0 * active_params * tokens
        attn_mult = 1.0

    # attention scores+values: 4 * S_ctx * width per token per layer
    if cfg.n_heads and cfg.family != "ssm":
        if cfg.use_mla:
            width = cfg.n_heads * (
                cfg.qk_nope_head_dim + cfg.qk_rope_head_dim + cfg.v_head_dim
            ) / 2
        else:
            width = cfg.n_heads * cfg.head_dim
        ctx = scfg.seq_len
        causal = 0.5 if scfg.kind != "decode" else 1.0
        n_attn_layers = cfg.n_layers
        if cfg.family == "hybrid":
            n_attn_layers = cfg.n_layers // max(cfg.shared_attn_every, 1)
        attn = 4.0 * ctx * width * causal * tokens * n_attn_layers * attn_mult
        base += attn
    if cfg.family == "ssm":
        # SSD: chunk-quadratic + state updates ~ 6 * d_inner * N per token
        base += (
            (6.0 if scfg.kind == "train" else 2.0)
            * 2.0 * cfg.d_inner * cfg.ssm_state
            * (scfg.tokens if scfg.kind != "decode" else scfg.global_batch)
            * cfg.n_layers
        )
    return base


def roofline(
    *,
    cfg: ModelConfig,
    scfg: ShapeConfig,
    chips: int,
    hlo_flops_per_device: float,
    hlo_bytes_per_device: float,
    wire_bytes_per_device: float,
    active_params: int,
) -> Roofline:
    compute_s = hlo_flops_per_device / PEAK_FLOPS
    memory_s = hlo_bytes_per_device / HBM_BW
    collective_s = wire_bytes_per_device / ICI_BW
    terms = {
        "compute": compute_s,
        "memory": memory_s,
        "collective": collective_s,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, scfg, active_params)
    hlo_total = hlo_flops_per_device * chips
    bound = max(terms.values())
    return Roofline(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=mf,
        hlo_flops_total=hlo_total,
        useful_ratio=mf / hlo_total if hlo_total else 0.0,
        bound_s=bound,
        mfu_bound=mf / (chips * PEAK_FLOPS * bound) if bound else 0.0,
    )
