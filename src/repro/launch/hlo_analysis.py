"""Collective-traffic extraction from compiled HLO text.

``cost_analysis()`` has no collective-bytes entry, so we parse the
post-optimization module: every ``all-gather`` / ``all-reduce`` /
``reduce-scatter`` / ``all-to-all`` / ``collective-permute`` op contributes
per-participant *wire bytes* under the standard ring-algorithm accounting:

  all-reduce       2 * bytes * (g-1)/g      (reduce-scatter + all-gather)
  all-gather       out_bytes * (g-1)/g
  reduce-scatter   in_bytes  * (g-1)/g  = out_bytes * (g-1)
  all-to-all       bytes * (g-1)/g
  collective-permute  bytes                  (point-to-point)

where ``g`` is the replica-group size parsed from ``replica_groups=[G,S]<=``
(iota form) or ``{{...}}`` (explicit form).  Shapes are parsed from the op's
result type; for all-reduce / all-to-all the result bytes equal the input
bytes, for all-gather the result is the gathered buffer, for reduce-scatter
the result is the scattered shard.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict


def normalize_cost_analysis(cost) -> dict:
    """``compiled.cost_analysis()`` returns a dict on current jax but a
    one-element list of dicts on jax < 0.5 — normalize to a dict."""
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost or {})

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

# result can be a plain shape or a tuple of shapes
_OP_RE = re.compile(
    r"=\s+(?P<result>\([^)]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclasses.dataclass
class CollectiveStats:
    #: per-participant wire bytes, summed over all collective ops
    wire_bytes: float
    #: raw buffer bytes moved through collectives (no ring scaling)
    buffer_bytes: float
    #: op-type -> (count, wire_bytes)
    by_op: dict


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _EXPLICIT_GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


def collective_stats(hlo_text: str, n_devices: int) -> CollectiveStats:
    wire = 0.0
    buf = 0.0
    by_op: dict[str, list[float]] = defaultdict(lambda: [0, 0.0])
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        op = m.group("op")
        result_bytes = _shape_bytes(m.group("result"))
        g = _group_size(line, n_devices)
        if g <= 1:
            continue
        if op == "all-reduce":
            w = 2.0 * result_bytes * (g - 1) / g
        elif op == "all-gather":
            w = result_bytes * (g - 1) / g
        elif op == "reduce-scatter":
            w = result_bytes * (g - 1)
        elif op == "all-to-all":
            w = result_bytes * (g - 1) / g
        else:  # collective-permute
            w = float(result_bytes)
        wire += w
        buf += result_bytes
        by_op[op][0] += 1
        by_op[op][1] += w
    return CollectiveStats(
        wire_bytes=wire,
        buffer_bytes=buf,
        by_op={k: tuple(v) for k, v in by_op.items()},
    )


_REMAT_NAME_RE = re.compile(r"%(fusion|[a-z-]+)\.?(\d*)")


def count_ops(hlo_text: str, opcode: str) -> int:
    return len(re.findall(rf"\b{re.escape(opcode)}\(", hlo_text))
