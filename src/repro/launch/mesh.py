"""Production mesh factories.

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked at first jax init, and smoke tests
must see 1 CPU device while the dry-run sees 512 placeholders).
"""

from __future__ import annotations

from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh

try:  # jax >= 0.5 exposes explicit/auto axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto
    AxisType = None


def _axis_type_kwargs(n_axes: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over the real local devices (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh(
        (data, model), ("data", "model"), **_axis_type_kwargs(2)
    )


def replica_device_groups(
    n_replicas: int, devices: Sequence | None = None
) -> list[tuple]:
    """Partition the visible devices into ``n_replicas`` data-parallel
    groups (one serving replica per group).

    Devices split contiguously and as evenly as possible; with more
    replicas than devices the assignment wraps, so oversubscribed hosts
    (every CPU test topology) still get one distinct group per replica
    rather than an error.
    """
    if n_replicas < 1:
        raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
    devs = list(devices) if devices is not None else jax.devices()
    n = len(devs)
    if n_replicas >= n:
        return [(devs[i % n],) for i in range(n_replicas)]
    per, extra = divmod(n, n_replicas)
    groups, start = [], 0
    for i in range(n_replicas):
        size = per + (1 if i < extra else 0)
        groups.append(tuple(devs[start : start + size]))
        start += size
    return groups


def make_replica_mesh(devices: Sequence, *, data: int = 1) -> Mesh:
    """("data", "model") mesh over ONE replica's device group: tensor
    parallelism inside the replica, data parallelism across replicas
    handled above the mesh by the service's :class:`ReplicaRouter`."""
    devs = np.asarray(list(devices), dtype=object)
    if data < 1 or len(devs) % data:
        raise ValueError(
            f"data={data} does not divide {len(devs)} replica devices"
        )
    return Mesh(devs.reshape(data, len(devs) // data), ("data", "model"))
