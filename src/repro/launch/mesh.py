"""Production mesh factories.

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked at first jax init, and smoke tests
must see 1 CPU device while the dry-run sees 512 placeholders).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5 exposes explicit/auto axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto
    AxisType = None


def _axis_type_kwargs(n_axes: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over the real local devices (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh(
        (data, model), ("data", "model"), **_axis_type_kwargs(2)
    )
