"""Dry-run cell builders: (architecture x input-shape x mesh) -> lowerable fn.

``input_specs`` produces weak-type-correct ``ShapeDtypeStruct`` stand-ins for
every model input — nothing is ever allocated; a 236B-parameter cell lowers
on a laptop.  ``build_cell`` assembles the jit-able step function plus its
in/out shardings from the logical-axis rule tables.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import sharding as sh
from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import params as pm
from repro.models.model import build_model
from repro.serve.steps import make_serve_step
from repro.train import (
    AdamWState,
    OptimizerConfig,
    TrainConfig,
    make_train_step,
)

PyTree = Any


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str  # train | prefill | decode
    fn: Callable
    args: tuple  # ShapeDtypeStruct trees
    in_shardings: tuple
    out_shardings: Any
    meta: dict
    donate: tuple = ()  # argnums whose buffers alias outputs (params/opt/cache)


def _batch_spec(rules: sh.ShardingRules, shape: tuple[int, ...]) -> NamedSharding:
    spec = rules.spec_for_axes(("batch",) + (None,) * (len(shape) - 1), shape)
    return NamedSharding(rules.mesh, spec)


def _replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


#: serving-default page size used to translate a dense int8 cache into the
#: paged pool's byte accounting (repro.serve.paged_cache.kv_page_bytes)
_QUANT_PAGE = 16


def _cache_meta_bytes(cache_specs: PyTree, cache_dtype: Any) -> int:
    """Cache HBM bytes for the cell meta, reconciled with the serving pool.

    ``pm.param_bytes`` counts stored elements only.  An ``int8`` cache in
    the real serving stack additionally stores one f32 absmax scale per
    (16-position page, head row) group — the grouping
    :func:`repro.serve.paged_cache.kv_page_bytes` charges the byte-budgeted
    pool for — so the analytical serve cells report the same bytes as the
    batcher instead of an optimistic payload-only count."""
    total = pm.param_bytes(cache_specs)
    if cache_dtype is not None and np.dtype(cache_dtype) == np.int8:
        for leaf in jax.tree.leaves(cache_specs):
            rows = 1
            for dim in leaf.shape[:-1]:  # every axis but the head row
                rows *= dim
            total += -(-rows // _QUANT_PAGE) * 4
    return total


def input_specs(
    arch: str, shape: str, scfg: ShapeConfig | None = None
) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the model inputs of one cell."""
    cfg = get_config(arch)
    scfg = scfg or SHAPES[shape]
    b, s = scfg.global_batch, scfg.seq_len
    out: dict[str, jax.ShapeDtypeStruct] = {}
    if scfg.kind == "train" or scfg.kind == "prefill":
        s_text = s - cfg.n_vision_tokens if cfg.family == "vlm" else s
        out["tokens"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
        if scfg.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
    else:  # decode
        out["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        out["positions"] = jax.ShapeDtypeStruct((b,), jnp.int32)
    if cfg.family == "encdec" and scfg.kind != "decode":
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16
        )
    if cfg.family == "vlm" and scfg.kind != "decode":
        out["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16
        )
    return out


def default_microbatches(cfg: ModelConfig, scfg: ShapeConfig, mesh: Mesh) -> int:
    """Pick grad-accum microbatches so a microbatch is ~1 sequence/device."""
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    m = max(1, scfg.global_batch // dp)
    while scfg.global_batch % m:
        m -= 1
    return m


def build_cell(
    arch: str,
    shape: str,
    mesh: Mesh,
    *,
    scfg: ShapeConfig | None = None,
    cfg: ModelConfig | None = None,
    microbatches: int | None = None,
    remat: str = "full",
    attn_impl: str = "chunked",
    rules_variant: str = "",          # "" = default per kind; or "prefill_cp"
    weights_dtype: Any = None,        # e.g. jnp.int8 storage (serve variants)
    cache_dtype: Any = None,
) -> Cell:
    cfg = cfg or get_config(arch)
    scfg = scfg or SHAPES[shape]
    if scfg.name == "long_500k" and not cfg.is_subquadratic:
        raise ValueError(
            f"{arch} is pure full-attention; long_500k is skipped (DESIGN.md §4.2)"
        )
    if scfg.kind == "train":
        return _build_train_cell(cfg, scfg, mesh, microbatches, remat, attn_impl)
    if scfg.kind == "prefill":
        return _build_prefill_cell(
            cfg, scfg, mesh, attn_impl,
            rules_variant=rules_variant, weights_dtype=weights_dtype,
            cache_dtype=cache_dtype,
        )
    return _build_decode_cell(
        cfg, scfg, mesh, rules_variant=rules_variant,
        weights_dtype=weights_dtype, cache_dtype=cache_dtype,
    )


# ---------------------------------------------------------------------------


def _build_train_cell(cfg, scfg, mesh, microbatches, remat, attn_impl) -> Cell:
    rules = sh.ShardingRules(sh.TRAIN_RULES, mesh)
    model = build_model(cfg, remat=remat, attn_impl=attn_impl) \
        if cfg.family != "ssm" else build_model(cfg, remat=remat)
    specs = model.param_specs()
    m = microbatches or default_microbatches(cfg, scfg, mesh)
    tcfg = TrainConfig(
        optimizer=OptimizerConfig(), microbatches=m, compute_dtype=jnp.bfloat16
    )
    raw_step = make_train_step(model, cfg, tcfg)

    def train_step(params, opt_state, batch):
        with sh.use_rules(rules):
            return raw_step(params, opt_state, batch)

    param_structs = pm.shape_structs(specs)
    param_sh = rules.param_shardings(specs)
    mu_structs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), param_structs
    )
    opt_structs = AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32), mu=mu_structs, nu=mu_structs
    )
    opt_sh = AdamWState(step=_replicated(mesh), mu=param_sh, nu=param_sh)

    inputs = input_specs(cfg.name, scfg.name, scfg)
    batch_sh = {k: _batch_spec(rules, v.shape) for k, v in inputs.items()}

    return Cell(
        arch=cfg.name,
        shape=scfg.name,
        kind="train",
        fn=train_step,
        args=(param_structs, opt_structs, inputs),
        in_shardings=(param_sh, opt_sh, batch_sh),
        out_shardings=(param_sh, opt_sh, None),
        donate=(0, 1),
        meta={
            "microbatches": m,
            "remat": remat,
            "params": pm.param_count(specs),
            "param_bytes": pm.param_bytes(specs),
            "tokens_per_step": scfg.tokens
            - (cfg.n_vision_tokens * scfg.global_batch if cfg.family == "vlm" else 0),
        },
    )


def _build_prefill_cell(
    cfg, scfg, mesh, attn_impl, *, rules_variant="", weights_dtype=None,
    cache_dtype=None,
) -> Cell:
    table = sh.RULE_TABLES.get(rules_variant or "serve", sh.SERVE_RULES)
    rules = sh.ShardingRules(table, mesh)
    model = build_model(cfg, remat="none", attn_impl=attn_impl) \
        if cfg.family != "ssm" else build_model(cfg, remat="none")
    specs = pm.cast_specs(model.param_specs(), weights_dtype or jnp.bfloat16)
    if cache_dtype is not None:
        cache_specs = model.cache_specs(scfg.global_batch, scfg.seq_len, cache_dtype)
    else:
        cache_specs = model.cache_specs(scfg.global_batch, scfg.seq_len)

    def prefill_step(params, batch, cache):
        with sh.use_rules(rules):
            return model.prefill(params, batch, cache)

    inputs = input_specs(cfg.name, scfg.name, scfg)
    batch_sh = {k: _batch_spec(rules, v.shape) for k, v in inputs.items()}
    cache_sh = rules.param_shardings(cache_specs)

    return Cell(
        arch=cfg.name,
        shape=scfg.name,
        kind="prefill",
        fn=prefill_step,
        args=(pm.shape_structs(specs), inputs, pm.shape_structs(cache_specs)),
        in_shardings=(rules.param_shardings(specs), batch_sh, cache_sh),
        out_shardings=(None, cache_sh),
        donate=(2,),
        meta={
            "params": pm.param_count(specs),
            "param_bytes": pm.param_bytes(specs),
            "cache_bytes": _cache_meta_bytes(cache_specs, cache_dtype),
            "tokens_per_step": scfg.tokens
            - (cfg.n_vision_tokens * scfg.global_batch if cfg.family == "vlm" else 0),
        },
    )


def _build_decode_cell(
    cfg, scfg, mesh, *, rules_variant="", weights_dtype=None, cache_dtype=None
) -> Cell:
    table = sh.RULE_TABLES.get(rules_variant or "serve", sh.SERVE_RULES)
    dispatch = "weight_stationary" if rules_variant == "serve_ep2d" else "token"
    rules = sh.ShardingRules(table, mesh, moe_dispatch=dispatch)
    model = build_model(cfg, remat="none")
    specs = pm.cast_specs(model.param_specs(), weights_dtype or jnp.bfloat16)
    if cache_dtype is not None:
        cache_specs = model.cache_specs(scfg.global_batch, scfg.seq_len, cache_dtype)
    else:
        cache_specs = model.cache_specs(scfg.global_batch, scfg.seq_len)
    raw_step = make_serve_step(model, cfg)

    def serve_step(params, cache, tokens, positions):
        with sh.use_rules(rules):
            return raw_step(params, cache, tokens, positions)

    inputs = input_specs(cfg.name, scfg.name, scfg)
    tok_sh = _batch_spec(rules, inputs["tokens"].shape)
    pos_sh = _batch_spec(rules, inputs["positions"].shape)
    cache_sh = rules.param_shardings(cache_specs)

    return Cell(
        arch=cfg.name,
        shape=scfg.name,
        kind="decode",
        fn=serve_step,
        args=(
            pm.shape_structs(specs),
            pm.shape_structs(cache_specs),
            inputs["tokens"],
            inputs["positions"],
        ),
        in_shardings=(rules.param_shardings(specs), cache_sh, tok_sh, pos_sh),
        out_shardings=(None, cache_sh),
        donate=(1,),
        meta={
            "params": pm.param_count(specs),
            "param_bytes": pm.param_bytes(specs),
            "cache_bytes": _cache_meta_bytes(cache_specs, cache_dtype),
            "tokens_per_step": scfg.global_batch,
        },
    )


def all_cells() -> list[tuple[str, str]]:
    """The assigned (arch x shape) grid (32 cells; DESIGN.md §4.2)."""
    from repro.configs import ARCHS, applicable_shapes

    out = []
    for arch, cfg in ARCHS.items():
        for shape in applicable_shapes(cfg):
            out.append((arch, shape))
    return out
