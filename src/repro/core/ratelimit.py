"""Token-bucket rate limiting — the paper's Algorithm 1, verbatim.

Two buckets per worker: requests-per-minute and tokens-per-minute, each
refilled continuously at ``limit/60`` per second.  The global limit is split
evenly across ``n_workers`` (per-executor rate limiting); §6.1 of the paper
notes this is suboptimal under skew — :class:`AdaptiveLimiter` implements
the adaptive redistribution the paper lists as future work: every window,
unused budget is re-granted proportionally to observed demand.

For the local JAX engine the same mechanism is *admission control*: the
"token" budget becomes the KV-residency/step quota of the continuous
batching scheduler (DESIGN.md §2).

Since the shared :class:`~repro.core.service.InferenceService`, acquisition
happens centrally in the service dispatchers (immediately before the engine
call) rather than in per-worker pipeline threads — the limiter objects are
unchanged, but the ``worker`` index is now the dispatcher index, so budget
redistribution follows actual dispatch demand.

The clock is injectable so tests run deterministically without sleeping.
"""

from __future__ import annotations

import threading
import time
from typing import Callable


class TokenBucket:
    """Algorithm 1: Acquire(estimated_tokens) blocks until budget allows."""

    def __init__(
        self,
        rpm_limit: float,
        tpm_limit: float,
        n_workers: int = 1,
        *,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
    ):
        self.r = rpm_limit / n_workers          # per-worker request limit
        self.t = tpm_limit / n_workers          # per-worker token limit
        self.request_tokens = self.r
        self.token_tokens = self.t
        self.clock = clock
        self.sleep = sleep
        self.last_update = clock()
        self.total_wait = 0.0
        self.acquires = 0
        self._lock = threading.Lock()

    def _refill(self) -> None:
        now = self.clock()
        elapsed = now - self.last_update
        self.request_tokens = min(self.r, self.request_tokens + elapsed * self.r / 60.0)
        self.token_tokens = min(self.t, self.token_tokens + elapsed * self.t / 60.0)
        self.last_update = now

    def acquire(self, estimated_tokens: float = 0.0) -> float:
        """Blocks until one request + ``estimated_tokens`` fit; returns wait s."""
        with self._lock:
            self._refill()
            wait = 0.0
            if self.request_tokens < 1.0:
                wait = max(wait, (1.0 - self.request_tokens) * 60.0 / self.r)
            if self.token_tokens < estimated_tokens:
                wait = max(
                    wait, (estimated_tokens - self.token_tokens) * 60.0 / self.t
                )
            if wait > 0:
                self.sleep(wait)
                self.total_wait += wait
                self._refill()
            self.request_tokens -= 1.0
            self.token_tokens -= estimated_tokens
            self.acquires += 1
            return wait


class AdaptiveLimiter:
    """Global-limit coordinator with windowed budget redistribution.

    Workers draw from per-worker buckets; every ``window`` seconds the
    coordinator reassigns each worker's share of the global RPM/TPM
    proportionally to its demand (acquires) in the last window, with a
    floor so idle workers can restart.  This removes the §6.1 skew
    inefficiency of static even splits.
    """

    def __init__(
        self,
        rpm_limit: float,
        tpm_limit: float,
        n_workers: int,
        *,
        window: float = 5.0,
        floor: float = 0.1,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
    ):
        self.rpm, self.tpm, self.n = rpm_limit, tpm_limit, n_workers
        self.window, self.floor = window, floor
        self.clock = clock
        self.buckets = [
            TokenBucket(rpm_limit, tpm_limit, n_workers, clock=clock, sleep=sleep)
            for _ in range(n_workers)
        ]
        self._last_counts = [0] * n_workers
        self._last_rebalance = clock()
        self._lock = threading.Lock()

    def acquire(self, worker: int, estimated_tokens: float = 0.0) -> float:
        self._maybe_rebalance()
        return self.buckets[worker].acquire(estimated_tokens)

    def shares(self) -> list[float]:
        """Fraction of the global budget currently granted to each worker
        (sums to 1 — the rebalance weights are a convex combination of the
        even split and the demand distribution)."""
        return [b.r / self.rpm for b in self.buckets]

    def _maybe_rebalance(self) -> None:
        with self._lock:
            now = self.clock()
            if now - self._last_rebalance < self.window:
                return
            demand = [
                b.acquires - last
                for b, last in zip(self.buckets, self._last_counts)
            ]
            total = sum(demand)
            if total > 0:
                weights = [
                    self.floor / self.n + (1 - self.floor) * d / total
                    for d in demand
                ]
                for b, w in zip(self.buckets, weights):
                    # non-blocking: a worker mid-acquire may be *sleeping*
                    # with its bucket lock held, and we hold the limiter
                    # lock that every acquire passes through — blocking
                    # here would stall all workers for the sleep duration.
                    # A busy bucket keeps its old grant until the next
                    # window (bounded, self-repairing overshoot).
                    if b._lock.acquire(blocking=False):
                        try:
                            b.r = self.rpm * w
                            b.t = self.tpm * w
                        finally:
                            b._lock.release()
            self._last_counts = [b.acquires for b in self.buckets]
            self._last_rebalance = now
