"""StreamingPipeline: bounded-memory chunked evaluation with resumable spill.

The default pipeline materializes prompts, responses and per-example scores
for the whole dataset — O(dataset) memory, which contradicts the paper's
"hundreds of thousands or millions of samples" claim.  This pipeline runs
prepare→infer→score per chunk (reusing the exact same stage objects, so
the sharded worker pool, caching, rate limiting and retries all apply
within a chunk), folds each chunk's scores into mergeable streaming
accumulators (:mod:`repro.stats.streaming`), and discards the chunk —
peak per-example state is one chunk, independent of dataset size.

With a ``spill_dir``, every completed chunk commits its partial state to a
:class:`~repro.storage.spill.ChunkManifest` (one DeltaLite commit per
chunk).  A restarted run replays the manifest: committed chunks are
skipped — their accumulator states merged instead of recomputed — and the
final metrics are bit-identical to an uninterrupted run, because the
Poisson-bootstrap weights are keyed by (seed, chunk offset), not by
processing order.

The aggregate CIs come from :func:`repro.stats.streaming.streaming_ci`:
exact analytical intervals from the moments, or the Poisson-bootstrap
percentile interval (Monte-Carlo-equivalent to the in-memory multinomial
bootstrap) for the bootstrap methods.
"""

from __future__ import annotations

import hashlib
import json
import time
from typing import Any, Iterable

from repro.core.config import EvalTask
from repro.core.stages import (
    EvalArtifact,
    EvalResult,
    InferStage,
    MetricValue,
    PrepareStage,
    ScoreStage,
)
from repro.data.datasets import iter_chunks
from repro.metrics.registry import BINARY_METRICS, resolve_metrics
from repro.stats.streaming import (
    MetricAccumulator,
    PoissonBootstrap,
    streaming_ci,
)
from repro.storage.spill import ChunkManifest

#: failures kept in the result (full per-example lists defeat O(chunk) memory)
MAX_FAILURE_SAMPLE = 100


class ManifestMismatch(RuntimeError):
    """Manifest row disagrees with the observed chunk layout — the data
    source differs from the run that wrote the manifest."""


class StreamingPipeline:
    def __init__(
        self,
        *,
        chunk_size: int = 1024,
        spill_dir: str = "",
        resume: bool = True,
    ):
        self.chunk_size = chunk_size
        self.spill_dir = spill_dir
        self.resume = resume

    @classmethod
    def from_task(cls, task: EvalTask) -> "StreamingPipeline":
        s = task.streaming
        return cls(
            chunk_size=s.max_memory_rows,
            spill_dir=s.spill_dir,
            resume=s.resume,
        )

    def run(
        self, source: Iterable[dict], task: EvalTask, session: Any
    ) -> EvalResult:
        stages = [PrepareStage(), InferStage(), ScoreStage()]
        stats_cfg = task.statistics
        names = [name for name, _ in resolve_metrics(task.metrics)]
        accs = {m: MetricAccumulator() for m in names}
        # the analytical interval comes straight from the moments; only the
        # bootstrap methods pay for the O(B x chunk) Poisson weight draws
        use_boot = stats_cfg.ci_method in ("percentile", "bca")
        boots = {
            m: PoissonBootstrap(stats_cfg.bootstrap_iterations, stats_cfg.seed)
            for m in names
        } if use_boot else {}
        manifest = (
            ChunkManifest(self.spill_dir, _run_key(task))
            if self.spill_dir
            else None
        )
        completed = (
            manifest.completed() if manifest is not None and self.resume else {}
        )

        failures: list[dict] = []
        timing: dict[str, float] = {}
        engine_stats = {"calls": 0, "total_cost": 0.0, "pool": {}}
        cache_stats: dict = {}
        n_examples = n_chunks = n_resumed = 0
        max_resident = 0
        start = 0

        for ci, chunk in enumerate(iter_chunks(source, self.chunk_size)):
            n_chunks += 1
            n_examples += len(chunk)
            max_resident = max(max_resident, len(chunk))
            # pop: committed rows carry B-length bootstrap partials, so
            # retaining the whole manifest would be O(n_chunks x B) memory
            row = completed.pop(ci, None)
            if row is not None:
                digest = _chunk_digest(chunk)
                if (
                    row["n_rows"] != len(chunk)
                    or row["start"] != start
                    or row.get("digest") != digest
                ):
                    raise ManifestMismatch(
                        f"chunk {ci}: manifest has start={row['start']} "
                        f"n_rows={row['n_rows']} digest={row.get('digest')}, "
                        f"observed start={start} n_rows={len(chunk)} "
                        f"digest={digest} — was the data source changed?"
                    )
                self._merge_committed(
                    row, accs, boots, failures, timing, engine_stats,
                    cache_stats,
                )
                n_resumed += 1
                start += len(chunk)
                continue

            art = EvalArtifact(rows=chunk, task=task)
            chunk_states: dict[str, dict] = {}
            chunk_timing: dict[str, float] = {}
            for stage in stages:
                t0 = time.monotonic()
                art = stage.run(art, session)
                chunk_timing[f"{stage.name}_s"] = time.monotonic() - t0
            for key, dt in chunk_timing.items():
                timing[key] = timing.get(key, 0.0) + dt

            for m in names:
                acc = MetricAccumulator()
                acc.update(art.scores[m])
                accs[m].merge(acc)
                if manifest is not None:
                    chunk_states.setdefault("metrics", {})[m] = acc.state()
                if use_boot:
                    boot = PoissonBootstrap(
                        stats_cfg.bootstrap_iterations, stats_cfg.seed
                    )
                    boot.update(art.scores[m], start)
                    boots[m].merge(boot)
                    if manifest is not None:
                        chunk_states.setdefault("boot", {})[m] = boot.state()
            chunk_failures = [
                {**f, "index": f["index"] + start} for f in art.failures
            ]
            state = {
                "start": start,
                "n_rows": len(chunk),
                "failures": chunk_failures[:MAX_FAILURE_SAMPLE],
                "n_failures": len(chunk_failures),
                "engine_stats": art.engine_stats,
                "cache_stats": art.cache_stats,
                "timing": chunk_timing,
            }
            if manifest is not None:
                # digest + serialized accumulator states are only needed for
                # the spill commit — the no-spill path skips the O(chunk)
                # hashing and the B-length list conversions entirely
                state["digest"] = _chunk_digest(chunk)
                state.update(chunk_states)
                manifest.record(ci, state)
            _merge_failures(failures, chunk_failures)
            _merge_engine_stats(engine_stats, art.engine_stats)
            _merge_cache_stats(cache_stats, art.cache_stats)
            for mw in session.middleware:
                mw.on_chunk_end(ci, state, session)
            start += len(chunk)
            del art, chunk  # chunk state dies here: O(chunk) memory

        if completed:
            # committed chunks beyond the end of the source: the data source
            # shrank by an exact chunk multiple — same class of error as a
            # mid-chunk mismatch, so refuse rather than silently under-count
            raise ManifestMismatch(
                f"manifest has {len(completed)} committed chunk(s) "
                f"({sorted(completed)}) beyond the end of the data source "
                f"({n_chunks} chunks observed) — was the data source changed?"
            )

        t0 = time.monotonic()
        metrics: dict[str, MetricValue] = {}
        for m in names:
            acc = accs[m]
            if acc.n == 0:
                metrics[m] = MetricValue(
                    m, float("nan"), (float("nan"),) * 2, "none", 0, acc.n_nan
                )
                continue
            iv = streaming_ci(
                acc,
                boots.get(m),
                method=stats_cfg.ci_method,
                confidence=stats_cfg.confidence_level,
                binary=m in BINARY_METRICS,
            )
            metrics[m] = MetricValue(
                m, iv.value, (iv.lo, iv.hi), iv.method, iv.n, acc.n_nan
            )
        timing["stats_s"] = time.monotonic() - t0

        if cache_stats:
            h, mi = cache_stats.get("hits", 0), cache_stats.get("misses", 0)
            cache_stats["hit_rate"] = h / (h + mi) if h + mi else 0.0
        return EvalResult(
            task_id=task.task_id,
            metrics=metrics,
            scores={},       # per-example scores are never materialized
            responses=[],    # raw responses were discarded per chunk
            failures=failures[:MAX_FAILURE_SAMPLE],
            cache_stats=cache_stats,
            engine_stats=engine_stats,
            timing=timing,
            logs={
                "streaming": {
                    "n_examples": n_examples,
                    "n_chunks": n_chunks,
                    "n_resumed_chunks": n_resumed,
                    "chunk_size": self.chunk_size,
                    "max_resident_rows": max_resident,
                    "spill_dir": self.spill_dir,
                }
            },
        )

    @staticmethod
    def _merge_committed(
        row: dict,
        accs: dict[str, MetricAccumulator],
        boots: dict[str, PoissonBootstrap],
        failures: list[dict],
        timing: dict[str, float],
        engine_stats: dict,
        cache_stats: dict,
    ) -> None:
        for m, acc in accs.items():
            acc.merge(MetricAccumulator.from_state(row["metrics"][m]))
            if m in boots:
                boots[m].merge(PoissonBootstrap.from_state(row["boot"][m]))
        _merge_failures(failures, row.get("failures", []))
        _merge_engine_stats(engine_stats, row.get("engine_stats", {}))
        _merge_cache_stats(cache_stats, row.get("cache_stats", {}))
        for k, v in row.get("timing", {}).items():
            timing[k] = timing.get(k, 0.0) + v


def _run_key(task: EvalTask) -> str:
    """Resume key: only configuration that affects the results — model,
    data prep, metrics, statistics, and the chunk layout
    (``max_memory_rows`` keys the bootstrap offsets) — decides whether
    committed chunks are reusable.  Execution-strategy knobs (the whole
    InferenceConfig: worker count, batching, caching, rate limits; spill
    location; resume flag) are normalized away so a restart may legitimately
    retune them without orphaning committed work."""
    payload = json.loads(task.to_json())
    payload.pop("inference", None)
    payload["streaming"] = {"max_memory_rows": task.streaming.max_memory_rows}
    return hashlib.sha256(
        json.dumps(payload, sort_keys=True).encode()
    ).hexdigest()[:16]


def _chunk_digest(chunk: list[dict]) -> str:
    """Content fingerprint of a chunk's rows: a resumed run must be fed the
    same data, not merely the same chunk layout."""
    payload = json.dumps(chunk, sort_keys=True, default=str)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def _merge_failures(acc: list[dict], new: list[dict]) -> None:
    room = MAX_FAILURE_SAMPLE - len(acc)
    if room > 0:
        acc.extend(new[:room])


def _merge_engine_stats(total: dict, delta: dict) -> None:
    total["calls"] += delta.get("calls") or 0
    total["total_cost"] += delta.get("total_cost", 0.0)
    for k, v in delta.get("pool", {}).items():
        total["pool"][k] = total["pool"].get(k, 0) + v


def _merge_cache_stats(total: dict, delta: dict) -> None:
    for k, v in delta.items():
        if not isinstance(v, (int, float)) or k == "hit_rate":
            continue  # hit_rate is recomputed from the summed counters
        if k in ("hits", "misses", "writes"):
            total[k] = total.get(k, 0) + v
        else:
            total[k] = v  # entries/version stay absolute: latest wins
