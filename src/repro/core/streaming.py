"""StreamingPipeline: bounded-memory chunked evaluation with resumable spill.

The default pipeline materializes prompts, responses and per-example scores
for the whole dataset — O(dataset) memory, which contradicts the paper's
"hundreds of thousands or millions of samples" claim.  This pipeline runs
prepare→infer→score per chunk (reusing the exact same stage objects, so
the sharded worker pool, caching, rate limiting and retries all apply
within a chunk), folds each chunk's scores into mergeable streaming
accumulators (:mod:`repro.stats.streaming`), and discards the chunk —
peak per-example state is one chunk, independent of dataset size.

With a ``spill_dir``, every completed chunk commits its partial state to a
:class:`~repro.storage.spill.ChunkManifest` (one DeltaLite commit per
chunk).  A restarted run replays the manifest: committed chunks are
skipped — their accumulator states merged instead of recomputed — and the
final metrics are bit-identical to an uninterrupted run, because the
Poisson-bootstrap weights are keyed by (seed, chunk offset), not by
processing order.

The aggregate CIs come from :func:`repro.stats.streaming.streaming_ci`:
exact analytical intervals from the moments, or the Poisson-bootstrap
percentile interval (Monte-Carlo-equivalent to the in-memory multinomial
bootstrap) for the bootstrap methods.  Replicate state is maintained by a
pluggable :class:`~repro.stats.streaming.BootstrapEngine`
(``StatisticsConfig.backend``): per-metric host Philox weight blocks
("numpy") or the device-resident chunked-partials kernel ("pallas") that
covers every metric of a chunk in one launch.  Either way the finished
result carries the merged O(B) state as ``EvalResult.stream_stats``,
which is what lets suites run paired significance tests between
streaming runs without per-example scores.

:class:`ConcurrentStreamingExecutor` is the parallel counterpart: it
schedules whole chunks onto a chunk-level :class:`~repro.ft.workers.
WorkerPool` window (``StreamingConfig.max_inflight_chunks``), so peak
memory is window x chunk — still independent of dataset size.  The Philox
keying of the bootstrap by (seed, chunk offset) makes chunk states
mergeable in *any* order; the executor nevertheless folds them
deterministically in chunk-index order through a bounded reorder buffer,
so the final metrics and CIs are **bit-identical** to the serial pipeline
(float addition is not associative — completion-order folding would be
statistically equivalent but not byte-equal).  Chunk-level straggler
mitigation reuses the pool's speculative re-issue; with a spill manifest,
racing attempts resolve first-committer-wins through DeltaLite's
conditional append, and the losing attempt's partial state is discarded
(its engine spend still lands in the session accounting — the calls were
real).
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import time
from typing import Any, Iterable, Iterator

from repro.core.config import EvalTask, StatisticsConfig
from repro.core.stages import (
    EvalArtifact,
    EvalResult,
    InferStage,
    MetricValue,
    PrepareStage,
    ScoreStage,
)
from repro.data.datasets import iter_chunks
from repro.ft.workers import WorkerPool
from repro.metrics.registry import BINARY_METRICS, resolve_metrics
from repro.stats.streaming import (
    BootstrapEngine,
    MetricAccumulator,
    StreamingStats,
    make_bootstrap_engine,
    streaming_ci,
)
from repro.storage.spill import ChunkManifest

#: failures kept in the result (full per-example lists defeat O(chunk) memory)
MAX_FAILURE_SAMPLE = 100


class ManifestMismatch(RuntimeError):
    """Manifest row disagrees with the observed chunk layout — the data
    source differs from the run that wrote the manifest."""


class _StopTracker:
    """Adaptive early-stopping state for one streaming run (both
    pipelines).  Owns the three manifest-backed invariants:

    * **one regime per manifest** — the stopping rule's fingerprint is
      committed as the manifest's regime row before any adaptive chunk
      commits; resuming with a different rule (or flipping adaptive
      mode on/off over existing chunks) refuses with a remediation hint
      instead of silently mixing certification regimes.
    * **one stop per run** — the first firing of the rule commits a stop
      row (first-committer-wins for racing drivers); the stop point then
      becomes part of the resume contract.
    * **bit-identical replay** — a resumed run re-consults the rule after
      every merged chunk (committed chunks replay the same accumulator
      states, so the same decision sequence), and any disagreement with
      the recorded stop row is a :class:`ManifestMismatch`, never a
      silent re-opening of sampling.
    """

    def __init__(
        self,
        task: EvalTask,
        manifest: ChunkManifest | None,
        completed: dict[int, dict],
    ):
        self.rule = task.stopping if task.stopping.enabled else None
        self.manifest = manifest
        self.stopped = False
        self.decision: dict | None = None
        self.recorded: dict | None = None
        if manifest is None:
            return
        fp = self.rule.fingerprint() if self.rule is not None else ""
        row = manifest.regime_row()
        if row is None and self.rule is not None:
            if completed:
                raise ManifestMismatch(
                    f"manifest {manifest.run_key} has {len(completed)} "
                    "committed chunk(s) but no certification-regime row — "
                    "it was written by a run without adaptive stopping. "
                    "Resume without a stopping rule, or clear the spill "
                    "dir to start an adaptive run"
                )
            if not manifest.try_record_regime({"rule": fp}):
                row = manifest.regime_row()  # lost the race: validate
        if row is not None and row.get("rule") != fp:
            ours = f"rule {fp}" if self.rule is not None else "stopping disabled"
            raise ManifestMismatch(
                f"manifest {manifest.run_key} was written under "
                f"certification regime {row.get('rule')!r} but this run has "
                f"{ours} — resuming would mix stopping regimes. Resume with "
                "the original StoppingRule, or clear the spill dir to "
                "re-certify under the new rule"
            )
        if self.rule is not None:
            self.recorded = manifest.stop_row()

    def after_chunk(
        self, ci: int, accs: dict[str, MetricAccumulator], n_examples: int
    ) -> bool:
        """Consult the rule after chunk ``ci`` merged; True = stop now.
        Validates (or commits) the manifest stop row as a side effect."""
        if self.rule is None:
            return False
        d = self.rule.should_stop(accs, n_examples)
        rec = self.recorded
        if not d.stop:
            if rec is not None and int(rec["stop_chunk"]) == ci:
                raise ManifestMismatch(
                    f"manifest records a certified stop at chunk {ci} "
                    f"(n={rec['n_examples']}, reason={rec['reason']!r}) but "
                    "this run's rule does not fire there — was the data "
                    "source or the rule changed?"
                )
            return False
        state = {
            "stop_chunk": ci,
            "n_examples": n_examples,
            "reason": d.reason,
            "metric": d.metric,
            "half_width": d.half_width,
            "rule": self.rule.fingerprint(),
        }
        if rec is None and self.manifest is not None:
            if not self.manifest.try_record_stop(state):
                rec = self.manifest.stop_row()  # lost the race: validate
        if rec is not None and (
            int(rec["stop_chunk"]),
            int(rec["n_examples"]),
            rec["reason"],
        ) != (ci, n_examples, d.reason):
            raise ManifestMismatch(
                f"stop decision diverged from the manifest: recorded "
                f"chunk {rec['stop_chunk']} n={rec['n_examples']} "
                f"reason={rec['reason']!r}, this run fired at chunk {ci} "
                f"n={n_examples} reason={d.reason!r} — was the data source "
                "or the rule changed?"
            )
        self.stopped = True
        self.decision = state
        return True

    def finish(self) -> None:
        """Source exhausted without the rule firing — legal, unless the
        manifest promised a stop this run never reached."""
        if self.recorded is not None and not self.stopped:
            raise ManifestMismatch(
                f"manifest records a certified stop at chunk "
                f"{self.recorded['stop_chunk']} "
                f"(n={self.recorded['n_examples']}) that this run never "
                "reached — was the data source shortened?"
            )

    def info(self) -> dict | None:
        """``logs['adaptive']`` payload, or None when stopping is off."""
        if self.rule is None:
            return None
        out = {
            "enabled": True,
            "stopped": self.stopped,
            "rule": self.rule.fingerprint(),
        }
        if self.decision is not None:
            out.update(self.decision)
        return out


class StreamingPipeline:
    def __init__(
        self,
        *,
        chunk_size: int = 1024,
        spill_dir: str = "",
        resume: bool = True,
        max_examples: int = 0,
    ):
        self.chunk_size = chunk_size
        self.spill_dir = spill_dir
        self.resume = resume
        self.max_examples = max_examples

    @classmethod
    def from_task(cls, task: EvalTask) -> "StreamingPipeline":
        s = task.streaming
        return cls(
            chunk_size=s.max_memory_rows,
            spill_dir=s.spill_dir,
            resume=s.resume,
            max_examples=s.max_examples,
        )

    def run(
        self, source: Iterable[dict], task: EvalTask, session: Any
    ) -> EvalResult:
        if self.max_examples > 0:
            source = itertools.islice(source, self.max_examples)
        stages = [PrepareStage(), InferStage(), ScoreStage()]
        stats_cfg = task.statistics
        names = [name for name, _ in resolve_metrics(task.metrics)]
        accs = {m: MetricAccumulator() for m in names}
        # the analytical interval comes straight from the moments; only the
        # bootstrap methods pay for maintaining replicate state (numpy:
        # O(B x chunk) Poisson weight draws per metric; pallas: one
        # chunked-partials kernel launch covering every metric)
        use_boot = stats_cfg.ci_method in ("percentile", "bca")
        engine = make_bootstrap_engine(
            stats_cfg.backend, stats_cfg.bootstrap_iterations,
            stats_cfg.seed, tuple(names),
        ) if use_boot else None
        manifest = (
            ChunkManifest(self.spill_dir, _run_key(task))
            if self.spill_dir
            else None
        )
        completed = (
            manifest.completed() if manifest is not None and self.resume else {}
        )
        stopper = _StopTracker(task, manifest, completed)

        failures: list[dict] = []
        timing: dict[str, float] = {}
        engine_stats = {"calls": 0, "total_cost": 0.0, "coalesced": 0, "pool": {}}
        cache_stats: dict = {}
        n_examples = n_chunks = n_resumed = 0
        max_resident = 0
        start = 0

        for ci, chunk in enumerate(iter_chunks(source, self.chunk_size)):
            n_chunks += 1
            n_examples += len(chunk)
            max_resident = max(max_resident, len(chunk))
            # pop: committed rows carry B-length bootstrap partials, so
            # retaining the whole manifest would be O(n_chunks x B) memory
            row = completed.pop(ci, None)
            if row is not None:
                digest = _chunk_digest(chunk)
                if (
                    row["n_rows"] != len(chunk)
                    or row["start"] != start
                    or row.get("digest") != digest
                ):
                    raise ManifestMismatch(
                        f"chunk {ci}: manifest has start={row['start']} "
                        f"n_rows={row['n_rows']} digest={row.get('digest')}, "
                        f"observed start={start} n_rows={len(chunk)} "
                        f"digest={digest} — was the data source changed?"
                    )
                self._merge_committed(
                    row, accs, engine, failures, timing, engine_stats,
                    cache_stats,
                )
                n_resumed += 1
                start += len(chunk)
                # resumed chunks replay the identical decision sequence:
                # a recorded stop fires here again, bit-identically, and
                # the source iterator is never advanced past it
                if stopper.after_chunk(ci, accs, n_examples):
                    break
                continue

            art = EvalArtifact(rows=chunk, task=task)
            chunk_states: dict[str, dict] = {}
            chunk_timing: dict[str, float] = {}
            for stage in stages:
                t0 = time.monotonic()
                art = stage.run(art, session)
                chunk_timing[f"{stage.name}_s"] = time.monotonic() - t0
            for key, dt in chunk_timing.items():
                timing[key] = timing.get(key, 0.0) + dt

            for m in names:
                acc = MetricAccumulator()
                acc.update(art.scores[m])
                accs[m].merge(acc)
                if manifest is not None:
                    chunk_states.setdefault("metrics", {})[m] = acc.state()
            if engine is not None:
                chunk_engine = engine.spawn()
                chunk_engine.update(art.scores, start)
                engine.merge(chunk_engine)
                if manifest is not None:
                    chunk_states["boot"] = chunk_engine.state()
            chunk_failures = [
                {**f, "index": f["index"] + start} for f in art.failures
            ]
            state = {
                "start": start,
                "n_rows": len(chunk),
                "failures": chunk_failures[:MAX_FAILURE_SAMPLE],
                "n_failures": len(chunk_failures),
                "engine_stats": art.engine_stats,
                "cache_stats": art.cache_stats,
                "timing": chunk_timing,
            }
            if manifest is not None:
                # digest + serialized accumulator states are only needed for
                # the spill commit — the no-spill path skips the O(chunk)
                # hashing and the B-length list conversions entirely
                state["digest"] = _chunk_digest(chunk)
                state.update(chunk_states)
                manifest.record(ci, state)
            _merge_failures(failures, chunk_failures)
            _merge_engine_stats(engine_stats, art.engine_stats)
            _merge_cache_stats(cache_stats, art.cache_stats)
            for mw in session.middleware:
                mw.on_chunk_end(ci, state, session)
            start += len(chunk)
            del art, chunk  # chunk state dies here: O(chunk) memory
            # the stop check sits after the manifest commit: the chunk that
            # satisfied the rule is durable before sampling closes, so a
            # crash here resumes straight to the same certified stop
            if stopper.after_chunk(ci, accs, n_examples):
                break

        stopper.finish()
        capped = 0 < self.max_examples <= n_examples
        if completed and not stopper.stopped and not capped:
            # committed chunks beyond the end of the source: the data source
            # shrank by an exact chunk multiple — same class of error as a
            # mid-chunk mismatch, so refuse rather than silently under-count
            # (after a certified stop, leftover rows are the in-flight
            # chunks a concurrent run committed past the stop point; after
            # reaching a declared max_examples cap, they are a larger prior
            # cap's chunks — both deterministically excluded, never merged)
            raise ManifestMismatch(
                f"manifest has {len(completed)} committed chunk(s) "
                f"({sorted(completed)}) beyond the end of the data source "
                f"({n_chunks} chunks observed) — was the data source changed?"
            )

        t0 = time.monotonic()
        metrics = _finalize_metrics(names, accs, engine, stats_cfg)
        timing["stats_s"] = time.monotonic() - t0

        if cache_stats:
            h, mi = cache_stats.get("hits", 0), cache_stats.get("misses", 0)
            cache_stats["hit_rate"] = h / (h + mi) if h + mi else 0.0
        logs = {
            "streaming": {
                "n_examples": n_examples,
                "n_chunks": n_chunks,
                "n_resumed_chunks": n_resumed,
                "chunk_size": self.chunk_size,
                "max_resident_rows": max_resident,
                "spill_dir": self.spill_dir,
                "stats_backend": stats_cfg.backend if use_boot else "",
            }
        }
        if stopper.info() is not None:
            logs["adaptive"] = stopper.info()
        return EvalResult(
            task_id=task.task_id,
            metrics=metrics,
            scores={},       # per-example scores are never materialized
            responses=[],    # raw responses were discarded per chunk
            failures=failures[:MAX_FAILURE_SAMPLE],
            cache_stats=cache_stats,
            engine_stats=engine_stats,
            timing=timing,
            logs=logs,
            stream_stats=StreamingStats(
                accs=accs, engine=engine,
                chunk_size=self.chunk_size, n_examples=n_examples,
            ),
        )

    @staticmethod
    def _merge_committed(
        row: dict,
        accs: dict[str, MetricAccumulator],
        engine: BootstrapEngine | None,
        failures: list[dict],
        timing: dict[str, float],
        engine_stats: dict,
        cache_stats: dict,
    ) -> None:
        for m, acc in accs.items():
            acc.merge(MetricAccumulator.from_state(row["metrics"][m]))
        if engine is not None:
            try:
                engine.merge_state(row["boot"])
            except ValueError as e:
                # designed refusal (e.g. pallas partials spilled on a TPU
                # host resumed on CPU): surface it as the documented
                # non-reusable-spill error, with a way out
                raise ManifestMismatch(
                    f"committed bootstrap partials are not mergeable by "
                    f"this run's statistics engine ({e}) — resume on the "
                    f"platform that wrote the spill, or clear the spill "
                    f"dir to recompute"
                ) from e
        _merge_failures(failures, row.get("failures", []))
        _merge_engine_stats(engine_stats, row.get("engine_stats", {}))
        _merge_cache_stats(cache_stats, row.get("cache_stats", {}))
        for k, v in row.get("timing", {}).items():
            timing[k] = timing.get(k, 0.0) + v


@dataclasses.dataclass
class ChunkOutcome:
    """One chunk's contribution, produced by a concurrent chunk worker.

    Exactly one outcome per chunk reaches the merge loop: speculative
    duplicates are discarded at the pool level (first finisher) and at the
    manifest level (first committer); ``state`` always carries the
    canonical chunk state — the one committed to the manifest when spill
    is configured.
    """

    index: int
    start: int
    n_rows: int
    state: dict
    resumed: bool = False        # merged from a prior run's manifest row
    deduped: bool = False        # this attempt lost the commit race
    #: live accumulator objects (None when merging a committed row)
    accs: dict[str, MetricAccumulator] | None = None
    engine: BootstrapEngine | None = None


class ConcurrentStreamingExecutor:
    """Parallel streaming evaluation: whole chunks in flight on a bounded
    window, bit-identical to :class:`StreamingPipeline`.

    * **Scheduling** — chunks are pulled lazily from the source and run on
      :meth:`WorkerPool.imap_windowed`: at most ``window`` chunks are
      materialized and executing at once, so peak memory is
      window x chunk (PR 2's O(chunk) guarantee, scaled by the window).
      Chunk-level retries and speculative re-issue of straggler chunks
      come from the same pool machinery the intra-chunk shards use.
    * **Merging** — chunk states are folded in chunk-index order (the
      pool's ordered mode reorders completions; a slot frees only once a
      chunk is yielded, so in-flight + buffered chunks never exceed the
      window), which makes metric totals and Poisson-bootstrap sums
      accumulate in exactly the serial order: the final metrics/CIs are
      byte-equal to a serial run.
    * **Spill** — each chunk worker commits its own manifest row through
      DeltaLite's optimistic-concurrency loop; racing speculative attempts
      resolve first-committer-wins (:meth:`ChunkManifest.try_record`), and
      a losing attempt adopts the committed row so the merged result never
      double-counts engine calls or cache traffic.
    * **Middleware** — ``on_chunk_end`` fires from the merge loop in chunk
      order (never for resumed chunks), matching serial semantics for
      progress, cost-budget aborts and crash injection.
    """

    def __init__(
        self,
        *,
        chunk_size: int = 1024,
        window: int = 2,
        spill_dir: str = "",
        resume: bool = True,
        max_examples: int = 0,
    ):
        self.chunk_size = chunk_size
        self.window = max(1, window)
        self.spill_dir = spill_dir
        self.resume = resume
        self.max_examples = max_examples

    @classmethod
    def from_task(cls, task: EvalTask) -> "ConcurrentStreamingExecutor":
        s = task.streaming
        return cls(
            chunk_size=s.max_memory_rows,
            window=s.max_inflight_chunks,
            spill_dir=s.spill_dir,
            resume=s.resume,
            max_examples=s.max_examples,
        )

    def run(
        self, source: Iterable[dict], task: EvalTask, session: Any
    ) -> EvalResult:
        if self.max_examples > 0:
            source = itertools.islice(source, self.max_examples)
        stages = [PrepareStage(), InferStage(), ScoreStage()]
        stats_cfg = task.statistics
        names = [name for name, _ in resolve_metrics(task.metrics)]
        accs = {m: MetricAccumulator() for m in names}
        use_boot = stats_cfg.ci_method in ("percentile", "bca")
        engine = make_bootstrap_engine(
            stats_cfg.backend, stats_cfg.bootstrap_iterations,
            stats_cfg.seed, tuple(names),
        ) if use_boot else None
        manifest = (
            ChunkManifest(self.spill_dir, _run_key(task))
            if self.spill_dir
            else None
        )
        completed = (
            manifest.completed() if manifest is not None and self.resume else {}
        )
        stopper = _StopTracker(task, manifest, completed)

        inf = task.inference
        chunk_pool = WorkerPool(
            n_workers=self.window,
            max_retries=inf.max_retries,
            straggler_factor=(
                inf.straggler_factor if inf.speculative_reissue else 0.0
            ),
        )

        failures: list[dict] = []
        timing: dict[str, float] = {}
        engine_stats = {"calls": 0, "total_cost": 0.0, "coalesced": 0, "pool": {}}
        cache_stats: dict = {}
        n_examples = n_chunks = n_resumed = 0
        resident = {"rows": 0, "max": 0}

        def items() -> Iterator[tuple[int, int, list[dict]]]:
            # runs on the driver thread inside the pool's scheduling loop:
            # a chunk is materialized only when a window slot is free
            start = 0
            for ci, chunk in enumerate(iter_chunks(source, self.chunk_size)):
                resident["rows"] += len(chunk)
                resident["max"] = max(resident["max"], resident["rows"])
                yield (ci, start, chunk)
                start += len(chunk)

        def process(index: int, item: tuple, worker: int) -> ChunkOutcome:
            ci, start, chunk = item
            return self._process_chunk(
                ci, start, chunk, task, session, stages, names, engine,
                manifest, completed,
            )

        # ordered=True does double duty: chunk states fold in index order
        # (deterministic float accumulation == serial order == bit-identical
        # output) and a window slot frees only at yield, so in-flight plus
        # completed-but-unmerged chunks never exceed the window
        stream = chunk_pool.imap_windowed(
            process, items(), window=self.window, ordered=True
        )
        try:
            for res in stream:
                out: ChunkOutcome = res.value
                resident["rows"] -= out.n_rows
                self._merge_outcome(
                    out, accs, engine, failures, timing, engine_stats,
                    cache_stats,
                )
                completed.pop(out.index, None)
                n_chunks += 1
                n_examples += out.n_rows
                if out.resumed:
                    n_resumed += 1
                else:
                    for mw in session.middleware:
                        mw.on_chunk_end(out.index, out.state, session)
                # the ordered merge folds chunk i only after 0..i-1, so the
                # rule observes the exact accumulator sequence of the serial
                # pipeline and fires at the same chunk — in-flight chunks
                # past the stop drain (committing their manifest rows) but
                # are never merged
                if stopper.after_chunk(out.index, accs, n_examples):
                    break
        finally:
            # a middleware abort (cost budget, crash injection) or a merge
            # error must join the chunk workers NOW, not at GC: in-flight
            # chunks drain — completing their manifest commits, which the
            # next resume will reuse — before the exception propagates,
            # so no worker keeps spending against the session afterwards
            stream.close()

        stopper.finish()
        capped = 0 < self.max_examples <= n_examples
        if completed and not stopper.stopped and not capped:
            raise ManifestMismatch(
                f"manifest has {len(completed)} committed chunk(s) "
                f"({sorted(completed)}) beyond the end of the data source "
                f"({n_chunks} chunks observed) — was the data source changed?"
            )

        t0 = time.monotonic()
        metrics = _finalize_metrics(names, accs, engine, stats_cfg)
        timing["stats_s"] = time.monotonic() - t0

        if cache_stats:
            h, mi = cache_stats.get("hits", 0), cache_stats.get("misses", 0)
            cache_stats["hit_rate"] = h / (h + mi) if h + mi else 0.0
        logs = {
            "streaming": {
                "n_examples": n_examples,
                "n_chunks": n_chunks,
                "n_resumed_chunks": n_resumed,
                "chunk_size": self.chunk_size,
                "max_inflight_chunks": self.window,
                "max_resident_rows": resident["max"],
                "spill_dir": self.spill_dir,
                "chunk_pool": dataclasses.asdict(chunk_pool.stats),
                "stats_backend": stats_cfg.backend if use_boot else "",
            }
        }
        if stopper.info() is not None:
            logs["adaptive"] = stopper.info()
        return EvalResult(
            task_id=task.task_id,
            metrics=metrics,
            scores={},
            responses=[],
            failures=failures[:MAX_FAILURE_SAMPLE],
            cache_stats=cache_stats,
            engine_stats=engine_stats,
            timing=timing,
            logs=logs,
            stream_stats=StreamingStats(
                accs=accs, engine=engine,
                chunk_size=self.chunk_size, n_examples=n_examples,
            ),
        )

    def _process_chunk(
        self, ci: int, start: int, chunk: list[dict], task: EvalTask,
        session: Any, stages: list, names: list[str],
        run_engine: BootstrapEngine | None,
        manifest: ChunkManifest | None,
        completed: dict[int, dict],
    ) -> ChunkOutcome:
        row = completed.get(ci) if manifest is not None else None
        if row is not None:
            digest = _chunk_digest(chunk)
            if (
                row["n_rows"] != len(chunk)
                or row["start"] != start
                or row.get("digest") != digest
            ):
                raise ManifestMismatch(
                    f"chunk {ci}: manifest has start={row['start']} "
                    f"n_rows={row['n_rows']} digest={row.get('digest')}, "
                    f"observed start={start} n_rows={len(chunk)} "
                    f"digest={digest} — was the data source changed?"
                )
            return ChunkOutcome(ci, start, len(chunk), state=row, resumed=True)

        art = EvalArtifact(rows=chunk, task=task)
        chunk_timing: dict[str, float] = {}
        for stage in stages:
            t0 = time.monotonic()
            art = stage.run(art, session)
            chunk_timing[f"{stage.name}_s"] = time.monotonic() - t0

        accs: dict[str, MetricAccumulator] = {}
        chunk_engine: BootstrapEngine | None = None
        chunk_states: dict[str, dict] = {}
        for m in names:
            acc = MetricAccumulator()
            acc.update(art.scores[m])
            accs[m] = acc
            if manifest is not None:
                chunk_states.setdefault("metrics", {})[m] = acc.state()
        if run_engine is not None:
            chunk_engine = run_engine.spawn()
            chunk_engine.update(art.scores, start)
            if manifest is not None:
                chunk_states["boot"] = chunk_engine.state()
        chunk_failures = [
            {**f, "index": f["index"] + start} for f in art.failures
        ]
        state = {
            "start": start,
            "n_rows": len(chunk),
            "failures": chunk_failures[:MAX_FAILURE_SAMPLE],
            "n_failures": len(chunk_failures),
            "engine_stats": art.engine_stats,
            "cache_stats": art.cache_stats,
            "timing": chunk_timing,
        }
        if manifest is not None:
            state["digest"] = _chunk_digest(chunk)
            state.update(chunk_states)
            if not manifest.try_record(ci, state):
                # lost the commit race to a speculative twin: adopt the
                # committed row so this chunk's calls/cache traffic are
                # counted exactly once in the merged result
                committed = manifest.get(ci)
                if committed is None:  # pragma: no cover — commit is durable
                    raise RuntimeError(
                        f"chunk {ci}: lost the manifest race but no "
                        "committed row is visible"
                    )
                return ChunkOutcome(
                    ci, start, len(chunk), state=committed, deduped=True
                )
        return ChunkOutcome(
            ci, start, len(chunk), state=state, accs=accs,
            engine=chunk_engine,
        )

    @staticmethod
    def _merge_outcome(
        out: ChunkOutcome,
        accs: dict[str, MetricAccumulator],
        engine: BootstrapEngine | None,
        failures: list[dict],
        timing: dict[str, float],
        engine_stats: dict,
        cache_stats: dict,
    ) -> None:
        if out.accs is None:
            # committed manifest row (resumed chunk or commit-race loser)
            StreamingPipeline._merge_committed(
                out.state, accs, engine, failures, timing, engine_stats,
                cache_stats,
            )
            return
        for m, acc in accs.items():
            acc.merge(out.accs[m])
        if engine is not None:
            engine.merge(out.engine)
        _merge_failures(failures, out.state["failures"])
        _merge_engine_stats(engine_stats, out.state["engine_stats"])
        _merge_cache_stats(cache_stats, out.state["cache_stats"])
        for k, v in out.state["timing"].items():
            timing[k] = timing.get(k, 0.0) + v


def _finalize_metrics(
    names: list[str],
    accs: dict[str, MetricAccumulator],
    engine: BootstrapEngine | None,
    stats_cfg: StatisticsConfig,
) -> dict[str, MetricValue]:
    """Aggregate merged accumulator state into final :class:`MetricValue`s
    (shared by the serial and concurrent streaming paths — same code, same
    floats, same bytes)."""
    metrics: dict[str, MetricValue] = {}
    for m in names:
        acc = accs[m]
        if acc.n == 0:
            metrics[m] = MetricValue(
                m, float("nan"), (float("nan"),) * 2, "none", 0, acc.n_nan
            )
            continue
        iv = streaming_ci(
            acc,
            engine.view(m) if engine is not None else None,
            method=stats_cfg.ci_method,
            confidence=stats_cfg.confidence_level,
            binary=m in BINARY_METRICS,
        )
        metrics[m] = MetricValue(
            m, iv.value, (iv.lo, iv.hi), iv.method, iv.n, acc.n_nan
        )
    return metrics


def _run_key(task: EvalTask) -> str:
    """Resume key: only configuration that affects the results — model,
    data prep, metrics, statistics, and the chunk layout
    (``max_memory_rows`` keys the bootstrap offsets) — decides whether
    committed chunks are reusable.  ``StatisticsConfig.backend`` stays in
    the key on purpose: the two backends draw different weight streams, so
    partials spilled by one are not mergeable by the other.
    Execution-strategy knobs (the whole InferenceConfig: worker count,
    batching, caching, rate limits; spill location; resume flag) are
    normalized away so a restart may legitimately retune them without
    orphaning committed work.  The stopping rule is also popped: chunk
    partials are reusable across rules (the rule decides *when sampling
    closes*, not what any chunk computed), but the manifest's regime row
    pins the certification regime — resuming with a changed rule is an
    explicit :class:`ManifestMismatch`, never a silent fresh directory."""
    payload = json.loads(task.to_json())
    payload.pop("inference", None)
    payload.pop("stopping", None)
    payload["streaming"] = {"max_memory_rows": task.streaming.max_memory_rows}
    return hashlib.sha256(
        json.dumps(payload, sort_keys=True).encode()
    ).hexdigest()[:16]


def _chunk_digest(chunk: list[dict]) -> str:
    """Content fingerprint of a chunk's rows: a resumed run must be fed the
    same data, not merely the same chunk layout."""
    payload = json.dumps(chunk, sort_keys=True, default=str)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def _merge_failures(acc: list[dict], new: list[dict]) -> None:
    room = MAX_FAILURE_SAMPLE - len(acc)
    if room > 0:
        acc.extend(new[:room])


def _merge_engine_stats(total: dict, delta: dict) -> None:
    total["calls"] += delta.get("calls") or 0
    total["total_cost"] += delta.get("total_cost", 0.0)
    total["coalesced"] = total.get("coalesced", 0) + (delta.get("coalesced") or 0)
    for k, v in delta.get("pool", {}).items():
        total["pool"][k] = total["pool"].get(k, 0) + v


def _merge_cache_stats(total: dict, delta: dict) -> None:
    for k, v in delta.items():
        if not isinstance(v, (int, float)) or k == "hit_rate":
            continue  # hit_rate is recomputed from the summed counters
        if k in ("hits", "misses", "writes"):
            total[k] = total.get(k, 0) + v
        else:
            total[k] = v  # entries/version stay absolute: latest wins
