"""Content-addressable response cache on DeltaLite (paper §3.2, Table 1)."""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any

from repro.core.config import CachePolicy, cache_key
from repro.storage.deltalite import DeltaLite


class CacheMiss(Exception):
    """Raised in REPLAY mode when a key is absent."""


@dataclasses.dataclass
class CacheEntry:
    prompt_hash: str
    model_name: str
    provider: str
    prompt_text: str
    response_text: str
    input_tokens: int
    output_tokens: int
    latency_ms: float
    created_at: float
    ttl_days: int | None = None

    def to_row(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_row(cls, row: dict) -> "CacheEntry":
        return cls(**{k: row.get(k) for k in cls.__dataclass_fields__})


class ResponseCache:
    """Five-policy cache; point lookups go through the DeltaLite CAS index.

    A warm in-memory key set makes the hot path O(1); it is rebuilt lazily
    from the log when the underlying table version moves (other writers).

    One handle is safely shared by concurrent chunk workers: the key set,
    version watermark and hit/miss/write counters are guarded by a single
    reentrant lock, so a ``_refresh`` racing a ``put`` can never publish a
    key set older than the version it is stamped with, and the counters
    never lose increments.  (DeltaLite appends themselves are already safe
    via optimistic concurrency — the lock covers the in-memory mirror.)
    """

    def __init__(self, path: str, policy: CachePolicy = CachePolicy.ENABLED):
        self.policy = policy
        self.table = DeltaLite(path, key_column="prompt_hash")
        self._known_version = -2
        self._keys: set[str] = set()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.writes = 0

    # -- key management --------------------------------------------------------

    def _refresh(self) -> None:
        v = self.table.latest_version()
        if v != self._known_version:
            self._keys = self.table.keys() if v >= 0 else set()
            self._known_version = v

    @staticmethod
    def key_for(
        prompt: str, model_name: str, provider: str,
        temperature: float, max_tokens: int,
    ) -> str:
        return cache_key(prompt, model_name, provider, temperature, max_tokens)

    # -- policy-aware operations -------------------------------------------------

    def lookup(self, key: str) -> CacheEntry | None:
        if self.policy in (CachePolicy.DISABLED, CachePolicy.WRITE_ONLY):
            return None
        with self._lock:
            self._refresh()
            if key not in self._keys:
                if self.policy == CachePolicy.REPLAY:
                    raise CacheMiss(
                        f"replay mode: {key[:12]}… not cached "
                        f"({len(self._keys)} entries present)"
                    )
                self.misses += 1
                return None
        # segment read happens outside the lock: concurrent lookups must
        # not serialize behind each other's (or a writer's) disk I/O
        row = self.table.lookup(key)
        if row is None:  # pragma: no cover — index said yes, table says no
            with self._lock:
                self.misses += 1
            return None
        entry = CacheEntry.from_row(row)
        if entry.ttl_days is not None and entry.created_at is not None:
            age_days = (time.time() - entry.created_at) / 86_400.0
            if age_days > entry.ttl_days:
                with self._lock:
                    self.misses += 1
                return None
        with self._lock:
            self.hits += 1
        return entry

    def put(self, entries: list[CacheEntry]) -> int:
        """Cache entries per policy; returns how many were recorded."""
        if self.policy in (CachePolicy.DISABLED, CachePolicy.READ_ONLY,
                           CachePolicy.REPLAY):
            return 0
        if not entries:
            return 0
        # the append itself is already safe under DeltaLite's optimistic
        # concurrency; only the in-memory mirror goes under the lock, so
        # readers are never blocked behind a writer's segment+commit I/O
        self.table.append([e.to_row() for e in entries])
        with self._lock:
            self._keys.update(e.prompt_hash for e in entries)
            self._known_version = self.table.latest_version()
            self.writes += len(entries)
        return len(entries)

    def stats(self) -> dict[str, Any]:
        with self._lock:
            total = self.hits + self.misses
            return {
                "hits": self.hits,
                "misses": self.misses,
                "writes": self.writes,
                "hit_rate": self.hits / total if total else 0.0,
                "entries": len(self._keys),
                "version": self.table.latest_version(),
            }
