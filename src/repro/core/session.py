"""EvalSession: long-lived owner of the shared evaluation resources.

The legacy ``EvalRunner.evaluate`` rebuilt the engine, response cache,
rate limiter and worker pool on every call, so an M-model × N-task
regression suite paid setup cost M×N times.  A session initializes each
resource once and reuses it across tasks:

* **engine registry** — one initialized :class:`InferenceEngine` per
  :class:`EngineModelConfig` (``session.engines``),
* **response caches** — one :class:`ResponseCache` handle per
  ``(cache_dir, policy)``,
* **inference services** — one :class:`~repro.core.service.
  InferenceService` per engine (``session.service_for``): the submit/
  gather front that coalesces identical in-flight requests and batches
  across every task/chunk/suite using that engine,
* **limiters / worker pools** — one per inference configuration,
* **accounting** — session-level totals (engine calls, tokens, cost,
  cache traffic) across every task run.

Lifecycle is a context manager::

    with EvalSession() as session:
        r1 = session.run_task(rows, task_a)
        r2 = session.run_task(rows, task_b)       # same engine, warm cache
        suite_res = session.run_suite(suite)      # M models × N tasks

``run_task`` executes the stage pipeline from :mod:`repro.core.stages`;
pass ``stages=`` to swap stages (e.g. ``rescore_stages(texts)`` for the
paper's cache-replay iteration loop).  ``run_suite`` executes an
:class:`~repro.core.suite.EvalSuite` and wires the per-model score
vectors into the pairwise significance machinery of
:mod:`repro.core.compare`.
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Any, Iterable, Sequence

from repro.core.cache import ResponseCache
from repro.core.config import CachePolicy, EngineModelConfig, EvalTask, InferenceConfig
from repro.core.engines import EngineRegistry, InferenceEngine
from repro.core.ratelimit import AdaptiveLimiter, TokenBucket
from repro.core.service import InferenceService
from repro.core.stages import (
    EvalArtifact,
    EvalResult,
    Middleware,
    Stage,
    default_stages,
)
from repro.core.suite import EvalSuite, SuiteResult, build_comparisons
from repro.ft.workers import WorkerPool


@dataclasses.dataclass
class SessionAccounting:
    """Cost/token totals across every task the session has run.

    Updated under ``lock``: concurrent chunk workers (streaming with
    ``max_inflight_chunks > 1``) fold their per-chunk traffic in from
    multiple threads.  Speculative chunk attempts that lose the manifest
    race still count here — the engine calls really happened and really
    cost money — while result-level ``engine_stats`` only merge the
    winning attempt per chunk.
    """

    tasks: int = 0
    engine_calls: int = 0
    input_tokens: int = 0
    output_tokens: int = 0
    cost_usd: float = 0.0
    cache_hits: int = 0
    cache_misses: int = 0
    #: submissions answered by an in-flight twin's engine call (the
    #: InferenceService's single-flight dedup): real requests, zero spend
    coalesced_requests: int = 0
    wall_s: float = 0.0

    def __post_init__(self) -> None:
        self.lock = threading.Lock()

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class EvalSession:
    def __init__(
        self,
        *,
        judge_engine: Any = None,
        wall_clock_rate_limit: bool = False,
        middleware: Iterable[Middleware] = (),
        cost_budget_usd: float | None = None,
        engine_kwargs: dict | None = None,
    ):
        self.judge_engine = judge_engine
        self.wall_clock = wall_clock_rate_limit
        self.middleware: list[Middleware] = list(middleware)
        if cost_budget_usd is not None:
            from repro.core.stages import CostBudgetMiddleware

            self.middleware.append(CostBudgetMiddleware(cost_budget_usd))
        self.engines = EngineRegistry()
        self.accounting = SessionAccounting()
        self._engine_kwargs = dict(engine_kwargs or {})
        self._caches: dict[tuple[str, CachePolicy], ResponseCache] = {}
        self._limiters: dict[tuple, Any] = {}
        self._pools: dict[tuple, WorkerPool] = {}
        #: one InferenceService per engine: the single-flight/batching
        #: domain spans every task, chunk and suite using that engine
        self._services: dict[tuple, InferenceService] = {}
        # get-or-create must be atomic: concurrent chunk workers asking for
        # the same cache/limiter/pool must share ONE instance — a duplicate
        # ResponseCache handle would fragment the key set and the hit/miss
        # counters across workers
        self._res_lock = threading.Lock()
        self._closed = False

    # -- shared resources ------------------------------------------------------

    @property
    def sleep(self):
        return time.sleep if self.wall_clock else (lambda s: None)

    def engine_for(self, model: EngineModelConfig) -> InferenceEngine:
        self._check_open()
        kw = dict(self._engine_kwargs)
        # direct-infer engines (judges, lock-step parity) are not serving
        # replicas: they must not claim a fault-schedule replica index,
        # or the schedule's replica numbering shifts under the fleet
        kw.pop("fault_plan", None)
        return self.engines.get(model, **kw)

    def _replica_engines(
        self, model: EngineModelConfig, inf: InferenceConfig
    ) -> list[InferenceEngine]:
        """One engine per data-parallel replica.  Local engines also get
        their own device group (``launch.mesh.replica_device_groups``) so
        replicas decode on distinct devices when the topology has them;
        simulated engines just get independent instances (own slots, own
        counters)."""
        n = max(1, inf.n_replicas)
        if n == 1:
            kw = dict(self._engine_kwargs)
            self._add_paging_kwargs(model, inf, kw)
            return [self.engines.get(model, **kw)]
        groups: list[Any] = [None] * n
        if model.provider == "local" and "devices" not in self._engine_kwargs:
            from repro.launch.mesh import replica_device_groups

            groups = replica_device_groups(n)
        out = []
        for i in range(n):
            kw = dict(self._engine_kwargs)
            if groups[i] is not None:
                kw["devices"] = groups[i]
            if inf.max_prefills_per_step and model.provider in (
                "local", "slotsim",
            ):
                kw.setdefault(
                    "max_prefills_per_step", inf.max_prefills_per_step
                )
            self._add_paging_kwargs(model, inf, kw)
            out.append(self.engines.get(model, replica=i, **kw))
        return out

    @staticmethod
    def _add_paging_kwargs(
        model: EngineModelConfig, inf: InferenceConfig, kw: dict
    ) -> None:
        """Forward paged-KV knobs to slot engines, but only when they are
        non-default so engine-registry cache keys stay stable for configs
        that never touch paging."""
        if inf.kv_page_size and model.provider in ("local", "slotsim"):
            kw.setdefault("kv_page_size", inf.kv_page_size)
            if not inf.prefix_cache:
                kw.setdefault("prefix_cache", False)
            if inf.kv_cache_dtype != "bf16":
                kw.setdefault("kv_cache_dtype", inf.kv_cache_dtype)

    def service_for(
        self, model: EngineModelConfig, inf: InferenceConfig
    ) -> InferenceService:
        """Get-or-create the shared :class:`InferenceService` for this
        engine.  Dispatch capacity scales with the stages attached to it
        (``InferenceService.attach``); queue depth, the coalescing default,
        the batch-formation window and the replica fan-out
        (``n_replicas`` / ``routing``) come from the first inference
        config that touches the engine."""
        self._check_open()
        key = (model, json.dumps(self._engine_kwargs, sort_keys=True, default=str))
        with self._res_lock:
            svc = self._services.get(key)
            if svc is None:
                from repro.core.service import ReplicaRouter

                svc = InferenceService(
                    engines=self._replica_engines(model, inf),
                    routing=ReplicaRouter(
                        inf.routing, prefix_len=inf.routing_prefix_len
                    ),
                    queue_depth=inf.service_queue_depth,
                    coalesce=inf.coalesce,
                    max_batch_wait_ms=inf.max_batch_wait_ms,
                    n_dispatchers=inf.n_workers,
                    sleep=self.sleep,
                    name=f"{model.provider}:{model.model_name}",
                    max_replica_restarts=inf.max_replica_restarts,
                    restart_backoff_s=inf.restart_backoff_s,
                    health_probe_steps=inf.health_probe_steps,
                )
                self._services[key] = svc
        return svc

    def serving_stats(self) -> list[dict]:
        """Per-service snapshots (submission/coalescing counters, and the
        batcher occupancy counters for slot engines) — surfaced in
        :class:`~repro.core.suite.SuiteResult` reports."""
        with self._res_lock:
            services = list(self._services.values())
        return [s.snapshot() for s in services]

    def cache_for(self, inf: InferenceConfig) -> ResponseCache | None:
        if not inf.cache_dir or inf.cache_policy == CachePolicy.DISABLED:
            return None
        key = (inf.cache_dir, inf.cache_policy)
        with self._res_lock:
            cache = self._caches.get(key)
            if cache is None:
                cache = ResponseCache(inf.cache_dir, inf.cache_policy)
                self._caches[key] = cache
        return cache

    def limiter_for(self, inf: InferenceConfig):
        key = (
            inf.adaptive_rate, inf.rate_limit_rpm, inf.rate_limit_tpm,
            inf.n_workers,
        )
        with self._res_lock:
            limiter = self._limiters.get(key)
            if limiter is None:
                if inf.adaptive_rate:
                    limiter = AdaptiveLimiter(
                        inf.rate_limit_rpm, inf.rate_limit_tpm, inf.n_workers,
                        sleep=self.sleep,
                    )
                else:
                    limiter = [
                        TokenBucket(
                            inf.rate_limit_rpm, inf.rate_limit_tpm,
                            inf.n_workers, sleep=self.sleep,
                        )
                        for _ in range(inf.n_workers)
                    ]
                self._limiters[key] = limiter
        return limiter

    def pool_for(self, inf: InferenceConfig) -> WorkerPool:
        straggler = inf.straggler_factor if inf.speculative_reissue else 0.0
        key = (inf.n_workers, inf.max_retries, straggler)
        with self._res_lock:
            pool = self._pools.get(key)
            if pool is None:
                pool = WorkerPool(
                    n_workers=inf.n_workers,
                    max_retries=inf.max_retries,
                    straggler_factor=straggler,
                )
                self._pools[key] = pool
        return pool

    # -- pipeline execution -----------------------------------------------------

    def run_task(
        self,
        rows: Iterable[dict],
        task: EvalTask,
        *,
        stages: Sequence[Stage] | None = None,
    ) -> EvalResult:
        self._check_open()
        if task.streaming.enabled:
            if stages is not None:
                raise ValueError(
                    "streaming tasks run a fixed per-chunk pipeline; "
                    "custom stages are not supported"
                )
            return self._run_streaming(rows, task)
        pipeline = list(stages) if stages is not None else default_stages()
        art = EvalArtifact(rows=list(rows), task=task)
        t_task = time.monotonic()
        for mw in self.middleware:
            mw.on_task_start(task, art.rows, self)
        for stage in pipeline:
            for mw in self.middleware:
                mw.on_stage_start(stage, art, self)
            t0 = time.monotonic()
            art = stage.run(art, self)
            art.timing[f"{stage.name}_s"] = time.monotonic() - t0
            for mw in self.middleware:
                mw.on_stage_end(stage, art, self)
        result = art.to_result()
        self.accounting.tasks += 1
        self.accounting.wall_s += time.monotonic() - t_task
        for mw in self.middleware:
            mw.on_task_end(task, result, self)
        return result

    def _run_streaming(self, source: Iterable[dict], task: EvalTask) -> EvalResult:
        """Bounded-memory chunked execution (``task.streaming.enabled``):
        prepare→infer→score per chunk, mergeable streaming aggregation,
        optional DeltaLite spill for resume.  With
        ``max_inflight_chunks > 1`` whole chunks run concurrently on a
        chunk-level worker pool (bounded window, chunk-level speculation),
        producing bit-identical results to the serial pipeline."""
        from repro.core.streaming import (
            ConcurrentStreamingExecutor,
            StreamingPipeline,
        )

        if task.streaming.max_inflight_chunks > 1:
            pipeline = ConcurrentStreamingExecutor.from_task(task)
        else:
            pipeline = StreamingPipeline.from_task(task)
        t_task = time.monotonic()
        for mw in self.middleware:
            mw.on_task_start(task, [], self)
        result = pipeline.run(source, task, self)
        self.accounting.tasks += 1
        self.accounting.wall_s += time.monotonic() - t_task
        for mw in self.middleware:
            mw.on_task_end(task, result, self)
        return result

    def run_suite(
        self,
        suite: EvalSuite,
        *,
        stages: Sequence[Stage] | None = None,
        parallel_jobs: int = 1,
    ) -> SuiteResult:
        """Run every (model, task) job of the suite, reusing session
        resources, and compute the pairwise significance matrix for every
        metric shared across models.

        ``parallel_jobs > 1`` runs that many jobs concurrently on a thread
        pool.  Session resources are already safe under concurrent tasks
        (locked get-or-create, locked accounting), and the shared
        InferenceService turns the overlap into cross-task batching and
        single-flight dedup — jobs sharing an engine fill its decode slots
        together instead of draining it per shard.  Each job's result is
        computed exactly as in a sequential run; middleware hooks may fire
        from worker threads."""
        self._check_open()
        results: dict[tuple[str, str], EvalResult] = {}
        jobs = suite.jobs()

        def _run_job(job):
            # a callable source yields a fresh iterator per job (streaming
            # tasks swept across models consume their source once per run)
            rows = job.rows() if callable(job.rows) else job.rows
            return (
                (job.model_label, job.task.task_id),
                self.run_task(rows, job.task, stages=stages),
            )

        if parallel_jobs <= 1:
            for job in jobs:
                k, v = _run_job(job)
                results[k] = v
        else:
            from concurrent.futures import ThreadPoolExecutor

            with ThreadPoolExecutor(max_workers=parallel_jobs) as ex:
                for k, v in ex.map(_run_job, jobs):
                    results[k] = v
        comparisons = build_comparisons(suite, results)
        accounting = self.accounting.as_dict()
        serving = self.serving_stats()
        if serving:
            accounting["serving"] = serving
        return SuiteResult(
            name=suite.name,
            models=suite.model_labels(),
            tasks=suite.task_ids(),
            results=results,
            comparisons=comparisons,
            accounting=accounting,
        )

    # -- lifecycle ---------------------------------------------------------------

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("EvalSession is closed")

    def close(self) -> None:
        if self._closed:
            return
        # services drain (queued work dispatches, in-flight decode
        # finishes, dispatcher threads join) before their engines go away
        for svc in self._services.values():
            svc.close()
        self._services.clear()
        self.engines.shutdown()
        self._caches.clear()
        self._limiters.clear()
        self._pools.clear()
        self._closed = True

    def __enter__(self) -> "EvalSession":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
