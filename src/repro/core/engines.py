"""Inference-engine abstraction (paper §3.3) and implementations.

* :class:`LocalJaxEngine` — the primary engine on a pod: serves one of the
  assigned architectures through the continuous-batching scheduler
  (``repro/serve``).  The paper lists local model support as future work
  #1; on a TPU pod it is the default.
* :class:`SimulatedAPIEngine` — deterministic stand-in for the OpenAI /
  Anthropic / Google providers: latency model + price book (Table 6) +
  deterministic responses, so the paper's throughput/caching/cost
  benchmarks reproduce without network access.

``get_engine`` keeps one engine per serialized config per process — the
paper's Listing-1 ``_ENGINE_CACHE`` pattern (amortize initialization across
batches; in JAX terms: compile once, execute many).
"""

from __future__ import annotations

import abc
import dataclasses
import hashlib
import json
import math
import re
import threading
import time
from typing import Any

from repro.core.config import EngineModelConfig, InferenceConfig

# -- request/response ---------------------------------------------------------


@dataclasses.dataclass
class InferenceRequest:
    prompt: str
    max_tokens: int = 64
    temperature: float = 0.0


@dataclasses.dataclass
class InferenceResponse:
    text: str
    input_tokens: int
    output_tokens: int
    latency_ms: float
    cost_usd: float = 0.0
    error: str | None = None


# -- price book (paper Table 6, USD per 1M tokens) -----------------------------

PRICE_BOOK: dict[tuple[str, str], tuple[float, float]] = {
    ("openai", "gpt-4o"): (2.50, 15.00),
    ("openai", "gpt-4o-mini"): (0.15, 0.60),
    ("openai", "gpt-4-turbo"): (10.00, 30.00),
    ("openai", "gpt-3.5-turbo"): (0.50, 1.50),
    ("anthropic", "claude-3-5-sonnet"): (3.00, 15.00),
    ("anthropic", "claude-3-opus"): (15.00, 75.00),
    ("anthropic", "claude-3-sonnet"): (3.00, 15.00),
    ("anthropic", "claude-3-haiku"): (0.25, 1.25),
    ("google", "gemini-1.5-pro"): (1.25, 5.00),
    ("google", "gemini-1.5-flash"): (0.075, 0.30),
    ("google", "gemini-1.0-pro"): (0.50, 1.50),
}


def api_cost(provider: str, model: str, in_tok: int, out_tok: int) -> float:
    pin, pout = PRICE_BOOK.get((provider, model), (0.0, 0.0))
    return (in_tok * pin + out_tok * pout) / 1e6


#: simulated answer quality per model tier (drives benchmark comparisons)
_MODEL_QUALITY: dict[str, float] = {
    "gpt-4o": 0.95, "gpt-4-turbo": 0.93, "gpt-4o-mini": 0.78,
    "gpt-3.5-turbo": 0.70, "claude-3-5-sonnet": 0.95, "claude-3-opus": 0.94,
    "claude-3-sonnet": 0.88, "claude-3-haiku": 0.75, "gemini-1.5-pro": 0.92,
    "gemini-1.5-flash": 0.80, "gemini-1.0-pro": 0.72,
}


# -- ABC ------------------------------------------------------------------------


class InferenceEngine(abc.ABC):
    @abc.abstractmethod
    def initialize(self) -> None: ...

    @abc.abstractmethod
    def infer(self, request: InferenceRequest) -> InferenceResponse: ...

    @abc.abstractmethod
    def infer_batch(
        self, requests: list[InferenceRequest]
    ) -> list[InferenceResponse]: ...

    @abc.abstractmethod
    def shutdown(self) -> None: ...


# -- simulated API engine ---------------------------------------------------------


class SimulatedAPIEngine(InferenceEngine):
    """Deterministic provider stand-in.

    Latency = base + per-token * output_tokens (+ deterministic jitter from
    the prompt hash).  Responses are a deterministic transform of the
    prompt, so caching benchmarks observe real hit/miss behaviour.  Set
    ``wall_clock=False`` to account latency without sleeping (fast
    benchmarks compute throughput from accounted latency).
    """

    def __init__(
        self,
        model: EngineModelConfig,
        *,
        base_latency_ms: float = 250.0,
        per_token_ms: float = 0.6,
        wall_clock: bool = False,
        fail_every: int = 0,  # inject a recoverable failure every N calls
    ):
        self.model = model
        self.base_latency_ms = base_latency_ms
        self.per_token_ms = per_token_ms
        self.wall_clock = wall_clock
        self.fail_every = fail_every
        self.calls = 0
        self.total_cost = 0.0
        self.initialized = False
        # counter updates must not lose increments when shards from
        # several concurrent chunks share one simulated engine
        self._counter_lock = threading.Lock()

    def initialize(self) -> None:
        self.initialized = True

    def shutdown(self) -> None:
        self.initialized = False

    @staticmethod
    def _count_tokens(text: str) -> int:
        return max(1, len(text.split()))

    def _respond(self, prompt: str, max_tokens: int) -> str:
        h = hashlib.sha256(prompt.encode()).hexdigest()
        hv = int(h[:8], 16)
        if prompt.startswith("[Judge]"):
            # deterministic judge behaviour, with a rare malformed response
            # (exercises the unparseable-logging path; paper §5.6 saw 0.12%)
            if hv % 797 == 0:
                return "I cannot assess this response."
            if "Winner:" in prompt or "Response A:" in prompt:
                return f"Winner: {'A' if hv % 2 == 0 else 'B'} — clearer answer."
            scale = 5
            m = re.search(r"1-(\d+) scale", prompt)
            if m:
                scale = int(m.group(1))
            # content-sensitive: degraded responses ("flub" fillers from
            # low-tier simulated models) score lower, plus mild hash noise —
            # so judge metrics track real quality differences
            m2 = re.search(r"Response: (.*)", prompt, re.DOTALL)
            resp = m2.group(1) if m2 else ""
            flubs = resp.count("flub")
            score = max(1, min(scale, scale - flubs + (hv % 2)))
            return f"Score: {score}. Concise and mostly accurate."
        words = prompt.split()
        # deterministic "answer": echo of salient words + hash suffix.
        # Quality scales with the (simulated) model tier so model
        # comparisons observe real, stable differences.
        quality = _MODEL_QUALITY.get(self.model.model_name, 0.8)
        salient = [w for w in words if len(w) > 3][: max(3, max_tokens // 4)]
        kept = []
        for i, w in enumerate(salient):
            wh = int(hashlib.sha256(f"{w}{i}{h[:4]}".encode()).hexdigest()[:4], 16)
            if (wh % 1000) / 1000.0 < quality:
                kept.append(w)
            else:
                kept.append(f"flub{wh % 97}")
        return " ".join(kept + [f"ans_{h[:8]}"])

    def infer(self, request: InferenceRequest) -> InferenceResponse:
        with self._counter_lock:
            self.calls += 1
            call_no = self.calls
        if self.fail_every and call_no % self.fail_every == 0:
            return InferenceResponse(
                text="", input_tokens=0, output_tokens=0,
                latency_ms=self.base_latency_ms, error="rate_limited_429",
            )
        text = self._respond(request.prompt, request.max_tokens)
        in_tok = self._count_tokens(request.prompt)
        out_tok = min(self._count_tokens(text), request.max_tokens)
        jitter = int(hashlib.sha256(request.prompt.encode()).hexdigest()[:4], 16)
        latency = self.base_latency_ms + self.per_token_ms * out_tok + jitter % 50
        if self.wall_clock:
            time.sleep(latency / 1000.0)
        cost = api_cost(self.model.provider, self.model.model_name, in_tok, out_tok)
        with self._counter_lock:
            self.total_cost += cost
        return InferenceResponse(
            text=text, input_tokens=in_tok, output_tokens=out_tok,
            latency_ms=latency, cost_usd=cost,
        )

    def infer_batch(self, requests: list[InferenceRequest]) -> list[InferenceResponse]:
        return [self.infer(r) for r in requests]


# -- local JAX engine ----------------------------------------------------------------


class LocalJaxEngine(InferenceEngine):
    """Serve an assigned architecture via the continuous-batching scheduler."""

    def __init__(self, model: EngineModelConfig, *, n_slots: int = 8,
                 max_len: int = 256):
        self.model_cfg = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.initialized = False
        self._scheduler = None
        self._tokenizer = None
        self._next_id = 0
        # worker threads share one scheduler; it is the batching layer, so
        # concurrent infer_batch calls serialize (slots multiplex inside)
        self._lock = threading.Lock()

    def initialize(self) -> None:
        if self.initialized:
            return
        import jax

        from repro.configs import get_config
        from repro.data.tokenizer import HashTokenizer
        from repro.models import params as pm
        from repro.models.model import build_model
        from repro.serve.scheduler import ContinuousBatcher

        cfg = get_config(self.model_cfg.model_name)
        if self.model_cfg.reduced:
            cfg = cfg.reduced()
        self._cfg = cfg
        self._tokenizer = HashTokenizer(cfg.vocab_size)
        model = build_model(cfg, remat="none")
        params = pm.init_params(
            jax.random.key(self.model_cfg.seed), model.param_specs()
        )
        self._scheduler = ContinuousBatcher(
            model, cfg, params,
            n_slots=self.n_slots, max_len=self.max_len,
            eos_id=self._tokenizer.eos_id,
            temperature=self.model_cfg.temperature,
        )
        self.initialized = True

    def shutdown(self) -> None:
        self._scheduler = None
        self.initialized = False

    def infer(self, request: InferenceRequest) -> InferenceResponse:
        return self.infer_batch([request])[0]

    def infer_batch(self, requests: list[InferenceRequest]) -> list[InferenceResponse]:
        with self._lock:
            return self._infer_batch_locked(requests)

    def _infer_batch_locked(
        self, requests: list[InferenceRequest]
    ) -> list[InferenceResponse]:
        from repro.serve.scheduler import Request

        self.initialize()
        t0 = time.monotonic()
        id_map: dict[int, int] = {}
        for i, r in enumerate(requests):
            rid = self._next_id
            self._next_id += 1
            id_map[rid] = i
            toks = self._tokenizer.encode(r.prompt)[: self.max_len // 2]
            self._scheduler.submit(
                Request(
                    request_id=rid,
                    prompt_tokens=toks or [self._tokenizer.bos_id],
                    max_new_tokens=min(
                        r.max_tokens, self.max_len - len(toks) - 1
                    ),
                )
            )
        completions = self._scheduler.run_to_completion()
        self._scheduler.completions = []
        out: list[InferenceResponse | None] = [None] * len(requests)
        for c in completions:
            if c.request_id not in id_map:
                continue
            i = id_map[c.request_id]
            text = self._tokenizer.decode(c.tokens)
            out[i] = InferenceResponse(
                text=text,
                input_tokens=c.prompt_len,
                output_tokens=len(c.tokens),
                latency_ms=c.latency_s * 1000.0,
            )
        dt = time.monotonic() - t0
        for i, r in enumerate(out):
            if r is None:  # pragma: no cover
                out[i] = InferenceResponse(
                    text="", input_tokens=0, output_tokens=0,
                    latency_ms=dt * 1000.0, error="lost",
                )
        return out  # type: ignore[return-value]


# -- registry (Listing 1) ------------------------------------------------------------


def create_engine(model: EngineModelConfig, **kw: Any) -> InferenceEngine:
    if model.provider == "local":
        return LocalJaxEngine(model, **kw)
    return SimulatedAPIEngine(model, **kw)


class EngineRegistry:
    """One initialized engine per :class:`EngineModelConfig` (+ extra
    constructor kwargs).  The paper's Listing-1 ``_ENGINE_CACHE`` pattern,
    made an owned object so an :class:`~repro.core.session.EvalSession`
    amortizes initialization across every task it runs — in JAX terms:
    compile once, execute many.
    """

    def __init__(self) -> None:
        self._engines: dict[tuple[EngineModelConfig, str], InferenceEngine] = {}
        self.initializations = 0
        # concurrent chunk workers may request the same engine at once;
        # initialization must happen exactly once per config
        self._lock = threading.Lock()

    def get(self, model: EngineModelConfig, **kw: Any) -> InferenceEngine:
        key = (model, json.dumps(kw, sort_keys=True, default=str))
        with self._lock:
            engine = self._engines.get(key)
            if engine is None:
                engine = create_engine(model, **kw)
                engine.initialize()
                self.initializations += 1
                self._engines[key] = engine
        return engine

    def shutdown(self) -> None:
        for engine in self._engines.values():
            engine.shutdown()
        self._engines.clear()

    def __len__(self) -> int:
        return len(self._engines)

    def __contains__(self, model: EngineModelConfig) -> bool:
        return any(k[0] == model for k in self._engines)

    def engines(self) -> list[InferenceEngine]:
        return list(self._engines.values())


_PROCESS_REGISTRY = EngineRegistry()


def get_engine(
    model: EngineModelConfig, inference: InferenceConfig, **kw: Any
) -> InferenceEngine:
    """Process-global engine lookup (legacy); sessions own their own
    :class:`EngineRegistry` instead."""
    del inference  # engines depend only on the model config + kwargs
    return _PROCESS_REGISTRY.get(model, **kw)


def retry_with_backoff(
    fn, *, max_retries: int = 3, base_delay: float = 1.0,
    sleep=time.sleep,
):
    """Exponential backoff for recoverable errors (429/5xx; paper §A.4)."""
    last: InferenceResponse | None = None
    for attempt in range(max_retries + 1):
        resp = fn()
        if resp.error is None:
            return resp
        recoverable = any(
            code in (resp.error or "") for code in ("429", "500", "502", "503")
        )
        if not recoverable:
            return resp
        last = resp
        if attempt < max_retries:
            sleep(base_delay * math.pow(2.0, attempt))
    return last
