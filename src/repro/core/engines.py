"""Inference-engine abstraction (paper §3.3) and implementations.

* :class:`LocalJaxEngine` — the primary engine on a pod: serves one of the
  assigned architectures through the continuous-batching scheduler
  (``repro/serve``).  The paper lists local model support as future work
  #1; on a TPU pod it is the default.
* :class:`SimulatedAPIEngine` — deterministic stand-in for the OpenAI /
  Anthropic / Google providers: latency model + price book (Table 6) +
  deterministic responses, so the paper's throughput/caching/cost
  benchmarks reproduce without network access.

``get_engine`` keeps one engine per serialized config per process — the
paper's Listing-1 ``_ENGINE_CACHE`` pattern (amortize initialization across
batches; in JAX terms: compile once, execute many).
"""

from __future__ import annotations

import abc
import dataclasses
import hashlib
import json
import math
import re
import threading
import time
from typing import Any

from repro.core.config import EngineModelConfig, InferenceConfig

# -- request/response ---------------------------------------------------------


@dataclasses.dataclass
class InferenceRequest:
    prompt: str
    max_tokens: int = 64
    temperature: float = 0.0


@dataclasses.dataclass
class InferenceResponse:
    text: str
    input_tokens: int
    output_tokens: int
    latency_ms: float
    cost_usd: float = 0.0
    error: str | None = None


class RecoverableEngineError(RuntimeError):
    """Transient engine failure worth retrying — the exception-typed
    counterpart of the 429/5xx error *strings* in
    :data:`RECOVERABLE_ERROR_CODES`.

    The service's retry paths back off and re-attempt on this type only;
    any other exception (a programming error such as ``ValueError`` /
    ``TypeError``) fails the ticket immediately with the original
    traceback instead of burning the backoff budget (DESIGN.md §9)."""


# -- price book (paper Table 6, USD per 1M tokens) -----------------------------

PRICE_BOOK: dict[tuple[str, str], tuple[float, float]] = {
    ("openai", "gpt-4o"): (2.50, 15.00),
    ("openai", "gpt-4o-mini"): (0.15, 0.60),
    ("openai", "gpt-4-turbo"): (10.00, 30.00),
    ("openai", "gpt-3.5-turbo"): (0.50, 1.50),
    ("anthropic", "claude-3-5-sonnet"): (3.00, 15.00),
    ("anthropic", "claude-3-opus"): (15.00, 75.00),
    ("anthropic", "claude-3-sonnet"): (3.00, 15.00),
    ("anthropic", "claude-3-haiku"): (0.25, 1.25),
    ("google", "gemini-1.5-pro"): (1.25, 5.00),
    ("google", "gemini-1.5-flash"): (0.075, 0.30),
    ("google", "gemini-1.0-pro"): (0.50, 1.50),
}


def api_cost(provider: str, model: str, in_tok: int, out_tok: int) -> float:
    pin, pout = PRICE_BOOK.get((provider, model), (0.0, 0.0))
    return (in_tok * pin + out_tok * pout) / 1e6


#: simulated answer quality per model tier (drives benchmark comparisons)
_MODEL_QUALITY: dict[str, float] = {
    "gpt-4o": 0.95, "gpt-4-turbo": 0.93, "gpt-4o-mini": 0.78,
    "gpt-3.5-turbo": 0.70, "claude-3-5-sonnet": 0.95, "claude-3-opus": 0.94,
    "claude-3-sonnet": 0.88, "claude-3-haiku": 0.75, "gemini-1.5-pro": 0.92,
    "gemini-1.5-flash": 0.80, "gemini-1.0-pro": 0.72,
}


# -- serving counters ------------------------------------------------------------


@dataclasses.dataclass
class BatcherStats:
    """Occupancy/throughput counters for a slot-multiplexed decode loop.

    Shared by :class:`~repro.serve.scheduler.ContinuousBatcher` (the real
    JAX decode gang) and :class:`SimulatedSlotEngine` (its deterministic
    stand-in), and surfaced through ``InferenceService.snapshot`` into
    session accounting and the suite report.
    """

    n_slots: int = 0
    steps: int = 0
    #: sum of active slots over all steps — occupancy numerator
    active_slot_steps: int = 0
    tokens_generated: int = 0
    admissions: int = 0
    #: distinct prompt lengths prefilled (exact-length prefill compiles
    #: one program per length; callers bound this by bucketing prompts)
    prefill_recompiles: int = 0
    completions: int = 0
    #: admissions pushed past the current step by the per-step prefill cap
    #: (prefill/decode disaggregation: decode keeps stepping, the prompt
    #: waits one step for a prefill slot instead of stalling the gang)
    prefills_deferred: int = 0
    #: prompt-prefix pages reused from the paged KV cache's prefix index
    #: (zero on contiguous-cache batchers)
    prefix_pages_hit: int = 0
    #: prompt tokens whose prefill was skipped via shared prefix pages
    prefix_tokens_saved: int = 0
    #: defensive copy-on-write page copies (structurally unreachable while
    #: sharing stops short of the final prompt token — see DESIGN.md §8)
    cow_copies: int = 0
    #: decode slots evicted under page-pool pressure; the victim's request
    #: requeues for a full deterministic recompute (DESIGN.md §9)
    preemptions: int = 0
    #: decoded tokens discarded by preemptions (the recompute cost)
    preempted_tokens: int = 0
    #: HBM bytes one cached token costs, quantization-scale buffer
    #: included (0 on contiguous-cache batchers) — int8 pages halve this,
    #: which is exactly where quantization buys pool capacity (DESIGN.md §10)
    kv_bytes_per_token: int = 0
    #: KV page-pool size in pages (0 on contiguous-cache batchers)
    pool_pages: int = 0

    @property
    def tokens_per_step(self) -> float:
        return self.tokens_generated / self.steps if self.steps else 0.0

    @property
    def occupancy(self) -> float:
        cap = self.steps * self.n_slots
        return self.active_slot_steps / cap if cap else 0.0

    def as_dict(self) -> dict:
        return {
            "n_slots": self.n_slots,
            "steps": self.steps,
            "admissions": self.admissions,
            "completions": self.completions,
            "tokens_generated": self.tokens_generated,
            "active_slot_steps": self.active_slot_steps,
            "tokens_per_step": round(self.tokens_per_step, 3),
            "slot_occupancy": round(self.occupancy, 4),
            "prefill_recompiles": self.prefill_recompiles,
            "prefills_deferred": self.prefills_deferred,
            "prefix_pages_hit": self.prefix_pages_hit,
            "prefix_tokens_saved": self.prefix_tokens_saved,
            "cow_copies": self.cow_copies,
            "preemptions": self.preemptions,
            "preempted_tokens": self.preempted_tokens,
            "kv_bytes_per_token": self.kv_bytes_per_token,
            "pool_pages": self.pool_pages,
        }


# -- ABC ------------------------------------------------------------------------


class InferenceEngine(abc.ABC):
    #: engines that expose the slot-streaming interface below are driven by
    #: the :class:`~repro.core.service.InferenceService` with one persistent
    #: batcher loop (continuous batching across tasks) instead of a
    #: thread-per-request dispatch pool
    supports_streaming: bool = False

    @abc.abstractmethod
    def initialize(self) -> None: ...

    @abc.abstractmethod
    def infer(self, request: InferenceRequest) -> InferenceResponse: ...

    @abc.abstractmethod
    def infer_batch(
        self, requests: list[InferenceRequest]
    ) -> list[InferenceResponse]: ...

    @abc.abstractmethod
    def shutdown(self) -> None: ...

    def reset(self) -> None:
        """Engine reset hook for replica restart: drop all in-flight
        serving state (queued and slotted requests, their KV pages) so a
        fresh batcher loop starts clean.  Cumulative counters survive.
        Default: full shutdown + initialize."""
        self.shutdown()
        self.initialize()

    # -- optional slot-streaming interface (``supports_streaming``) ----------

    def stream_submit(self, request: InferenceRequest) -> int:
        """Enqueue a request for continuous-batching decode; returns an id."""
        raise NotImplementedError

    def stream_pump(self) -> list[tuple[int, InferenceResponse]]:
        """Advance decode by one step (admitting queued requests into free
        slots first) and return the requests that finished."""
        raise NotImplementedError

    def stream_pending(self) -> bool:
        """True while queued or in-flight streaming work remains."""
        return False

    def stream_cancel(self, rid: int) -> bool:
        """Abandon a streaming request without producing a completion:
        dequeue it, or free its decode slot and release its KV pages.
        Used by the service to cancel the losing leg of a hedged request.
        Returns True if the request was found and cancelled."""
        return False

    def serving_stats(self) -> dict:
        """:class:`BatcherStats` snapshot for slot engines; ``{}`` otherwise."""
        return {}


# -- simulated API engine ---------------------------------------------------------


def simulated_answer(prompt: str, max_tokens: int, model_name: str) -> str:
    """Deterministic response text — a pure function of (prompt, model) —
    shared by every simulated engine so the coalescing/caching layers can
    be validated byte-for-byte across execution strategies."""
    h = hashlib.sha256(prompt.encode()).hexdigest()
    hv = int(h[:8], 16)
    if prompt.startswith("[Judge]"):
        # deterministic judge behaviour, with a rare malformed response
        # (exercises the unparseable-logging path; paper §5.6 saw 0.12%)
        if hv % 797 == 0:
            return "I cannot assess this response."
        if "Winner:" in prompt or "Response A:" in prompt:
            return f"Winner: {'A' if hv % 2 == 0 else 'B'} — clearer answer."
        scale = 5
        m = re.search(r"1-(\d+) scale", prompt)
        if m:
            scale = int(m.group(1))
        # content-sensitive: degraded responses ("flub" fillers from
        # low-tier simulated models) score lower, plus mild hash noise —
        # so judge metrics track real quality differences
        m2 = re.search(r"Response: (.*)", prompt, re.DOTALL)
        resp = m2.group(1) if m2 else ""
        flubs = resp.count("flub")
        score = max(1, min(scale, scale - flubs + (hv % 2)))
        return f"Score: {score}. Concise and mostly accurate."
    words = prompt.split()
    # deterministic "answer": echo of salient words + hash suffix.
    # Quality scales with the (simulated) model tier so model
    # comparisons observe real, stable differences.
    quality = _MODEL_QUALITY.get(model_name, 0.8)
    salient = [w for w in words if len(w) > 3][: max(3, max_tokens // 4)]
    kept = []
    for i, w in enumerate(salient):
        wh = int(hashlib.sha256(f"{w}{i}{h[:4]}".encode()).hexdigest()[:4], 16)
        if (wh % 1000) / 1000.0 < quality:
            kept.append(w)
        else:
            kept.append(f"flub{wh % 97}")
    return " ".join(kept + [f"ans_{h[:8]}"])


class SimulatedAPIEngine(InferenceEngine):
    """Deterministic provider stand-in.

    Latency = base + per-token * output_tokens (+ deterministic jitter from
    the prompt hash).  Responses are a deterministic transform of the
    prompt, so caching benchmarks observe real hit/miss behaviour.  Set
    ``wall_clock=False`` to account latency without sleeping (fast
    benchmarks compute throughput from accounted latency).
    """

    def __init__(
        self,
        model: EngineModelConfig,
        *,
        base_latency_ms: float = 250.0,
        per_token_ms: float = 0.6,
        wall_clock: bool = False,
        fail_every: int = 0,  # inject a recoverable failure every N calls
    ):
        self.model = model
        self.base_latency_ms = base_latency_ms
        self.per_token_ms = per_token_ms
        self.wall_clock = wall_clock
        self.fail_every = fail_every
        self.calls = 0
        self.total_cost = 0.0
        self.initialized = False
        # counter updates must not lose increments when shards from
        # several concurrent chunks share one simulated engine
        self._counter_lock = threading.Lock()

    def initialize(self) -> None:
        self.initialized = True

    def shutdown(self) -> None:
        self.initialized = False

    @staticmethod
    def _count_tokens(text: str) -> int:
        return max(1, len(text.split()))

    def _respond(self, prompt: str, max_tokens: int) -> str:
        return simulated_answer(prompt, max_tokens, self.model.model_name)

    def infer(self, request: InferenceRequest) -> InferenceResponse:
        with self._counter_lock:
            self.calls += 1
            call_no = self.calls
        if self.fail_every and call_no % self.fail_every == 0:
            return InferenceResponse(
                text="", input_tokens=0, output_tokens=0,
                latency_ms=self.base_latency_ms, error="rate_limited_429",
            )
        text = self._respond(request.prompt, request.max_tokens)
        in_tok = self._count_tokens(request.prompt)
        out_tok = min(self._count_tokens(text), request.max_tokens)
        jitter = int(hashlib.sha256(request.prompt.encode()).hexdigest()[:4], 16)
        latency = self.base_latency_ms + self.per_token_ms * out_tok + jitter % 50
        if self.wall_clock:
            time.sleep(latency / 1000.0)
        cost = api_cost(self.model.provider, self.model.model_name, in_tok, out_tok)
        with self._counter_lock:
            self.total_cost += cost
        return InferenceResponse(
            text=text, input_tokens=in_tok, output_tokens=out_tok,
            latency_ms=latency, cost_usd=cost,
        )

    def infer_batch(self, requests: list[InferenceRequest]) -> list[InferenceResponse]:
        return [self.infer(r) for r in requests]


# -- simulated slot engine ---------------------------------------------------------

#: nominal KV geometry the slot simulator charges page bytes against —
#: shaped like the reduced qwen3 serving config, so simulated byte
#: budgets and capacity ratios track the real batcher's page economics
SIM_KV_HEADS = 8
SIM_HEAD_DIM = 64
SIM_LAYERS = 4


class SimulatedSlotEngine(InferenceEngine):
    """Deterministic slot-multiplexed decode engine (no JAX): models the
    continuous-batching substrate — ``n_slots`` decode slots advancing one
    token per step at ``step_ms`` — with deterministic texts and output
    lengths, so serving benchmarks measure *scheduling*, not model math.

    ``infer_batch`` is the lock-step path: requests decode in gangs of
    ``n_slots`` and the whole gang drains at its slowest member's length
    (exactly what per-shard ``run_to_completion`` does to the JAX engine,
    and what ``engine.infer`` per prompt degrades to — a gang of one).
    The streaming interface refills slots as they free, which is what the
    :class:`~repro.core.service.InferenceService` batcher loop drives.
    Output lengths are long-tail skewed on purpose: that is the regime
    where lock-step waves pay the straggler price every time.
    """

    supports_streaming = True

    def __init__(
        self,
        model: EngineModelConfig,
        *,
        n_slots: int = 8,
        step_ms: float = 0.5,
        wall_clock: bool = False,
        min_out: int = 4,
        max_out: int = 48,
        max_prefills_per_step: int = 0,
        kv_page_size: int = 0,
        prefix_cache: bool = True,
        prefill_ms_per_token: float = 0.0,
        page_pool: int = 4096,
        page_pool_bytes: int = 0,
        kv_cache_dtype: str = "bf16",
        decode_page_growth: bool = False,
        fault_plan: Any = None,
    ):
        self.model = model
        self.n_slots = n_slots
        self.step_ms = step_ms
        self.wall_clock = wall_clock
        self.min_out = min_out
        self.max_out = max_out
        #: 0 = unlimited; otherwise at most this many queued prompts are
        #: prefilled into free slots per pump (prefill/decode split)
        self.max_prefills_per_step = max_prefills_per_step
        #: simulated prefill cost: each *uncached* prompt token (word)
        #: charges this much wall time at admission, so prefix sharing has
        #: a measurable effect on the streaming path
        self.prefill_ms_per_token = prefill_ms_per_token
        self.kv_page_size = kv_page_size
        #: "bf16" | "int8": accounting-only in the simulator — responses
        #: are pure prompt functions, so quantization changes page *bytes*
        #: (and therefore how many pages a byte budget admits), never text
        self.kv_cache_dtype = kv_cache_dtype
        #: charge one KV page per decoded token past the prompt (the real
        #: batcher's decode growth) so long generations create organic
        #: page pressure — off by default to keep prompt-only accounting
        self.decode_page_growth = decode_page_growth
        if kv_cache_dtype not in ("bf16", "int8"):
            raise ValueError(
                f"kv_cache_dtype must be 'bf16' or 'int8', got "
                f"{kv_cache_dtype!r}"
            )
        if kv_cache_dtype == "int8" and not kv_page_size:
            raise ValueError(
                "kv_cache_dtype='int8' requires a paged cache "
                "(kv_page_size > 0)"
            )
        page_bytes = 0
        if kv_page_size:
            # deferred import: repro.serve.scheduler imports this module
            from repro.serve.paged_cache import (
                PagedCacheManager,
                kv_page_bytes,
                pages_for_budget,
            )

            page_bytes = kv_page_bytes(
                kv_page_size, SIM_KV_HEADS, SIM_HEAD_DIM, SIM_LAYERS,
                kv_cache_dtype,
            )
            if page_pool_bytes:
                # byte-budgeted pool: int8 pages are ~half the bytes, so
                # the same budget admits ~2x pages
                page_pool = pages_for_budget(page_pool_bytes, page_bytes)
            self._pages = PagedCacheManager(
                page_pool, kv_page_size, prefix_cache=prefix_cache,
                page_bytes=page_bytes,
            )
        else:
            self._pages = None
        self.calls = 0
        self.total_cost = 0.0
        self.initialized = False
        self.stats = BatcherStats(n_slots=n_slots)
        if kv_page_size:
            self.stats.kv_bytes_per_token = page_bytes // kv_page_size
            self.stats.pool_pages = self._pages.n_pages
        self._lock = threading.Lock()
        self._next_id = 0
        #: streaming admission queue: (rid, request, out_len)
        self._queue: list[tuple[int, InferenceRequest, int]] = []
        self._slots: list[dict | None] = [None] * n_slots
        self._seen_len_buckets: set[int] = set()
        #: deterministic chaos: a ServingFaultSchedule polled every pump
        #: (replica index claimed in engine creation order)
        self._fault_plan = fault_plan
        self.fault_replica = fault_plan.attach() if fault_plan is not None else 0
        #: monotonic pump counter — survives reset() so later faults on
        #: the same replica still fire at their scheduled step
        self._pumps = 0
        self._hang_until = 0

    def initialize(self) -> None:
        self.initialized = True

    def shutdown(self) -> None:
        self.initialized = False

    def _out_len(self, request: InferenceRequest) -> int:
        h = int(hashlib.sha256(request.prompt.encode()).hexdigest()[8:16], 16)
        span = max(1, self.max_out - self.min_out)
        if h % 6 == 0:  # long tail: ~1 in 6 answers runs near max_out
            n = self.max_out - h % (span // 4 + 1)
        else:
            n = self.min_out + h % (span // 3 + 1)
        return max(1, min(request.max_tokens, n))

    def _response(
        self, request: InferenceRequest, out_len: int, latency_ms: float
    ) -> InferenceResponse:
        text = simulated_answer(
            request.prompt, request.max_tokens, self.model.model_name
        )
        return InferenceResponse(
            text=text,
            input_tokens=max(1, len(request.prompt.split())),
            output_tokens=out_len,
            latency_ms=latency_ms,
        )

    def _account_admission(self, request: InferenceRequest) -> None:
        self.stats.admissions += 1
        b, n = 16, max(1, len(request.prompt.split()))
        while b < n:
            b <<= 1
        if b not in self._seen_len_buckets:
            self._seen_len_buckets.add(b)
            self.stats.prefill_recompiles += 1

    def _account_steps(self, steps: int, active_slot_steps: int) -> None:
        self.stats.steps += steps
        self.stats.active_slot_steps += active_slot_steps
        self.stats.tokens_generated += active_slot_steps

    # -- lock-step path --------------------------------------------------------

    def infer(self, request: InferenceRequest) -> InferenceResponse:
        return self.infer_batch([request])[0]

    def infer_batch(self, requests: list[InferenceRequest]) -> list[InferenceResponse]:
        out: list[InferenceResponse] = []
        with self._lock:
            self.initialize()
            for i in range(0, len(requests), self.n_slots):
                wave = requests[i : i + self.n_slots]
                lens = [self._out_len(r) for r in wave]
                wave_steps = max(lens)
                for r in wave:
                    self._account_admission(r)
                self._account_steps(wave_steps, sum(lens))
                self.stats.completions += len(wave)
                # lock-step pays full prefill for every prompt: the wave
                # has no persistent slots, so nothing survives to share
                prefill_ms = self.prefill_ms_per_token * sum(
                    max(1, len(r.prompt.split())) for r in wave
                )
                if self.wall_clock:
                    time.sleep((wave_steps * self.step_ms + prefill_ms) / 1000.0)
                latency = wave_steps * self.step_ms + prefill_ms
                for r, n in zip(wave, lens):
                    self.calls += 1
                    out.append(self._response(r, n, latency))
        return out

    # -- streaming path --------------------------------------------------------

    def stream_submit(self, request: InferenceRequest) -> int:
        with self._lock:
            self.initialize()
            rid = self._next_id
            self._next_id += 1
            self._queue.append((rid, request, self._out_len(request)))
            return rid

    def stream_pending(self) -> bool:
        with self._lock:
            return bool(self._queue) or any(s is not None for s in self._slots)

    def slots_busy(self) -> int:
        with self._lock:
            return sum(1 for s in self._slots if s is not None) + len(self._queue)

    def _preempt_one_locked(self) -> bool:
        """Evict the victim slot — fewest decoded tokens, index tie-break —
        releasing its pages and requeueing its request at the queue front
        for a full deterministic recompute (byte-identical output)."""
        victims = [
            (s["out"] - s["left"], i)
            for i, s in enumerate(self._slots)
            if s is not None
        ]
        if not victims:
            return False
        decoded, i = min(victims)
        s = self._slots[i]
        if self._pages is not None:
            self._pages.release(s["rid"])
        self._queue.insert(0, (s["rid"], s["req"], s["out"]))
        self._slots[i] = None
        self.stats.preemptions += 1
        self.stats.preempted_tokens += decoded
        return True

    def _page_gate_locked(self) -> bool:
        """Low-watermark admission gate: admit the queue head only if the
        pool covers its worst-case page need while keeping one page per
        busy slot in reserve.  A prompt larger than the whole pool is
        admitted anyway so ``acquire`` raises a clear error instead of
        the request deferring forever."""
        _, req, _ = self._queue[0]
        words = req.prompt.split() or ["<bos>"]
        need = -(-len(words) // self.kv_page_size)
        if need >= self._pages.n_pages:
            return True
        busy = sum(1 for s in self._slots if s is not None)
        return self._pages.pages_free + self._pages.pages_cached >= need + busy

    def _poll_fault_locked(self) -> float:
        """Apply the due scheduled fault, if any; returns extra latency ms
        (``slow_step``).  ``replica_crash`` raises out of the pump."""
        fault = self._fault_plan.poll(self.fault_replica, self._pumps)
        if fault is None:
            return 0.0
        if fault.kind == "replica_crash":
            from repro.ft.failure_sim import SimulatedCrash

            raise SimulatedCrash(
                f"injected replica_crash replica={self.fault_replica} "
                f"pump={self._pumps}"
            )
        if fault.kind == "hang":
            self._hang_until = self._pumps + fault.duration
        elif fault.kind == "page_pressure":
            for _ in range(max(1, fault.duration)):
                if not self._preempt_one_locked():
                    break
        elif fault.kind == "slow_step":
            return fault.delay_s * 1000.0
        return 0.0

    def _grow_decode_pages_locked(self) -> None:
        """Charge each active slot the KV page holding this step's new
        token (the real batcher's ``ensure_position``): long generations
        spill past their prompt pages, so a tight pool preempts under
        *decode* pressure, not just admission pressure.  Pool exhaustion
        preempts the cheapest victim and retries — possibly the growing
        slot itself, in which case its growth is moot this pump."""
        from repro.serve.paged_cache import PagePoolExhausted

        for i, s in enumerate(self._slots):
            if s is None:
                continue
            words = s["req"].prompt.split() or ["<bos>"]
            pos = len(words) + (s["out"] - s["left"])
            while self._slots[i] is not None:
                try:
                    pw = self._pages.ensure_position(s["rid"], pos)
                    if pw.cow_src is not None:
                        self.stats.cow_copies += 1
                    break
                except PagePoolExhausted:
                    if not self._preempt_one_locked():
                        break

    def stream_pump(self) -> list[tuple[int, InferenceResponse]]:
        slow_ms = 0.0
        with self._lock:
            self._pumps += 1
            if self._fault_plan is not None:
                slow_ms = self._poll_fault_locked()
            if self._pumps <= self._hang_until:
                return []  # hung: no admissions, no decode, no progress
            admitted = 0
            prefill_tokens = 0
            for i, s in enumerate(self._slots):
                if s is None and self._queue:
                    if (
                        self.max_prefills_per_step
                        and admitted >= self.max_prefills_per_step
                    ):
                        # each still-queued request a free slot could have
                        # taken this pump defers exactly once per pump it
                        # actually waits (not once per queue neighbour)
                        free_left = sum(
                            1 for s2 in self._slots[i:] if s2 is None
                        )
                        self.stats.prefills_deferred += min(
                            len(self._queue), free_left
                        )
                        break
                    if self._pages is not None and not self._page_gate_locked():
                        # pool pressure: defer the prefill rather than
                        # overcommit pages a decode will need (DESIGN.md §9)
                        free_left = sum(
                            1 for s2 in self._slots[i:] if s2 is None
                        )
                        self.stats.prefills_deferred += min(
                            len(self._queue), free_left
                        )
                        break
                    rid, req, out_len = self._queue.pop(0)
                    self._account_admission(req)
                    admitted += 1
                    words = req.prompt.split() or ["<bos>"]
                    if self._pages is not None:
                        m = self._pages.acquire(rid, words)
                        self._pages.register(rid, words)
                        self.stats.prefix_pages_hit += m.n_shared_pages
                        self.stats.prefix_tokens_saved += m.n_shared_tokens
                        prefill_tokens += len(words) - m.n_shared_tokens
                    else:
                        prefill_tokens += len(words)
                    self._slots[i] = {
                        "rid": rid, "req": req, "left": out_len,
                        "out": out_len, "start_step": self.stats.steps,
                    }
            n_active = sum(1 for s in self._slots if s is not None)
            if not n_active:
                return []
        if self.wall_clock:
            # sleep outside the lock: direct infer calls (judges, legacy
            # paths) interleave between steps instead of stalling behind one
            time.sleep(
                (self.step_ms + self.prefill_ms_per_token * prefill_tokens
                 + slow_ms)
                / 1000.0
            )
        done: list[tuple[int, InferenceResponse]] = []
        with self._lock:
            if self._pages is not None and self.decode_page_growth:
                self._grow_decode_pages_locked()
            self._account_steps(1, n_active)
            for i, s in enumerate(self._slots):
                if s is None:
                    continue
                s["left"] -= 1
                if s["left"] <= 0:
                    latency = (self.stats.steps - s["start_step"]) * self.step_ms
                    self.calls += 1
                    self.stats.completions += 1
                    if self._pages is not None:
                        self._pages.release(s["rid"])
                    done.append(
                        (s["rid"], self._response(s["req"], s["out"], latency))
                    )
                    self._slots[i] = None
        return done

    def stream_cancel(self, rid: int) -> bool:
        with self._lock:
            for i, (qid, _req, _out) in enumerate(self._queue):
                if qid == rid:
                    del self._queue[i]
                    return True
            for i, s in enumerate(self._slots):
                if s is not None and s["rid"] == rid:
                    if self._pages is not None:
                        self._pages.release(rid)
                    self._slots[i] = None
                    return True
        return False

    def reset(self) -> None:
        """Replica-restart hook: drop queued and slotted requests and
        their pages; cumulative stats and the pump counter survive (the
        fault schedule stays aligned to engine lifetime, not incarnation)."""
        with self._lock:
            if self._pages is not None:
                self._pages.release_all()
            self._queue.clear()
            self._slots = [None] * self.n_slots
            self._hang_until = 0
            self.initialized = True

    def serving_stats(self) -> dict:
        with self._lock:
            return self.stats.as_dict()


# -- local JAX engine ----------------------------------------------------------------


class LocalJaxEngine(InferenceEngine):
    """Serve an assigned architecture via the continuous-batching scheduler.

    Two entry points share one scheduler:

    * ``infer_batch`` — legacy lock-step: submit a batch, drain it to
      completion under the engine lock (concurrent callers serialize);
    * ``stream_submit``/``stream_pump`` — persistent streaming, driven by
      the :class:`~repro.core.service.InferenceService` batcher loop:
      prompts are admitted into decode slots as slots free, so batches
      form across shards, chunks, tasks and suites.

    Greedy decode (temperature 0) is batch-composition independent, so
    both paths produce identical tokens for a given prompt.
    """

    supports_streaming = True

    def __init__(self, model: EngineModelConfig, *, n_slots: int = 8,
                 max_len: int = 256, devices: Any = None,
                 max_prefills_per_step: int = 0,
                 kv_page_size: int = 0, prefix_cache: bool = True,
                 page_pool: int = 0, page_pool_bytes: int = 0,
                 kv_cache_dtype: str = "bf16", fault_plan: Any = None):
        self.model_cfg = model
        self.n_slots = n_slots
        self.max_len = max_len
        #: replica placement: None = default device; one device = pinned
        #: data-parallel replica; several devices = tensor-parallel replica
        #: over a ("data","model") mesh built from this group
        self.devices = tuple(devices) if devices else None
        self.max_prefills_per_step = max_prefills_per_step
        #: 0 = contiguous per-slot KV cache; > 0 = paged pool (page size)
        self.kv_page_size = kv_page_size
        self.prefix_cache = prefix_cache
        #: 0 = auto-sized pool (worst case, never exhausts); > 0 pins the
        #: pool small enough that decode pressure triggers preemption
        self.page_pool = page_pool
        #: byte-budgeted alternative to ``page_pool`` (pages = budget //
        #: page bytes, scale buffers included)
        self.page_pool_bytes = page_pool_bytes
        #: "bf16" = full-precision pool pages; "int8" = absmax-quantized
        #: pages + scales, dequantized at the decode gather (DESIGN.md §10)
        self.kv_cache_dtype = kv_cache_dtype
        self._fault_plan = fault_plan
        self.fault_replica = fault_plan.attach() if fault_plan is not None else 0
        self.initialized = False
        self._scheduler = None
        self._tokenizer = None
        self._next_id = 0
        # worker threads share one scheduler; it is the batching layer, so
        # concurrent infer_batch calls serialize (slots multiplex inside)
        self._lock = threading.Lock()

    def initialize(self) -> None:
        if self.initialized:
            return
        import jax

        from repro.configs import get_config
        from repro.data.tokenizer import HashTokenizer
        from repro.models import params as pm
        from repro.models.model import build_model
        from repro.serve.scheduler import ContinuousBatcher

        cfg = get_config(self.model_cfg.model_name)
        if self.model_cfg.reduced:
            cfg = cfg.reduced()
        self._cfg = cfg
        self._tokenizer = HashTokenizer(cfg.vocab_size)
        model = build_model(cfg, remat="none")
        params = pm.init_params(
            jax.random.key(self.model_cfg.seed), model.param_specs()
        )
        device = rules = None
        if self.devices and len(self.devices) == 1:
            device = self.devices[0]
        elif self.devices:
            from repro.launch.mesh import make_replica_mesh
            from repro.sharding import SERVE_RULES, ShardingRules

            rules = ShardingRules(
                SERVE_RULES, make_replica_mesh(self.devices)
            )
        self._scheduler = ContinuousBatcher(
            model, cfg, params,
            n_slots=self.n_slots, max_len=self.max_len,
            eos_id=self._tokenizer.eos_id,
            temperature=self.model_cfg.temperature,
            max_prefills_per_step=self.max_prefills_per_step,
            device=device, rules=rules,
            page_size=self.kv_page_size, prefix_cache=self.prefix_cache,
            page_pool=self.page_pool, page_pool_bytes=self.page_pool_bytes,
            kv_cache_dtype=self.kv_cache_dtype,
        )
        if self._fault_plan is not None:
            self._scheduler.fault_hook = self._fault_plan.as_hook(
                self.fault_replica
            )
        self.initialized = True

    def shutdown(self) -> None:
        self._scheduler = None
        self.initialized = False

    def infer(self, request: InferenceRequest) -> InferenceResponse:
        return self.infer_batch([request])[0]

    def _submit_locked(self, request: InferenceRequest) -> int:
        from repro.serve.scheduler import Request

        self.initialize()
        rid = self._next_id
        self._next_id += 1
        toks = self._tokenizer.encode(request.prompt)[: self.max_len // 2]
        self._scheduler.submit(
            Request(
                request_id=rid,
                prompt_tokens=toks or [self._tokenizer.bos_id],
                max_new_tokens=min(
                    request.max_tokens, self.max_len - len(toks) - 1
                ),
            )
        )
        return rid

    def _completion_response(self, c) -> InferenceResponse:
        return InferenceResponse(
            text=self._tokenizer.decode(c.tokens),
            input_tokens=c.prompt_len,
            output_tokens=len(c.tokens),
            latency_ms=c.latency_s * 1000.0,
        )

    def infer_batch(self, requests: list[InferenceRequest]) -> list[InferenceResponse]:
        with self._lock:
            return self._infer_batch_locked(requests)

    def _infer_batch_locked(
        self, requests: list[InferenceRequest]
    ) -> list[InferenceResponse]:
        t0 = time.monotonic()
        id_map: dict[int, int] = {}
        for i, r in enumerate(requests):
            id_map[self._submit_locked(r)] = i
        completions = self._scheduler.run_to_completion()
        # the drain may have carried service-submitted streaming requests
        # to completion too; leave those for the next stream_pump
        self._scheduler.completions = [
            c for c in completions if c.request_id not in id_map
        ]
        out: list[InferenceResponse | None] = [None] * len(requests)
        for c in completions:
            if c.request_id not in id_map:
                continue
            out[id_map[c.request_id]] = self._completion_response(c)
        dt = time.monotonic() - t0
        for i, r in enumerate(out):
            if r is None:  # pragma: no cover
                out[i] = InferenceResponse(
                    text="", input_tokens=0, output_tokens=0,
                    latency_ms=dt * 1000.0, error="lost",
                )
        return out  # type: ignore[return-value]

    # -- persistent streaming (InferenceService batcher loop) -------------------

    def stream_submit(self, request: InferenceRequest) -> int:
        with self._lock:
            return self._submit_locked(request)

    def stream_pump(self) -> list[tuple[int, InferenceResponse]]:
        with self._lock:
            sched = self._scheduler
            if sched is None:
                return []
            if sched.queue or sched.slots_busy:
                sched.step()
            return [
                (c.request_id, self._completion_response(c))
                for c in sched.drain_completions()
            ]

    def stream_pending(self) -> bool:
        with self._lock:
            sched = self._scheduler
            return bool(
                sched
                and (sched.queue or sched.slots_busy or sched.completions)
            )

    def slots_busy(self) -> int:
        with self._lock:
            sched = self._scheduler
            if sched is None:
                return 0
            return sched.slots_busy + len(sched.queue)

    def stream_cancel(self, rid: int) -> bool:
        with self._lock:
            sched = self._scheduler
            return bool(sched) and sched.cancel(rid)

    def reset(self) -> None:
        """Replica-restart hook: rebuild the scheduler (fresh slots, fresh
        page pool).  Cheap relative to a lost replica; the fault hook is
        re-attached by ``initialize`` so scheduled faults keep firing."""
        with self._lock:
            self._scheduler = None
            self.initialized = False
            self.initialize()

    def serving_stats(self) -> dict:
        with self._lock:
            if self._scheduler is None:
                return {}
            return self._scheduler.stats.as_dict()


# -- registry (Listing 1) ------------------------------------------------------------


def create_engine(model: EngineModelConfig, **kw: Any) -> InferenceEngine:
    if model.provider == "local":
        return LocalJaxEngine(model, **kw)
    if model.provider == "slotsim":
        return SimulatedSlotEngine(model, **kw)
    return SimulatedAPIEngine(model, **kw)


class EngineRegistry:
    """One initialized engine per :class:`EngineModelConfig` (+ extra
    constructor kwargs).  The paper's Listing-1 ``_ENGINE_CACHE`` pattern,
    made an owned object so an :class:`~repro.core.session.EvalSession`
    amortizes initialization across every task it runs — in JAX terms:
    compile once, execute many.
    """

    def __init__(self) -> None:
        self._engines: dict[
            tuple[EngineModelConfig, int, str], InferenceEngine
        ] = {}
        self.initializations = 0
        # concurrent chunk workers may request the same engine at once;
        # initialization must happen exactly once per config
        self._lock = threading.Lock()

    def get(
        self, model: EngineModelConfig, *, replica: int = 0, **kw: Any
    ) -> InferenceEngine:
        """``replica`` distinguishes otherwise-identical data-parallel
        engine instances: replica i of a model is its own engine (own
        batcher, own decode slots), while repeated lookups of the same
        (model, replica, kwargs) still amortize to one initialization."""
        key = (model, replica, json.dumps(kw, sort_keys=True, default=str))
        with self._lock:
            engine = self._engines.get(key)
            if engine is None:
                engine = create_engine(model, **kw)
                engine.initialize()
                self.initializations += 1
                self._engines[key] = engine
        return engine

    def shutdown(self) -> None:
        for engine in self._engines.values():
            engine.shutdown()
        self._engines.clear()

    def __len__(self) -> int:
        return len(self._engines)

    def __contains__(self, model: EngineModelConfig) -> bool:
        return any(k[0] == model for k in self._engines)

    def engines(self) -> list[InferenceEngine]:
        return list(self._engines.values())


_PROCESS_REGISTRY = EngineRegistry()


def get_engine(
    model: EngineModelConfig, inference: InferenceConfig, **kw: Any
) -> InferenceEngine:
    """Process-global engine lookup (legacy); sessions own their own
    :class:`EngineRegistry` instead."""
    del inference  # engines depend only on the model config + kwargs
    return _PROCESS_REGISTRY.get(model, **kw)


#: provider error codes worth retrying (429/5xx; paper §A.4)
RECOVERABLE_ERROR_CODES = ("429", "500", "502", "503")


def is_recoverable(error: str | None) -> bool:
    return error is not None and any(
        code in error for code in RECOVERABLE_ERROR_CODES
    )


def retry_with_backoff(
    fn, *, max_retries: int = 3, base_delay: float = 1.0,
    sleep=time.sleep,
):
    """Exponential backoff for recoverable errors (paper §A.4): the
    429/5xx error strings and :class:`RecoverableEngineError`.  Any other
    exception — a programming error like ``ValueError`` — propagates
    immediately with its original traceback instead of burning the
    backoff budget."""
    last: InferenceResponse | None = None
    for attempt in range(max_retries + 1):
        try:
            resp = fn()
        except RecoverableEngineError:
            if attempt >= max_retries:
                raise
            sleep(base_delay * math.pow(2.0, attempt))
            continue
        if resp.error is None:
            return resp
        if not is_recoverable(resp.error):
            return resp
        last = resp
        if attempt < max_retries:
            sleep(base_delay * math.pow(2.0, attempt))
    return last
