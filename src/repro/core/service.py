"""Shared asynchronous inference service: cross-task continuous batching
with single-flight request coalescing, fanned out across N data-parallel
engine replicas.

Before this module, inference was lock-step per shard: every pipeline
stage blocked on its own ``engine.infer`` calls, the local JAX engine
serialized concurrent callers behind one lock (decode slots idling
whenever a caller's batch had fewer requests than slots), and two
concurrent chunk workers that missed the cache on the same prompt both
paid for the call — the duplicate-spend the content-addressable cache
exists to prevent, leaking back in through concurrency.  The service
inverts control: tasks, chunks, models and suites **submit**
:class:`~repro.core.engines.InferenceRequest` objects and get tickets
(futures) back; dispatch happens centrally —

* **single-flight coalescing** — identical in-flight cache keys share ONE
  engine call.  The first submitter is the *primary* (its shard is
  charged the call, the cost, the tokens, and the cache write); later
  submitters become waiters on the same flight and are counted as
  ``coalesced``.  The cache prevents duplicate spend across time;
  single-flight closes the concurrency window the cache cannot see.
  The flight table is **global across replicas**: a duplicate coalesces
  onto the original flight no matter which replica serves it.
* **central admission** — the per-task rate limiter is acquired by the
  dispatcher immediately before the engine call, not by worker threads
  sleeping inside the pipeline, so budget flows to whatever is runnable.
* **continuous batching** — engines exposing the slot-streaming interface
  (``supports_streaming``: the local JAX engine, the simulated slot
  engine) are driven by ONE persistent batcher loop *per replica*:
  queued prompts are admitted into decode slots as slots free, so
  batches form across shards, chunks, tasks and suites instead of
  inside one shard.  API-style engines get a dispatcher-thread pool per
  replica instead, sized by the pipeline stages currently attached.
* **replica routing** — with ``n_replicas > 1`` one submit queue fans
  out to N engine replicas through a :class:`ReplicaRouter`.  Policies:
  ``least_loaded`` (fewest outstanding requests — busy decode slots plus
  backlog), ``prefix_affinity`` (prompt-prefix hash, so shared few-shot
  headers land on the same batcher and its warmed prefixes), and
  ``round_robin``.  Routing is *stats-plane-invisible*: responses are a
  pure function of the request, so placement never changes a byte of
  evaluation output (see the determinism contract below).

Determinism contract: responses are a pure function of the request key
(prompt, model, provider, temperature, max_tokens) — simulated engines by
construction, the local engine because greedy decode at temperature 0 is
batch-composition independent.  Coalescing and routing therefore never
change a response byte; they only change how many engine calls paid for
it and which replica served it.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import queue
import threading
import time
from typing import Any, Callable, Sequence

from repro.core.engines import (
    InferenceEngine,
    InferenceRequest,
    InferenceResponse,
    is_recoverable,
    retry_with_backoff,
)
from repro.core.ratelimit import AdaptiveLimiter

_SENTINEL = object()


class _Flight:
    """One engine call and its waiters (single-flight unit)."""

    __slots__ = ("key", "event", "response", "exc", "attempts")

    def __init__(self, key: str):
        self.key = key
        self.event = threading.Event()
        self.response: InferenceResponse | None = None
        self.exc: BaseException | None = None
        self.attempts = 0


class ServiceTicket:
    """Future for one submitted request.  ``primary`` is True for the
    submission that owns the engine call (and therefore the spend); a
    coalesced follower shares the response but owns nothing."""

    __slots__ = ("_flight", "primary")

    def __init__(self, flight: _Flight, primary: bool):
        self._flight = flight
        self.primary = primary

    def done(self) -> bool:
        return self._flight.event.is_set()

    @property
    def attempts(self) -> int:
        """Engine-call attempts the flight took (retries included)."""
        return self._flight.attempts

    def result(self, timeout: float | None = None) -> InferenceResponse:
        if not self._flight.event.wait(timeout):
            raise TimeoutError(
                f"inference ticket not resolved within {timeout}s"
            )
        if self._flight.exc is not None:
            raise self._flight.exc
        assert self._flight.response is not None
        return self._flight.response


@dataclasses.dataclass
class _Submission:
    flight: _Flight
    request: InferenceRequest
    limiter: Any
    est_tokens: float
    max_retries: int
    retry_delay: float
    replica: "_Replica | None" = None


@dataclasses.dataclass
class ServiceStats:
    submitted: int = 0
    coalesced: int = 0
    dispatched: int = 0   # engine-call attempts actually issued
    completed: int = 0
    retries: int = 0
    errors: int = 0

    @property
    def dedup_rate(self) -> float:
        return self.coalesced / self.submitted if self.submitted else 0.0

    def as_dict(self) -> dict:
        return {
            "submitted": self.submitted,
            "dispatched": self.dispatched,
            "coalesced": self.coalesced,
            "completed": self.completed,
            "retries": self.retries,
            "errors": self.errors,
            "dedup_rate": round(self.dedup_rate, 4),
        }


# -- replicas -------------------------------------------------------------------


class _Replica:
    """One engine replica behind the shared submit front: its own FIFO
    queue, its own dispatcher threads (one batcher loop for slot engines,
    a thread pool for API engines), and its own ServiceStats slice.
    Counter fields are guarded by the owning service's lock."""

    __slots__ = (
        "index", "engine", "queue", "wake", "threads",
        "routed", "outstanding", "dispatched", "completed", "errors",
        "broken",
    )

    def __init__(self, index: int, engine: InferenceEngine, depth: int):
        self.index = index
        self.engine = engine
        self.queue: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self.wake = threading.Event()
        self.threads: list[threading.Thread] = []
        self.routed = 0        # submissions ever routed here
        self.outstanding = 0   # routed but not yet resolved
        self.dispatched = 0
        self.completed = 0
        self.errors = 0
        self.broken: BaseException | None = None

    def busy_slots(self) -> int:
        sched = getattr(self.engine, "slots_busy", None)
        if callable(sched):
            return sched()
        return 0

    def stats_dict(self) -> dict:
        d = {
            "index": self.index,
            "routed": self.routed,
            "outstanding": self.outstanding,
            "dispatched": self.dispatched,
            "completed": self.completed,
            "errors": self.errors,
            "broken": self.broken is not None,
        }
        batcher = self.engine.serving_stats()
        if batcher:
            d["batcher"] = batcher
        return d


@dataclasses.dataclass(frozen=True)
class ReplicaView:
    """Router-visible load snapshot of one (alive) replica."""

    index: int
    queued: int        # submissions waiting in the replica's service queue
    outstanding: int   # routed but unresolved (includes in-engine backlog)
    busy_slots: int = 0

    @property
    def load(self) -> int:
        return self.queued + self.outstanding


class ReplicaRouter:
    """Pluggable replica-placement policy.

    ``route`` picks among the *alive* replicas only; ties break on the
    lowest index so placement is deterministic given fixed stats.

    * ``least_loaded`` — fewest outstanding requests (busy decode slots
      plus queued backlog; the service counts routed-but-unresolved, which
      covers both).
    * ``prefix_affinity`` — stable hash of the first ``prefix_len``
      characters of the prompt: requests sharing a few-shot header or
      system prompt land on the same batcher (and, downstream, the same
      warmed prefix cache), independent of load.
    * ``round_robin`` — strict rotation.
    """

    POLICIES = ("least_loaded", "prefix_affinity", "round_robin")

    def __init__(self, policy: str = "least_loaded", prefix_len: int = 64):
        if policy not in self.POLICIES:
            raise ValueError(
                f"unknown routing policy {policy!r}; one of {self.POLICIES}"
            )
        self.policy = policy
        self.prefix_len = prefix_len
        self._rr = 0  # guarded by the owning service's lock

    def route(self, prompt: str, views: Sequence[ReplicaView]) -> int:
        """Replica index for ``prompt`` among the given (alive) views."""
        if not views:
            raise RuntimeError("no alive replicas to route to")
        if len(views) == 1:
            return views[0].index
        if self.policy == "least_loaded":
            return min(views, key=lambda v: (v.load, v.index)).index
        if self.policy == "prefix_affinity":
            h = hashlib.sha256(
                prompt[: self.prefix_len].encode("utf-8", "replace")
            ).digest()
            return views[int.from_bytes(h[:8], "big") % len(views)].index
        pick = views[self._rr % len(views)].index
        self._rr += 1
        return pick


def aggregate_batcher_stats(parts: Sequence[dict]) -> dict:
    """Fleet-level BatcherStats: counters sum across replicas; occupancy
    is re-derived as total active slot-steps over total slot-step
    capacity, and tokens/step is per (replica, step)."""
    parts = [p for p in parts if p]
    if not parts:
        return {}
    agg = {
        k: sum(p.get(k, 0) for p in parts)
        for k in (
            "n_slots", "steps", "admissions", "completions",
            "tokens_generated", "active_slot_steps", "prefill_recompiles",
            "prefills_deferred", "prefix_pages_hit", "prefix_tokens_saved",
            "cow_copies",
        )
    }
    cap = sum(p.get("steps", 0) * p.get("n_slots", 0) for p in parts)
    agg["slot_occupancy"] = round(
        agg["active_slot_steps"] / cap if cap else 0.0, 4
    )
    agg["tokens_per_step"] = round(
        agg["tokens_generated"] / agg["steps"] if agg["steps"] else 0.0, 3
    )
    return agg


class InferenceService:
    """Session-owned asynchronous dispatch front for one engine (or N
    data-parallel replicas of it).

    ``submit`` never blocks on inference (only on queue backpressure at
    ``queue_depth`` outstanding requests per replica);
    ``ServiceTicket.result`` gathers.  Construction is cheap — dispatcher
    threads start lazily on first use and are joined by :meth:`close`.
    """

    #: absolute ceiling on dispatcher threads per replica (the rate
    #: limiter, not the thread count, is the real admission control)
    HARD_MAX_DISPATCHERS = 128

    def __init__(
        self,
        engine: InferenceEngine | None = None,
        *,
        engines: Sequence[InferenceEngine] | None = None,
        routing: "str | ReplicaRouter" = "least_loaded",
        queue_depth: int = 256,
        coalesce: bool = True,
        max_batch_wait_ms: float = 2.0,
        n_dispatchers: int = 4,
        sleep: Callable[[float], None] = time.sleep,
        name: str = "",
    ):
        fleet = list(engines) if engines else []
        if engine is not None and not fleet:
            fleet = [engine]
        if not fleet:
            raise ValueError("InferenceService needs at least one engine")
        streaming = {bool(getattr(e, "supports_streaming", False)) for e in fleet}
        if len(streaming) != 1:
            raise ValueError(
                "replica fleet mixes streaming and non-streaming engines"
            )
        #: replica-0 engine, kept as ``self.engine`` for single-replica
        #: callers and introspection compatibility
        self.engine = fleet[0]
        self.coalesce = coalesce
        self.max_batch_wait_ms = max_batch_wait_ms
        self.name = name
        self.stats = ServiceStats()
        self.router = (
            routing if isinstance(routing, ReplicaRouter)
            else ReplicaRouter(routing)
        )
        self._sleep = sleep
        self.replicas = [
            _Replica(i, e, queue_depth) for i, e in enumerate(fleet)
        ]
        self._inflight: dict[str, _Flight] = {}
        self._lock = threading.Lock()
        self._base_dispatchers = max(1, n_dispatchers)
        self._attached = 0
        self._closed = False
        self._broken: BaseException | None = None
        self._streaming = streaming.pop()
        self._uniq = itertools.count()

    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    # -- capacity ---------------------------------------------------------------

    def attach(self, n_workers: int = 1) -> None:
        """A pipeline stage is about to submit: size the dispatch pool for
        its configured parallelism.  Batcher-mode engines need no threads
        beyond one loop per replica — decode slots are the parallelism."""
        with self._lock:
            self._check_open()
            self._attached += max(1, n_workers)
            self._ensure_dispatchers()

    def detach(self, n_workers: int = 1) -> None:
        with self._lock:
            self._attached = max(0, self._attached - max(1, n_workers))
            # threads never shrink: idle dispatchers just block on the queue

    def _threads_per_replica(self) -> int:
        if self._streaming:
            return 1
        return min(
            self.HARD_MAX_DISPATCHERS,
            max(self._base_dispatchers, self._attached),
        )

    def _ensure_dispatchers(self) -> None:  # caller holds self._lock
        target = self._threads_per_replica()
        for rep in self.replicas:
            while len(rep.threads) < target:
                idx = len(rep.threads)
                t = threading.Thread(
                    target=self._batcher_loop if self._streaming
                    else self._dispatch_loop,
                    args=(rep,) if self._streaming else (rep, idx),
                    name=(
                        f"infer-service-{self.name or 'engine'}"
                        f"-r{rep.index}-{idx}"
                    ),
                    daemon=True,
                )
                rep.threads.append(t)
                t.start()

    # -- submission --------------------------------------------------------------

    def _alive_views(self) -> list[ReplicaView]:  # caller holds self._lock
        return [
            ReplicaView(
                index=r.index,
                queued=r.queue.qsize(),
                outstanding=r.outstanding,
                busy_slots=r.busy_slots(),
            )
            for r in self.replicas
            if r.broken is None
        ]

    def submit(
        self,
        request: InferenceRequest,
        *,
        key: str | None = None,
        coalesce: bool | None = None,
        limiter: Any = None,
        est_tokens: float = 0.0,
        max_retries: int = 0,
        retry_delay: float = 1.0,
    ) -> ServiceTicket:
        """Enqueue a request; returns a :class:`ServiceTicket` immediately.

        ``key`` is the content-addressable identity of the request (the
        response-cache key); identical in-flight keys coalesce into one
        engine call unless coalescing is off — the flight table is checked
        *before* routing, so the dedup is global across replicas.
        ``limiter`` (an :class:`~repro.core.ratelimit.AdaptiveLimiter` or
        a list of :class:`~repro.core.ratelimit.TokenBucket`) is acquired
        by the dispatcher right before the engine call."""
        do_coalesce = self.coalesce if coalesce is None else coalesce
        if key is None:
            do_coalesce = False
            key = f"~uniq-{next(self._uniq)}"
        with self._lock:
            self._check_open()
            self.stats.submitted += 1
            if do_coalesce:
                flight = self._inflight.get(key)
                if flight is not None:
                    self.stats.coalesced += 1
                    return ServiceTicket(flight, primary=False)
            views = self._alive_views()
            if not views:
                self.stats.submitted -= 1
                raise RuntimeError(
                    f"InferenceService {self.name!r}: all "
                    f"{self.n_replicas} replicas failed "
                    f"(first failure: {self.replicas[0].broken!r})"
                )
            flight = _Flight(key)
            if do_coalesce:
                self._inflight[key] = flight
            rep = self.replicas[self.router.route(request.prompt, views)]
            rep.routed += 1
            rep.outstanding += 1
            self._ensure_dispatchers()
        # outside the lock: a full replica queue blocks the submitter
        # (backpressure), never the dispatchers
        rep.queue.put(
            _Submission(
                flight, request, limiter, est_tokens, max_retries,
                retry_delay, replica=rep,
            )
        )
        rep.wake.set()
        with self._lock:
            dead_now = (
                self._closed or self._broken is not None
                or rep.broken is not None
            )
        if dead_now:
            # close() (or a dispatcher crash) may have drained the queue
            # between our open-check and the put: nobody will read this
            # submission, so fail it — and any fellow stragglers — rather
            # than strand the waiters.  During normal operation this
            # branch is unreachable.
            self._drain_replica(
                rep, exc=rep.broken or RuntimeError("InferenceService closed")
            )
        return ServiceTicket(flight, primary=True)

    def note_coalesced(self, n: int = 1) -> None:
        """Record submissions deduplicated *before* reaching the service
        (e.g. a stage reusing its own ticket for a repeated key), so
        service-level dedup counters reflect total demand."""
        with self._lock:
            self.stats.submitted += n
            self.stats.coalesced += n

    # -- dispatch ---------------------------------------------------------------

    def _admit(self, sub: _Submission, widx: int) -> None:
        lim = sub.limiter
        if lim is None:
            return
        if isinstance(lim, AdaptiveLimiter):
            lim.acquire(widx % lim.n, sub.est_tokens)
        elif isinstance(lim, (list, tuple)):
            lim[widx % len(lim)].acquire(sub.est_tokens)
        else:
            lim.acquire(sub.est_tokens)

    def _resolve(
        self,
        sub_or_flight: "_Submission | _Flight",
        response: InferenceResponse | None = None,
        exc: BaseException | None = None,
    ) -> None:
        if isinstance(sub_or_flight, _Submission):
            flight = sub_or_flight.flight
            rep = sub_or_flight.replica
        else:
            flight, rep = sub_or_flight, None
        with self._lock:
            self._inflight.pop(flight.key, None)
            self.stats.completed += 1
            self.stats.retries += max(0, flight.attempts - 1)
            failed = exc is not None or (
                response is not None and response.error is not None
            )
            if failed:
                self.stats.errors += 1
            if rep is not None:
                rep.outstanding = max(0, rep.outstanding - 1)
                rep.completed += 1
                if failed:
                    rep.errors += 1
        flight.response = response
        flight.exc = exc
        flight.event.set()

    def _count_dispatch(self, rep: _Replica) -> None:
        with self._lock:
            self.stats.dispatched += 1
            rep.dispatched += 1

    def _dispatch_loop(self, rep: _Replica, widx: int) -> None:
        """Thread-pool dispatch for API-style engines: one request per
        engine call against this thread's replica, retries via
        :func:`retry_with_backoff`.

        After each call the loop opportunistically drains further queued
        submissions without re-blocking — one condition-variable wakeup
        can serve a whole burst, which matters for fast engines where the
        wakeup itself dominates.  Exactly one stop sentinel is consumed
        per dispatcher (the loop returns the moment it sees one), so
        every dispatcher thread still shuts down."""
        while True:
            item = rep.queue.get()
            while True:
                if item is _SENTINEL:
                    return
                sub: _Submission = item
                try:
                    self._admit(sub, widx)

                    def _call(sub=sub) -> InferenceResponse:
                        sub.flight.attempts += 1
                        self._count_dispatch(rep)
                        return rep.engine.infer(sub.request)

                    resp = retry_with_backoff(
                        _call,
                        max_retries=sub.max_retries,
                        base_delay=sub.retry_delay,
                        sleep=self._sleep,
                    )
                    self._resolve(sub, resp)
                except BaseException as e:  # noqa: BLE001 — waiters must wake
                    self._resolve(sub, exc=e)
                try:
                    item = rep.queue.get_nowait()
                except queue.Empty:
                    break

    def _batcher_loop(self, rep: _Replica) -> None:
        """Persistent continuous-batching loop for one slot-streaming
        replica: admit queued prompts into decode slots as slots free,
        step, deliver completions — one loop per replica, shared by every
        task the session runs.

        Recoverable errors re-admit with exponential backoff through a
        scheduled-retry list (the loop itself must never sleep — other
        slots are decoding); with a no-op injected sleep (virtual-clock
        sessions) retries are immediate, matching the lock-step path's
        behaviour under the same injection.  The rate-limiter index
        round-robins across admissions so list-mode buckets grant their
        full aggregate budget.

        A dying loop fails only ITS replica: pending/queued tickets get
        the exception, the replica is marked broken so the router stops
        placing work on it, and the service stays up as long as one
        replica survives."""
        engine = rep.engine
        pending: dict[int, _Submission] = {}
        retry_at: list[tuple[float, _Submission]] = []
        wait_s = max(0.0, self.max_batch_wait_ms) / 1000.0
        real_sleep = self._sleep is time.sleep
        stop = False
        admit_rr = 0

        def _dispatch(sub: _Submission) -> None:
            nonlocal admit_rr
            try:
                self._admit(sub, admit_rr)
                admit_rr += 1
                sub.flight.attempts += 1
                self._count_dispatch(rep)
                pending[engine.stream_submit(sub.request)] = sub
            except BaseException as e:
                # the in-hand submission is in neither `pending` nor the
                # queue — fail its flight here or its waiters hang; the
                # outer handler then fails everything else
                self._resolve(sub, exc=e)
                raise

        try:
            while True:
                was_idle = not pending
                admitted = 0
                if retry_at:
                    # pop one at a time: if a dispatch raises, the entries
                    # not yet reached are still in retry_at and the crash
                    # handler below can fail their flights
                    now = time.monotonic()
                    i = 0
                    while i < len(retry_at):
                        if retry_at[i][0] <= now:
                            _, sub_r = retry_at.pop(i)
                            _dispatch(sub_r)
                            admitted += 1
                        else:
                            i += 1
                while True:
                    try:
                        item = rep.queue.get_nowait()
                    except queue.Empty:
                        break
                    if item is _SENTINEL:
                        stop = True
                        break
                    _dispatch(item)
                    admitted += 1
                if stop and not pending and not retry_at:
                    return
                if not pending:
                    rep.wake.clear()
                    rep.wake.wait(timeout=0.005 if retry_at else 0.05)
                    continue
                if was_idle and admitted and wait_s and not stop:
                    # batch-formation window: a cold batcher waits briefly
                    # for co-submitted prompts before spinning up decode
                    # (injected sleep — a no-op under virtual clocks)
                    self._sleep(wait_s)
                    continue
                for rid, resp in engine.stream_pump():
                    sub2 = pending.pop(rid, None)
                    if sub2 is None:
                        continue
                    if (
                        is_recoverable(resp.error)
                        and sub2.flight.attempts <= sub2.max_retries
                    ):
                        delay = (
                            sub2.retry_delay
                            * 2.0 ** (sub2.flight.attempts - 1)
                            if real_sleep
                            else 0.0
                        )
                        retry_at.append((time.monotonic() + delay, sub2))
                        continue
                    self._resolve(sub2, resp)
        except BaseException as e:  # noqa: BLE001
            # replica-failure drain: a dying batcher loop fails every
            # outstanding ticket IT owns instead of stranding its waiters,
            # and quarantines the replica from further routing.  Only when
            # the whole fleet is dead does the service itself go broken.
            with self._lock:
                rep.broken = e
                if all(r.broken is not None for r in self.replicas):
                    self._broken = e
            for sub3 in pending.values():
                self._resolve(sub3, exc=e)
            for _, sub3 in retry_at:
                self._resolve(sub3, exc=e)
            self._drain_replica(rep, exc=e)
            # handled: every waiter got the exception and the router now
            # skips this replica — exit the loop thread cleanly

    # -- lifecycle ---------------------------------------------------------------

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("InferenceService is closed")
        if self._broken is not None:
            raise RuntimeError(
                f"InferenceService dispatch failed: {self._broken!r}"
            )

    def _drain_replica(self, rep: _Replica, exc: BaseException) -> None:
        """Fail every submission queued on one replica; stop sentinels are
        preserved (re-enqueued) so dispatchers racing this drain still
        shut down."""
        sentinels = 0
        while True:
            try:
                item = rep.queue.get_nowait()
            except queue.Empty:
                break
            if item is _SENTINEL:
                sentinels += 1
            else:
                self._resolve(item, exc=exc)
        for _ in range(sentinels):
            rep.queue.put(_SENTINEL)

    def _drain_queue(self, exc: BaseException) -> None:
        for rep in self.replicas:
            self._drain_replica(rep, exc=exc)

    def close(self, timeout: float = 30.0) -> None:
        """Drain and stop: queued work is dispatched to completion (FIFO —
        the stop sentinels sit behind it), in-flight decode finishes, then
        dispatcher threads exit and are joined."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            plan = [(rep, list(rep.threads)) for rep in self.replicas]
        for rep, threads in plan:
            for _ in threads:
                rep.queue.put(_SENTINEL)
            rep.wake.set()
        for rep, threads in plan:
            for t in threads:
                t.join(timeout=timeout)
        # a submit racing close may have enqueued behind the sentinels:
        # fail those tickets rather than strand their waiters
        self._drain_queue(exc=RuntimeError("InferenceService closed"))

    def __enter__(self) -> "InferenceService":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # -- introspection -----------------------------------------------------------

    def snapshot(self) -> dict:
        """Global service counters, per-replica routing/batcher counters,
        and (for slot engines) the fleet-aggregated occupancy/throughput
        counters under ``"batcher"``."""
        with self._lock:
            d = {
                "engine": self.name,
                "mode": "batcher" if self._streaming else "threads",
                "replicas": self.n_replicas,
                "dispatchers": sum(len(r.threads) for r in self.replicas),
                "inflight": len(self._inflight),
                **self.stats.as_dict(),
            }
            per_replica = [rep.stats_dict() for rep in self.replicas]
        batcher = aggregate_batcher_stats(
            [p.get("batcher", {}) for p in per_replica]
        )
        if batcher:
            d["batcher"] = batcher
        if self.n_replicas > 1:
            d["replica_stats"] = per_replica
        return d
