"""Shared asynchronous inference service: cross-task continuous batching
with single-flight request coalescing, fanned out across N data-parallel
engine replicas.

Before this module, inference was lock-step per shard: every pipeline
stage blocked on its own ``engine.infer`` calls, the local JAX engine
serialized concurrent callers behind one lock (decode slots idling
whenever a caller's batch had fewer requests than slots), and two
concurrent chunk workers that missed the cache on the same prompt both
paid for the call — the duplicate-spend the content-addressable cache
exists to prevent, leaking back in through concurrency.  The service
inverts control: tasks, chunks, models and suites **submit**
:class:`~repro.core.engines.InferenceRequest` objects and get tickets
(futures) back; dispatch happens centrally —

* **single-flight coalescing** — identical in-flight cache keys share ONE
  engine call.  The first submitter is the *primary* (its shard is
  charged the call, the cost, the tokens, and the cache write); later
  submitters become waiters on the same flight and are counted as
  ``coalesced``.  The cache prevents duplicate spend across time;
  single-flight closes the concurrency window the cache cannot see.
  The flight table is **global across replicas**: a duplicate coalesces
  onto the original flight no matter which replica serves it.
* **central admission** — the per-task rate limiter is acquired by the
  dispatcher immediately before the engine call, not by worker threads
  sleeping inside the pipeline, so budget flows to whatever is runnable.
* **continuous batching** — engines exposing the slot-streaming interface
  (``supports_streaming``: the local JAX engine, the simulated slot
  engine) are driven by ONE persistent batcher loop *per replica*:
  queued prompts are admitted into decode slots as slots free, so
  batches form across shards, chunks, tasks and suites instead of
  inside one shard.  API-style engines get a dispatcher-thread pool per
  replica instead, sized by the pipeline stages currently attached.
* **replica routing** — with ``n_replicas > 1`` one submit queue fans
  out to N engine replicas through a :class:`ReplicaRouter`.  Policies:
  ``least_loaded`` (fewest outstanding requests — busy decode slots plus
  backlog), ``prefix_affinity`` (prompt-prefix hash, so shared few-shot
  headers land on the same batcher and its warmed prefixes), and
  ``round_robin``.  Routing is *stats-plane-invisible*: responses are a
  pure function of the request, so placement never changes a byte of
  evaluation output (see the determinism contract below).

Determinism contract: responses are a pure function of the request key
(prompt, model, provider, temperature, max_tokens) — simulated engines by
construction, the local engine because greedy decode at temperature 0 is
batch-composition independent.  Coalescing and routing therefore never
change a response byte; they only change how many engine calls paid for
it and which replica served it.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import queue
import threading
import time
from typing import Any, Callable, Sequence

from repro.core.engines import (
    InferenceEngine,
    InferenceRequest,
    InferenceResponse,
    RecoverableEngineError,
    is_recoverable,
    retry_with_backoff,
)
from repro.core.ratelimit import AdaptiveLimiter

_SENTINEL = object()


class ReplicaHungError(RuntimeError):
    """Raised by the health probe inside a batcher loop: the replica has
    in-flight work but its engine made no progress (no decode steps, no
    completions) for ``health_probe_steps`` consecutive pumps.  Takes the
    same drain-and-restart path as a crash."""


class _Flight:
    """One engine call and its waiters (single-flight unit)."""

    __slots__ = ("key", "event", "response", "exc", "attempts", "resolved")

    def __init__(self, key: str):
        self.key = key
        self.event = threading.Event()
        self.response: InferenceResponse | None = None
        self.exc: BaseException | None = None
        self.attempts = 0
        #: flipped under the service lock by the FIRST resolution — a
        #: hedged flight can race two completions; the loser only touches
        #: replica bookkeeping (``event`` alone would race: it is set
        #: outside the lock)
        self.resolved = False


class ServiceTicket:
    """Future for one submitted request.  ``primary`` is True for the
    submission that owns the engine call (and therefore the spend); a
    coalesced follower shares the response but owns nothing."""

    __slots__ = ("_flight", "primary")

    def __init__(self, flight: _Flight, primary: bool):
        self._flight = flight
        self.primary = primary

    def done(self) -> bool:
        return self._flight.event.is_set()

    @property
    def attempts(self) -> int:
        """Engine-call attempts the flight took (retries included)."""
        return self._flight.attempts

    def result(self, timeout: float | None = None) -> InferenceResponse:
        if not self._flight.event.wait(timeout):
            raise TimeoutError(
                f"inference ticket not resolved within {timeout}s"
            )
        if self._flight.exc is not None:
            raise self._flight.exc
        assert self._flight.response is not None
        return self._flight.response


@dataclasses.dataclass
class _Submission:
    flight: _Flight
    request: InferenceRequest
    limiter: Any
    est_tokens: float
    max_retries: int
    retry_delay: float
    replica: "_Replica | None" = None
    #: absolute monotonic deadline (None = no deadline); set at submit
    #: time so queue wait counts against it
    deadline_at: float | None = None
    #: this submission IS the hedge leg of an expired flight
    is_hedge: bool = False
    #: a hedge has already been issued for this submission's flight
    hedged: bool = False
    #: deadline expiry already counted (once per primary submission)
    expired: bool = False


class _BatcherState:
    """In-flight bookkeeping for ONE incarnation of a batcher loop.  On a
    crash the supervisor collects :meth:`survivors` and hands them to the
    next incarnation (restart) or fails them (retirement) — submissions a
    replica dies holding are never silently lost."""

    __slots__ = ("pending", "retry_at", "carry", "stall", "last_steps")

    def __init__(self) -> None:
        #: engine stream id -> submission, currently in decode
        self.pending: dict[int, _Submission] = {}
        #: (monotonic due time, submission) backoff-scheduled retries
        self.retry_at: list[tuple[float, _Submission]] = []
        #: submissions owned by the loop but in neither structure above
        #: (crashed mid-dispatch, or carried in from a prior incarnation)
        self.carry: list[_Submission] = []
        #: consecutive pumps without engine progress (health probe)
        self.stall = 0
        self.last_steps = -1

    def survivors(self) -> list[_Submission]:
        subs = (
            list(self.pending.values())
            + [s for _, s in self.retry_at]
            + list(self.carry)
        )
        self.pending.clear()
        self.retry_at.clear()
        self.carry.clear()
        return subs


@dataclasses.dataclass
class ServiceStats:
    submitted: int = 0
    coalesced: int = 0
    dispatched: int = 0   # engine-call attempts actually issued
    completed: int = 0
    retries: int = 0
    errors: int = 0
    #: broken replicas brought back by the bounded-backoff restart path
    restarts: int = 0
    #: primary submissions that outlived their deadline
    deadline_expiries: int = 0
    #: hedge legs actually re-issued to another alive replica
    hedges_issued: int = 0
    #: flights won by the hedge leg (the original was slower/stuck)
    hedges_won: int = 0

    @property
    def dedup_rate(self) -> float:
        return self.coalesced / self.submitted if self.submitted else 0.0

    def as_dict(self) -> dict:
        return {
            "submitted": self.submitted,
            "dispatched": self.dispatched,
            "coalesced": self.coalesced,
            "completed": self.completed,
            "retries": self.retries,
            "errors": self.errors,
            "dedup_rate": round(self.dedup_rate, 4),
            "restarts": self.restarts,
            "deadline_expiries": self.deadline_expiries,
            "hedges_issued": self.hedges_issued,
            "hedges_won": self.hedges_won,
        }


# -- replicas -------------------------------------------------------------------


class _Replica:
    """One engine replica behind the shared submit front: its own FIFO
    queue, its own dispatcher threads (one batcher loop for slot engines,
    a thread pool for API engines), and its own ServiceStats slice.
    Counter fields are guarded by the owning service's lock."""

    __slots__ = (
        "index", "engine", "queue", "wake", "threads",
        "routed", "outstanding", "dispatched", "completed", "errors",
        "broken", "first_failure", "last_progress", "restarts", "cancelled",
    )

    def __init__(self, index: int, engine: InferenceEngine, depth: int):
        self.index = index
        self.engine = engine
        self.queue: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self.wake = threading.Event()
        self.threads: list[threading.Thread] = []
        self.routed = 0        # submissions ever routed here
        self.outstanding = 0   # routed but not yet resolved
        self.dispatched = 0
        self.completed = 0
        self.errors = 0
        self.broken: BaseException | None = None
        #: cause of the replica's FIRST failure, kept across restarts for
        #: the fleet-dead post-mortem message
        self.first_failure: BaseException | None = None
        #: engine step count at the last observed progress (-1 = never)
        self.last_progress = -1
        self.restarts = 0
        #: hedge-loser legs cancelled on this replica
        self.cancelled = 0

    def busy_slots(self) -> int:
        sched = getattr(self.engine, "slots_busy", None)
        if callable(sched):
            return sched()
        return 0

    def stats_dict(self) -> dict:
        d = {
            "index": self.index,
            "routed": self.routed,
            "outstanding": self.outstanding,
            "dispatched": self.dispatched,
            "completed": self.completed,
            "errors": self.errors,
            "broken": self.broken is not None,
            "restarts": self.restarts,
            "cancelled": self.cancelled,
            "last_progress": self.last_progress,
        }
        batcher = self.engine.serving_stats()
        if batcher:
            d["batcher"] = batcher
        return d


@dataclasses.dataclass(frozen=True)
class ReplicaView:
    """Router-visible load snapshot of one (alive) replica."""

    index: int
    queued: int        # submissions waiting in the replica's service queue
    outstanding: int   # routed but unresolved (includes in-engine backlog)
    busy_slots: int = 0

    @property
    def load(self) -> int:
        return self.queued + self.outstanding


class ReplicaRouter:
    """Pluggable replica-placement policy.

    ``route`` picks among the *alive* replicas only; ties break on the
    lowest index so placement is deterministic given fixed stats.

    * ``least_loaded`` — fewest outstanding requests (busy decode slots
      plus queued backlog; the service counts routed-but-unresolved, which
      covers both).
    * ``prefix_affinity`` — stable hash of the first ``prefix_len``
      characters of the prompt: requests sharing a few-shot header or
      system prompt land on the same batcher (and, downstream, the same
      warmed prefix cache), independent of load.
    * ``round_robin`` — strict rotation.
    """

    POLICIES = ("least_loaded", "prefix_affinity", "round_robin")

    def __init__(self, policy: str = "least_loaded", prefix_len: int = 64):
        if policy not in self.POLICIES:
            raise ValueError(
                f"unknown routing policy {policy!r}; one of {self.POLICIES}"
            )
        self.policy = policy
        self.prefix_len = prefix_len
        self._rr = 0  # guarded by the owning service's lock

    def route(self, prompt: str, views: Sequence[ReplicaView]) -> int:
        """Replica index for ``prompt`` among the given (alive) views."""
        if not views:
            raise RuntimeError("no alive replicas to route to")
        if len(views) == 1:
            return views[0].index
        if self.policy == "least_loaded":
            return min(views, key=lambda v: (v.load, v.index)).index
        if self.policy == "prefix_affinity":
            h = hashlib.sha256(
                prompt[: self.prefix_len].encode("utf-8", "replace")
            ).digest()
            return views[int.from_bytes(h[:8], "big") % len(views)].index
        pick = views[self._rr % len(views)].index
        self._rr += 1
        return pick


def aggregate_batcher_stats(parts: Sequence[dict]) -> dict:
    """Fleet-level BatcherStats: counters sum across replicas; occupancy
    is re-derived as total active slot-steps over total slot-step
    capacity, and tokens/step is per (replica, step)."""
    parts = [p for p in parts if p]
    if not parts:
        return {}
    agg = {
        k: sum(p.get(k, 0) for p in parts)
        for k in (
            "n_slots", "steps", "admissions", "completions",
            "tokens_generated", "active_slot_steps", "prefill_recompiles",
            "prefills_deferred", "prefix_pages_hit", "prefix_tokens_saved",
            "cow_copies", "preemptions", "preempted_tokens", "pool_pages",
        )
    }
    # a rate, not a counter: replicas of one config share it, so take max
    # (0 only when no replica runs a paged cache)
    agg["kv_bytes_per_token"] = max(
        (p.get("kv_bytes_per_token", 0) for p in parts), default=0
    )
    cap = sum(p.get("steps", 0) * p.get("n_slots", 0) for p in parts)
    agg["slot_occupancy"] = round(
        agg["active_slot_steps"] / cap if cap else 0.0, 4
    )
    agg["tokens_per_step"] = round(
        agg["tokens_generated"] / agg["steps"] if agg["steps"] else 0.0, 3
    )
    return agg


class InferenceService:
    """Session-owned asynchronous dispatch front for one engine (or N
    data-parallel replicas of it).

    ``submit`` never blocks on inference (only on queue backpressure at
    ``queue_depth`` outstanding requests per replica);
    ``ServiceTicket.result`` gathers.  Construction is cheap — dispatcher
    threads start lazily on first use and are joined by :meth:`close`.
    """

    #: absolute ceiling on dispatcher threads per replica (the rate
    #: limiter, not the thread count, is the real admission control)
    HARD_MAX_DISPATCHERS = 128

    def __init__(
        self,
        engine: InferenceEngine | None = None,
        *,
        engines: Sequence[InferenceEngine] | None = None,
        routing: "str | ReplicaRouter" = "least_loaded",
        queue_depth: int = 256,
        coalesce: bool = True,
        max_batch_wait_ms: float = 2.0,
        n_dispatchers: int = 4,
        sleep: Callable[[float], None] = time.sleep,
        name: str = "",
        max_replica_restarts: int = 0,
        restart_backoff_s: float = 0.05,
        health_probe_steps: int = 0,
    ):
        fleet = list(engines) if engines else []
        if engine is not None and not fleet:
            fleet = [engine]
        if not fleet:
            raise ValueError("InferenceService needs at least one engine")
        streaming = {bool(getattr(e, "supports_streaming", False)) for e in fleet}
        if len(streaming) != 1:
            raise ValueError(
                "replica fleet mixes streaming and non-streaming engines"
            )
        #: replica-0 engine, kept as ``self.engine`` for single-replica
        #: callers and introspection compatibility
        self.engine = fleet[0]
        self.coalesce = coalesce
        self.max_batch_wait_ms = max_batch_wait_ms
        self.name = name
        #: bounded-backoff restarts per broken replica (0 = legacy: the
        #: first crash quarantines the replica for good)
        self.max_replica_restarts = max(0, max_replica_restarts)
        self.restart_backoff_s = restart_backoff_s
        #: pumps without engine progress before a loaded replica is
        #: declared hung and drain-and-restarted (0 = probe disabled)
        self.health_probe_steps = max(0, health_probe_steps)
        self.stats = ServiceStats()
        self.router = (
            routing if isinstance(routing, ReplicaRouter)
            else ReplicaRouter(routing)
        )
        self._sleep = sleep
        self.replicas = [
            _Replica(i, e, queue_depth) for i, e in enumerate(fleet)
        ]
        self._inflight: dict[str, _Flight] = {}
        self._lock = threading.Lock()
        self._base_dispatchers = max(1, n_dispatchers)
        self._attached = 0
        self._closed = False
        self._broken: BaseException | None = None
        self._streaming = streaming.pop()
        self._uniq = itertools.count()

    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    # -- capacity ---------------------------------------------------------------

    def attach(self, n_workers: int = 1) -> None:
        """A pipeline stage is about to submit: size the dispatch pool for
        its configured parallelism.  Batcher-mode engines need no threads
        beyond one loop per replica — decode slots are the parallelism."""
        with self._lock:
            self._check_open()
            self._attached += max(1, n_workers)
            self._ensure_dispatchers()

    def detach(self, n_workers: int = 1) -> None:
        with self._lock:
            self._attached = max(0, self._attached - max(1, n_workers))
            # threads never shrink: idle dispatchers just block on the queue

    def _threads_per_replica(self) -> int:
        if self._streaming:
            return 1
        return min(
            self.HARD_MAX_DISPATCHERS,
            max(self._base_dispatchers, self._attached),
        )

    def _ensure_dispatchers(self) -> None:  # caller holds self._lock
        target = self._threads_per_replica()
        for rep in self.replicas:
            while len(rep.threads) < target:
                idx = len(rep.threads)
                t = threading.Thread(
                    target=self._batcher_loop if self._streaming
                    else self._dispatch_loop,
                    args=(rep,) if self._streaming else (rep, idx),
                    name=(
                        f"infer-service-{self.name or 'engine'}"
                        f"-r{rep.index}-{idx}"
                    ),
                    daemon=True,
                )
                rep.threads.append(t)
                t.start()

    # -- submission --------------------------------------------------------------

    def _alive_views(self) -> list[ReplicaView]:  # caller holds self._lock
        return [
            ReplicaView(
                index=r.index,
                queued=r.queue.qsize(),
                outstanding=r.outstanding,
                busy_slots=r.busy_slots(),
            )
            for r in self.replicas
            if r.broken is None
        ]

    def submit(
        self,
        request: InferenceRequest,
        *,
        key: str | None = None,
        coalesce: bool | None = None,
        limiter: Any = None,
        est_tokens: float = 0.0,
        max_retries: int = 0,
        retry_delay: float = 1.0,
        deadline_s: float = 0.0,
    ) -> ServiceTicket:
        """Enqueue a request; returns a :class:`ServiceTicket` immediately.

        ``key`` is the content-addressable identity of the request (the
        response-cache key); identical in-flight keys coalesce into one
        engine call unless coalescing is off — the flight table is checked
        *before* routing, so the dedup is global across replicas.
        ``limiter`` (an :class:`~repro.core.ratelimit.AdaptiveLimiter` or
        a list of :class:`~repro.core.ratelimit.TokenBucket`) is acquired
        by the dispatcher right before the engine call."""
        do_coalesce = self.coalesce if coalesce is None else coalesce
        if key is None:
            do_coalesce = False
            key = f"~uniq-{next(self._uniq)}"
        with self._lock:
            self._check_open()
            self.stats.submitted += 1
            if do_coalesce:
                flight = self._inflight.get(key)
                if flight is not None:
                    self.stats.coalesced += 1
                    return ServiceTicket(flight, primary=False)
            views = self._alive_views()
            if not views:
                self.stats.submitted -= 1
                raise RuntimeError(
                    f"InferenceService {self.name!r}: all "
                    f"{self.n_replicas} replicas failed — "
                    + self._fleet_report()
                )
            flight = _Flight(key)
            if do_coalesce:
                self._inflight[key] = flight
            rep = self.replicas[self.router.route(request.prompt, views)]
            rep.routed += 1
            rep.outstanding += 1
            self._ensure_dispatchers()
        # outside the lock: a full replica queue blocks the submitter
        # (backpressure), never the dispatchers
        rep.queue.put(
            _Submission(
                flight, request, limiter, est_tokens, max_retries,
                retry_delay, replica=rep,
                deadline_at=(
                    time.monotonic() + deadline_s if deadline_s > 0 else None
                ),
            )
        )
        rep.wake.set()
        with self._lock:
            dead_now = (
                self._closed or self._broken is not None
                or rep.broken is not None
            )
        if dead_now:
            # close() (or a dispatcher crash) may have drained the queue
            # between our open-check and the put: nobody will read this
            # submission, so fail it — and any fellow stragglers — rather
            # than strand the waiters.  During normal operation this
            # branch is unreachable.
            self._drain_replica(
                rep, exc=rep.broken or RuntimeError("InferenceService closed")
            )
        return ServiceTicket(flight, primary=True)

    def note_coalesced(self, n: int = 1) -> None:
        """Record submissions deduplicated *before* reaching the service
        (e.g. a stage reusing its own ticket for a repeated key), so
        service-level dedup counters reflect total demand."""
        with self._lock:
            self.stats.submitted += n
            self.stats.coalesced += n

    # -- dispatch ---------------------------------------------------------------

    def _admit(self, sub: _Submission, widx: int) -> None:
        lim = sub.limiter
        if lim is None:
            return
        if isinstance(lim, AdaptiveLimiter):
            lim.acquire(widx % lim.n, sub.est_tokens)
        elif isinstance(lim, (list, tuple)):
            lim[widx % len(lim)].acquire(sub.est_tokens)
        else:
            lim.acquire(sub.est_tokens)

    def _resolve(
        self,
        sub_or_flight: "_Submission | _Flight",
        response: InferenceResponse | None = None,
        exc: BaseException | None = None,
    ) -> None:
        if isinstance(sub_or_flight, _Submission):
            flight = sub_or_flight.flight
            rep = sub_or_flight.replica
            is_hedge = sub_or_flight.is_hedge
        else:
            flight, rep, is_hedge = sub_or_flight, None, False
        with self._lock:
            if flight.resolved:
                # hedge-race loser (or a drain hitting an already-resolved
                # flight): the first resolution owns the response and the
                # completion/error counters; only replica-load bookkeeping
                # moves here
                if rep is not None:
                    rep.outstanding = max(0, rep.outstanding - 1)
                return
            flight.resolved = True
            self._inflight.pop(flight.key, None)
            self.stats.completed += 1
            self.stats.retries += max(0, flight.attempts - 1)
            if is_hedge:
                self.stats.hedges_won += 1
            failed = exc is not None or (
                response is not None and response.error is not None
            )
            if failed:
                self.stats.errors += 1
            if rep is not None:
                rep.outstanding = max(0, rep.outstanding - 1)
                rep.completed += 1
                if failed:
                    rep.errors += 1
        flight.response = response
        flight.exc = exc
        flight.event.set()

    def _count_dispatch(self, rep: _Replica) -> None:
        with self._lock:
            self.stats.dispatched += 1
            rep.dispatched += 1

    def _dispatch_loop(self, rep: _Replica, widx: int) -> None:
        """Thread-pool dispatch for API-style engines: one request per
        engine call against this thread's replica, retries via
        :func:`retry_with_backoff`.

        After each call the loop opportunistically drains further queued
        submissions without re-blocking — one condition-variable wakeup
        can serve a whole burst, which matters for fast engines where the
        wakeup itself dominates.  Exactly one stop sentinel is consumed
        per dispatcher (the loop returns the moment it sees one), so
        every dispatcher thread still shuts down."""
        while True:
            item = rep.queue.get()
            while True:
                if item is _SENTINEL:
                    return
                sub: _Submission = item
                try:
                    self._admit(sub, widx)

                    def _call(sub=sub) -> InferenceResponse:
                        sub.flight.attempts += 1
                        self._count_dispatch(rep)
                        return rep.engine.infer(sub.request)

                    resp = retry_with_backoff(
                        _call,
                        max_retries=sub.max_retries,
                        base_delay=sub.retry_delay,
                        sleep=self._sleep,
                    )
                    self._resolve(sub, resp)
                except BaseException as e:  # noqa: BLE001 — waiters must wake
                    self._resolve(sub, exc=e)
                try:
                    item = rep.queue.get_nowait()
                except queue.Empty:
                    break

    def _batcher_loop(self, rep: _Replica) -> None:
        """Replica supervisor: run the batcher, and on a crash (or a
        health-probe hang verdict) either restart the replica with bounded
        backoff — carrying its in-flight submissions into the fresh
        incarnation — or, budget exhausted, fail them and quarantine the
        replica (DESIGN.md §9)."""
        used = 0
        carry: list[_Submission] = []
        while True:
            state = _BatcherState()
            # survivors of the previous incarnation re-dispatch first
            # (directly, not via the bounded queue — the only consumer of
            # that queue is this very thread)
            state.carry = carry
            try:
                self._batcher_run(rep, state)
                return  # clean shutdown via stop sentinel
            except BaseException as e:  # noqa: BLE001
                carry = state.survivors()
                used = self._handle_replica_failure(rep, e, carry, used)
                if used < 0:
                    return

    def _handle_replica_failure(
        self,
        rep: _Replica,
        exc: BaseException,
        carry: list[_Submission],
        used: int,
    ) -> int:
        """Recover or retire a crashed/hung replica.  Returns the restart
        budget consumed so far, or -1 once the replica is dead (its
        survivors failed, the fleet-dead flag set if it was the last).
        A failed ``engine.reset()`` burns a restart and retries."""
        while True:
            with self._lock:
                rep.broken = exc
                if rep.first_failure is None:
                    rep.first_failure = exc
                closed = self._closed
            if closed or used >= self.max_replica_restarts:
                with self._lock:
                    if all(r.broken is not None for r in self.replicas):
                        self._broken = exc
                for sub in carry:
                    self._resolve(sub, exc=exc)
                self._drain_replica(rep, exc=exc)
                return -1
            self._sleep(self.restart_backoff_s * (2.0 ** used))
            used += 1
            try:
                rep.engine.reset()
            except BaseException as e2:  # noqa: BLE001
                exc = e2
                continue
            with self._lock:
                rep.broken = None
                rep.restarts += 1
                self.stats.restarts += 1
            # the caller's fresh incarnation re-dispatches `carry` itself:
            # same request ids are fine — the engine issues new stream
            # ids, and responses are a pure function of the request, so
            # the re-served output is byte-identical to the lost one
            rep.wake.set()
            return used

    def _issue_hedge(self, sub: _Submission, origin: _Replica) -> bool:
        """Re-issue an expired submission's flight to another alive
        replica.  Single-flight semantics survive: both legs share one
        flight, the first resolution wins (see ``_Flight.resolved``), the
        loser is cancelled cooperatively by its owning loop.  Returns True
        once the hedge leg is enqueued."""
        with self._lock:
            if self._closed or sub.flight.resolved:
                return True  # nothing left to hedge
            views = [
                v for v in self._alive_views() if v.index != origin.index
            ]
            if not views:
                return False  # retry on a later pump
            rep2 = self.replicas[
                self.router.route(sub.request.prompt, views)
            ]
            hedge = _Submission(
                sub.flight, sub.request, sub.limiter, sub.est_tokens,
                sub.max_retries, sub.retry_delay, replica=rep2,
                is_hedge=True,
            )
            try:
                # never block a batcher thread on backpressure; a full
                # queue just defers the hedge to the next pump
                rep2.queue.put_nowait(hedge)
            except queue.Full:
                return False
            rep2.routed += 1
            rep2.outstanding += 1
            self.stats.hedges_issued += 1
        rep2.wake.set()
        return True

    def _batcher_run(self, rep: _Replica, state: "_BatcherState") -> None:
        """Persistent continuous-batching loop for one slot-streaming
        replica: admit queued prompts into decode slots as slots free,
        step, deliver completions — one loop per replica, shared by every
        task the session runs.

        Recoverable errors re-admit with exponential backoff through a
        scheduled-retry list (the loop itself must never sleep — other
        slots are decoding); with a no-op injected sleep (virtual-clock
        sessions) retries are immediate, matching the lock-step path's
        behaviour under the same injection.  The rate-limiter index
        round-robins across admissions so list-mode buckets grant their
        full aggregate budget.

        Error taxonomy (DESIGN.md §9): ``RecoverableEngineError`` retries
        with backoff; ``ValueError``/``TypeError`` fail the one ticket with
        its original traceback and the replica lives on; anything else is
        a replica crash — in-flight submissions survive in ``state`` for
        the supervisor's restart path.  Each iteration also enforces
        request deadlines (expiry → hedge to another replica), cancels
        hedge-loser legs, and runs the no-progress health probe."""
        engine = rep.engine
        pending = state.pending
        retry_at = state.retry_at
        wait_s = max(0.0, self.max_batch_wait_ms) / 1000.0
        real_sleep = self._sleep is time.sleep
        stop = False
        admit_rr = 0

        def _engine_steps() -> int:
            try:
                return int(engine.serving_stats().get("steps", 0) or 0)
            except Exception:  # noqa: BLE001 — probe must not kill the loop
                return 0

        def _dispatch(sub: _Submission) -> None:
            nonlocal admit_rr
            try:
                self._admit(sub, admit_rr)
                admit_rr += 1
                sub.flight.attempts += 1
                self._count_dispatch(rep)
                pending[engine.stream_submit(sub.request)] = sub
            except RecoverableEngineError as e:
                # transient refusal: burn a backoff slot, not the replica
                if sub.flight.attempts <= sub.max_retries:
                    delay = (
                        sub.retry_delay * 2.0 ** (sub.flight.attempts - 1)
                        if real_sleep else 0.0
                    )
                    retry_at.append((time.monotonic() + delay, sub))
                else:
                    self._resolve(sub, exc=e)
            except (ValueError, TypeError) as e:
                # programming error: fail THIS ticket with the original
                # traceback; the replica stays healthy
                self._resolve(sub, exc=e)
            except BaseException:
                # replica crash: the in-hand submission is in neither
                # `pending` nor the queue — carry it into the restart path
                state.carry.append(sub)
                raise

        # survivors carried over from a crashed incarnation re-dispatch
        # first; a repeat crash lands them back in state.carry/pending
        while state.carry:
            _dispatch(state.carry.pop(0))

        while True:
            was_idle = not pending
            admitted = 0
            if retry_at:
                # pop one at a time: if a dispatch raises, the entries
                # not yet reached are still in retry_at and the supervisor
                # carries them across the restart
                now = time.monotonic()
                i = 0
                while i < len(retry_at):
                    if retry_at[i][0] <= now:
                        _, sub_r = retry_at.pop(i)
                        _dispatch(sub_r)
                        admitted += 1
                    else:
                        i += 1
            while True:
                try:
                    item = rep.queue.get_nowait()
                except queue.Empty:
                    break
                if item is _SENTINEL:
                    stop = True
                    break
                _dispatch(item)
                admitted += 1
            if pending:
                now = time.monotonic()
                for rid in list(pending):
                    sub_p = pending[rid]
                    if sub_p.flight.resolved:
                        # another replica won this flight (hedge or drain):
                        # cancel the local leg, free its slot and pages
                        pending.pop(rid)
                        try:
                            engine.stream_cancel(rid)
                        except Exception:  # noqa: BLE001
                            pass
                        with self._lock:
                            rep.outstanding = max(0, rep.outstanding - 1)
                            rep.cancelled += 1
                        continue
                    if (
                        sub_p.deadline_at is not None
                        and now >= sub_p.deadline_at
                    ):
                        if not sub_p.expired:
                            sub_p.expired = True
                            with self._lock:
                                self.stats.deadline_expiries += 1
                        if not sub_p.hedged:
                            sub_p.hedged = self._issue_hedge(sub_p, rep)
            if stop and not pending and not retry_at:
                return
            if not pending:
                rep.wake.clear()
                rep.wake.wait(timeout=0.005 if retry_at else 0.05)
                continue
            if was_idle and admitted and wait_s and not stop:
                # batch-formation window: a cold batcher waits briefly
                # for co-submitted prompts before spinning up decode
                # (injected sleep — a no-op under virtual clocks)
                self._sleep(wait_s)
                continue
            done = engine.stream_pump()
            for rid, resp in done:
                sub2 = pending.pop(rid, None)
                if sub2 is None:
                    continue
                if (
                    is_recoverable(resp.error)
                    and sub2.flight.attempts <= sub2.max_retries
                ):
                    delay = (
                        sub2.retry_delay
                        * 2.0 ** (sub2.flight.attempts - 1)
                        if real_sleep
                        else 0.0
                    )
                    retry_at.append((time.monotonic() + delay, sub2))
                    continue
                self._resolve(sub2, resp)
            # health probe: progress = completions delivered or engine
            # decode steps advancing; a loaded replica that shows neither
            # for health_probe_steps consecutive pumps is hung (a wedged
            # engine raises no exception — only the probe catches it)
            steps_now = _engine_steps()
            progressed = bool(done) or steps_now != state.last_steps
            state.last_steps = steps_now
            if progressed:
                state.stall = 0
                with self._lock:
                    rep.last_progress = steps_now
            elif pending:
                state.stall += 1
                if (
                    self.health_probe_steps
                    and state.stall >= self.health_probe_steps
                ):
                    raise ReplicaHungError(
                        f"replica {rep.index}: no engine progress in "
                        f"{state.stall} pumps with {len(pending)} "
                        f"request(s) in flight"
                    )

    # -- lifecycle ---------------------------------------------------------------

    def _fleet_report(self) -> str:
        """Per-replica post-mortem for fleet-dead errors: every replica's
        first-failure cause, last-progress step and restart count — not
        just the first replica's."""
        parts = []
        for r in self.replicas:
            cause = r.first_failure or r.broken
            parts.append(
                f"replica {r.index}: "
                + (f"{cause!r}" if cause is not None else "alive")
                + f" (last progress step {r.last_progress}, "
                f"restarts {r.restarts})"
            )
        return "; ".join(parts)

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("InferenceService is closed")
        if self._broken is not None:
            raise RuntimeError(
                f"InferenceService dispatch failed: {self._broken!r} — "
                + self._fleet_report()
            )

    def _drain_replica(self, rep: _Replica, exc: BaseException) -> None:
        """Fail every submission queued on one replica; stop sentinels are
        preserved (re-enqueued) so dispatchers racing this drain still
        shut down."""
        sentinels = 0
        while True:
            try:
                item = rep.queue.get_nowait()
            except queue.Empty:
                break
            if item is _SENTINEL:
                sentinels += 1
            else:
                self._resolve(item, exc=exc)
        for _ in range(sentinels):
            rep.queue.put(_SENTINEL)

    def _drain_queue(self, exc: BaseException) -> None:
        for rep in self.replicas:
            self._drain_replica(rep, exc=exc)

    def close(self, timeout: float = 30.0) -> None:
        """Drain and stop: queued work is dispatched to completion (FIFO —
        the stop sentinels sit behind it), in-flight decode finishes, then
        dispatcher threads exit and are joined."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            plan = [(rep, list(rep.threads)) for rep in self.replicas]
        for rep, threads in plan:
            for _ in threads:
                rep.queue.put(_SENTINEL)
            rep.wake.set()
        for rep, threads in plan:
            for t in threads:
                t.join(timeout=timeout)
        # a submit racing close may have enqueued behind the sentinels:
        # fail those tickets rather than strand their waiters
        self._drain_queue(exc=RuntimeError("InferenceService closed"))

    def __enter__(self) -> "InferenceService":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # -- introspection -----------------------------------------------------------

    def snapshot(self) -> dict:
        """Global service counters, per-replica routing/batcher counters,
        and (for slot engines) the fleet-aggregated occupancy/throughput
        counters under ``"batcher"``."""
        with self._lock:
            d = {
                "engine": self.name,
                "mode": "batcher" if self._streaming else "threads",
                "replicas": self.n_replicas,
                "dispatchers": sum(len(r.threads) for r in self.replicas),
                "inflight": len(self._inflight),
                **self.stats.as_dict(),
            }
            per_replica = [rep.stats_dict() for rep in self.replicas]
        batcher = aggregate_batcher_stats(
            [p.get("batcher", {}) for p in per_replica]
        )
        if batcher:
            d["batcher"] = batcher
        if self.n_replicas > 1:
            d["replica_stats"] = per_replica
        return d
