"""Shared asynchronous inference service: cross-task continuous batching
with single-flight request coalescing.

Before this module, inference was lock-step per shard: every pipeline
stage blocked on its own ``engine.infer`` calls, the local JAX engine
serialized concurrent callers behind one lock (decode slots idling
whenever a caller's batch had fewer requests than slots), and two
concurrent chunk workers that missed the cache on the same prompt both
paid for the call — the duplicate-spend the content-addressable cache
exists to prevent, leaking back in through concurrency.  The service
inverts control: tasks, chunks, models and suites **submit**
:class:`~repro.core.engines.InferenceRequest` objects and get tickets
(futures) back; dispatch happens centrally —

* **single-flight coalescing** — identical in-flight cache keys share ONE
  engine call.  The first submitter is the *primary* (its shard is
  charged the call, the cost, the tokens, and the cache write); later
  submitters become waiters on the same flight and are counted as
  ``coalesced``.  The cache prevents duplicate spend across time;
  single-flight closes the concurrency window the cache cannot see.
* **central admission** — the per-task rate limiter is acquired by the
  dispatcher immediately before the engine call, not by worker threads
  sleeping inside the pipeline, so budget flows to whatever is runnable.
* **continuous batching** — engines exposing the slot-streaming interface
  (``supports_streaming``: the local JAX engine, the simulated slot
  engine) are driven by ONE persistent batcher loop: queued prompts are
  admitted into decode slots as slots free, so batches form across
  shards, chunks, tasks and suites instead of inside one shard.
  API-style engines get a dispatcher-thread pool instead, sized by the
  pipeline stages currently attached (K concurrent chunk workers with
  ``n_workers`` each get ~K x n_workers overlapping calls, matching the
  lock-step path's aggregate concurrency).

Determinism contract: responses are a pure function of the request key
(prompt, model, provider, temperature, max_tokens) — simulated engines by
construction, the local engine because greedy decode at temperature 0 is
batch-composition independent.  Coalescing therefore never changes a
response byte; it only changes how many engine calls paid for it.
"""

from __future__ import annotations

import dataclasses
import itertools
import queue
import threading
import time
from typing import Any, Callable

from repro.core.engines import (
    InferenceEngine,
    InferenceRequest,
    InferenceResponse,
    is_recoverable,
    retry_with_backoff,
)
from repro.core.ratelimit import AdaptiveLimiter

_SENTINEL = object()


class _Flight:
    """One engine call and its waiters (single-flight unit)."""

    __slots__ = ("key", "event", "response", "exc", "attempts")

    def __init__(self, key: str):
        self.key = key
        self.event = threading.Event()
        self.response: InferenceResponse | None = None
        self.exc: BaseException | None = None
        self.attempts = 0


class ServiceTicket:
    """Future for one submitted request.  ``primary`` is True for the
    submission that owns the engine call (and therefore the spend); a
    coalesced follower shares the response but owns nothing."""

    __slots__ = ("_flight", "primary")

    def __init__(self, flight: _Flight, primary: bool):
        self._flight = flight
        self.primary = primary

    def done(self) -> bool:
        return self._flight.event.is_set()

    @property
    def attempts(self) -> int:
        """Engine-call attempts the flight took (retries included)."""
        return self._flight.attempts

    def result(self, timeout: float | None = None) -> InferenceResponse:
        if not self._flight.event.wait(timeout):
            raise TimeoutError(
                f"inference ticket not resolved within {timeout}s"
            )
        if self._flight.exc is not None:
            raise self._flight.exc
        assert self._flight.response is not None
        return self._flight.response


@dataclasses.dataclass
class _Submission:
    flight: _Flight
    request: InferenceRequest
    limiter: Any
    est_tokens: float
    max_retries: int
    retry_delay: float


@dataclasses.dataclass
class ServiceStats:
    submitted: int = 0
    coalesced: int = 0
    dispatched: int = 0   # engine-call attempts actually issued
    completed: int = 0
    retries: int = 0
    errors: int = 0

    @property
    def dedup_rate(self) -> float:
        return self.coalesced / self.submitted if self.submitted else 0.0

    def as_dict(self) -> dict:
        return {
            "submitted": self.submitted,
            "dispatched": self.dispatched,
            "coalesced": self.coalesced,
            "completed": self.completed,
            "retries": self.retries,
            "errors": self.errors,
            "dedup_rate": round(self.dedup_rate, 4),
        }


class InferenceService:
    """Session-owned asynchronous dispatch front for one engine.

    ``submit`` never blocks on inference (only on queue backpressure at
    ``queue_depth`` outstanding requests); ``ServiceTicket.result``
    gathers.  Construction is cheap — dispatcher threads start lazily on
    first use and are joined by :meth:`close`.
    """

    #: absolute ceiling on dispatcher threads per service (the rate
    #: limiter, not the thread count, is the real admission control)
    HARD_MAX_DISPATCHERS = 128

    def __init__(
        self,
        engine: InferenceEngine,
        *,
        queue_depth: int = 256,
        coalesce: bool = True,
        max_batch_wait_ms: float = 2.0,
        n_dispatchers: int = 4,
        sleep: Callable[[float], None] = time.sleep,
        name: str = "",
    ):
        self.engine = engine
        self.coalesce = coalesce
        self.max_batch_wait_ms = max_batch_wait_ms
        self.name = name
        self.stats = ServiceStats()
        self._sleep = sleep
        self._queue: queue.Queue = queue.Queue(maxsize=max(1, queue_depth))
        self._inflight: dict[str, _Flight] = {}
        self._lock = threading.Lock()
        self._threads: list[threading.Thread] = []
        self._base_dispatchers = max(1, n_dispatchers)
        self._attached = 0
        self._closed = False
        self._broken: BaseException | None = None
        self._streaming = bool(getattr(engine, "supports_streaming", False))
        self._wake = threading.Event()
        self._uniq = itertools.count()

    # -- capacity ---------------------------------------------------------------

    def attach(self, n_workers: int = 1) -> None:
        """A pipeline stage is about to submit: size the dispatch pool for
        its configured parallelism.  Batcher-mode engines need no threads
        beyond the loop — decode slots are the parallelism."""
        with self._lock:
            self._check_open()
            self._attached += max(1, n_workers)
            self._ensure_dispatchers()

    def detach(self, n_workers: int = 1) -> None:
        with self._lock:
            self._attached = max(0, self._attached - max(1, n_workers))
            # threads never shrink: idle dispatchers just block on the queue

    def _target_threads(self) -> int:
        if self._streaming:
            return 1
        return min(
            self.HARD_MAX_DISPATCHERS,
            max(self._base_dispatchers, self._attached),
        )

    def _ensure_dispatchers(self) -> None:  # caller holds self._lock
        target = self._target_threads()
        while len(self._threads) < target:
            idx = len(self._threads)
            t = threading.Thread(
                target=self._batcher_loop if self._streaming
                else self._dispatch_loop,
                args=() if self._streaming else (idx,),
                name=f"infer-service-{self.name or 'engine'}-{idx}",
                daemon=True,
            )
            self._threads.append(t)
            t.start()

    # -- submission --------------------------------------------------------------

    def submit(
        self,
        request: InferenceRequest,
        *,
        key: str | None = None,
        coalesce: bool | None = None,
        limiter: Any = None,
        est_tokens: float = 0.0,
        max_retries: int = 0,
        retry_delay: float = 1.0,
    ) -> ServiceTicket:
        """Enqueue a request; returns a :class:`ServiceTicket` immediately.

        ``key`` is the content-addressable identity of the request (the
        response-cache key); identical in-flight keys coalesce into one
        engine call unless coalescing is off.  ``limiter`` (an
        :class:`~repro.core.ratelimit.AdaptiveLimiter` or a list of
        :class:`~repro.core.ratelimit.TokenBucket`) is acquired by the
        dispatcher right before the engine call."""
        do_coalesce = self.coalesce if coalesce is None else coalesce
        if key is None:
            do_coalesce = False
            key = f"~uniq-{next(self._uniq)}"
        with self._lock:
            self._check_open()
            self.stats.submitted += 1
            if do_coalesce:
                flight = self._inflight.get(key)
                if flight is not None:
                    self.stats.coalesced += 1
                    return ServiceTicket(flight, primary=False)
            flight = _Flight(key)
            if do_coalesce:
                self._inflight[key] = flight
            self._ensure_dispatchers()
        # outside the lock: a full queue blocks the submitter (backpressure),
        # never the dispatchers
        self._queue.put(
            _Submission(
                flight, request, limiter, est_tokens, max_retries, retry_delay
            )
        )
        self._wake.set()
        with self._lock:
            closed_now = self._closed or self._broken is not None
        if closed_now:
            # close() (or a dispatcher crash) may have drained the queue
            # between our open-check and the put: nobody will read this
            # submission, so fail it — and any fellow stragglers — rather
            # than strand the waiters.  During normal operation this
            # branch is unreachable.
            self._drain_queue(exc=RuntimeError("InferenceService closed"))
        return ServiceTicket(flight, primary=True)

    def note_coalesced(self, n: int = 1) -> None:
        """Record submissions deduplicated *before* reaching the service
        (e.g. a stage reusing its own ticket for a repeated key), so
        service-level dedup counters reflect total demand."""
        with self._lock:
            self.stats.submitted += n
            self.stats.coalesced += n

    # -- dispatch ---------------------------------------------------------------

    def _admit(self, sub: _Submission, widx: int) -> None:
        lim = sub.limiter
        if lim is None:
            return
        if isinstance(lim, AdaptiveLimiter):
            lim.acquire(widx % lim.n, sub.est_tokens)
        elif isinstance(lim, (list, tuple)):
            lim[widx % len(lim)].acquire(sub.est_tokens)
        else:
            lim.acquire(sub.est_tokens)

    def _resolve(
        self,
        flight: _Flight,
        response: InferenceResponse | None = None,
        exc: BaseException | None = None,
    ) -> None:
        with self._lock:
            self._inflight.pop(flight.key, None)
            self.stats.completed += 1
            self.stats.retries += max(0, flight.attempts - 1)
            if exc is not None or (
                response is not None and response.error is not None
            ):
                self.stats.errors += 1
        flight.response = response
        flight.exc = exc
        flight.event.set()

    def _dispatch_loop(self, widx: int) -> None:
        """Thread-pool dispatch for API-style engines: one request per
        engine call, retries via :func:`retry_with_backoff`.

        After each call the loop opportunistically drains further queued
        submissions without re-blocking — one condition-variable wakeup
        can serve a whole burst, which matters for fast engines where the
        wakeup itself dominates.  Exactly one stop sentinel is consumed
        per dispatcher (the loop returns the moment it sees one), so
        every dispatcher thread still shuts down."""
        while True:
            item = self._queue.get()
            while True:
                if item is _SENTINEL:
                    return
                sub: _Submission = item
                flight = sub.flight
                try:
                    self._admit(sub, widx)

                    def _call(sub=sub, flight=flight) -> InferenceResponse:
                        flight.attempts += 1
                        with self._lock:
                            self.stats.dispatched += 1
                        return self.engine.infer(sub.request)

                    resp = retry_with_backoff(
                        _call,
                        max_retries=sub.max_retries,
                        base_delay=sub.retry_delay,
                        sleep=self._sleep,
                    )
                    self._resolve(flight, resp)
                except BaseException as e:  # noqa: BLE001 — waiters must wake
                    self._resolve(flight, exc=e)
                try:
                    item = self._queue.get_nowait()
                except queue.Empty:
                    break

    def _batcher_loop(self) -> None:
        """Persistent continuous-batching loop for slot-streaming engines:
        admit queued prompts into decode slots as slots free, step, deliver
        completions — one loop for every task the session runs.

        Recoverable errors re-admit with exponential backoff through a
        scheduled-retry list (the loop itself must never sleep — other
        slots are decoding); with a no-op injected sleep (virtual-clock
        sessions) retries are immediate, matching the lock-step path's
        behaviour under the same injection.  The rate-limiter index
        round-robins across admissions so list-mode buckets grant their
        full aggregate budget."""
        engine = self.engine
        pending: dict[int, _Submission] = {}
        retry_at: list[tuple[float, _Submission]] = []
        wait_s = max(0.0, self.max_batch_wait_ms) / 1000.0
        real_sleep = self._sleep is time.sleep
        stop = False
        admit_rr = 0

        def _dispatch(sub: _Submission) -> None:
            nonlocal admit_rr
            try:
                self._admit(sub, admit_rr)
                admit_rr += 1
                sub.flight.attempts += 1
                with self._lock:
                    self.stats.dispatched += 1
                pending[engine.stream_submit(sub.request)] = sub
            except BaseException as e:
                # the in-hand submission is in neither `pending` nor the
                # queue — fail its flight here or its waiters hang; the
                # outer handler then fails everything else
                self._resolve(sub.flight, exc=e)
                raise

        try:
            while True:
                was_idle = not pending
                admitted = 0
                if retry_at:
                    # pop one at a time: if a dispatch raises, the entries
                    # not yet reached are still in retry_at and the crash
                    # handler below can fail their flights
                    now = time.monotonic()
                    i = 0
                    while i < len(retry_at):
                        if retry_at[i][0] <= now:
                            _, sub_r = retry_at.pop(i)
                            _dispatch(sub_r)
                            admitted += 1
                        else:
                            i += 1
                while True:
                    try:
                        item = self._queue.get_nowait()
                    except queue.Empty:
                        break
                    if item is _SENTINEL:
                        stop = True
                        break
                    _dispatch(item)
                    admitted += 1
                if stop and not pending and not retry_at:
                    return
                if not pending:
                    self._wake.clear()
                    self._wake.wait(timeout=0.005 if retry_at else 0.05)
                    continue
                if was_idle and admitted and wait_s and not stop:
                    # batch-formation window: a cold batcher waits briefly
                    # for co-submitted prompts before spinning up decode
                    # (injected sleep — a no-op under virtual clocks)
                    self._sleep(wait_s)
                    continue
                for rid, resp in engine.stream_pump():
                    sub2 = pending.pop(rid, None)
                    if sub2 is None:
                        continue
                    if (
                        is_recoverable(resp.error)
                        and sub2.flight.attempts <= sub2.max_retries
                    ):
                        delay = (
                            sub2.retry_delay
                            * 2.0 ** (sub2.flight.attempts - 1)
                            if real_sleep
                            else 0.0
                        )
                        retry_at.append((time.monotonic() + delay, sub2))
                        continue
                    self._resolve(sub2.flight, resp)
        except BaseException as e:  # noqa: BLE001
            # deadlock backstop: a dying batcher loop fails every
            # outstanding ticket instead of stranding its waiters
            with self._lock:
                self._broken = e
            for sub3 in pending.values():
                self._resolve(sub3.flight, exc=e)
            for _, sub3 in retry_at:
                self._resolve(sub3.flight, exc=e)
            self._drain_queue(exc=e)
            raise

    # -- lifecycle ---------------------------------------------------------------

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("InferenceService is closed")
        if self._broken is not None:
            raise RuntimeError(
                f"InferenceService dispatch failed: {self._broken!r}"
            )

    def _drain_queue(self, exc: BaseException) -> None:
        """Fail every queued submission; stop sentinels are preserved
        (re-enqueued) so dispatchers racing this drain still shut down."""
        sentinels = 0
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            if item is _SENTINEL:
                sentinels += 1
            else:
                self._resolve(item.flight, exc=exc)
        for _ in range(sentinels):
            self._queue.put(_SENTINEL)

    def close(self, timeout: float = 30.0) -> None:
        """Drain and stop: queued work is dispatched to completion (FIFO —
        the stop sentinels sit behind it), in-flight decode finishes, then
        dispatcher threads exit and are joined."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            threads = list(self._threads)
        for _ in threads:
            self._queue.put(_SENTINEL)
        self._wake.set()
        for t in threads:
            t.join(timeout=timeout)
        # a submit racing close may have enqueued behind the sentinels:
        # fail those tickets rather than strand their waiters
        self._drain_queue(exc=RuntimeError("InferenceService closed"))

    def __enter__(self) -> "InferenceService":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # -- introspection -----------------------------------------------------------

    def snapshot(self) -> dict:
        """Service counters plus (for slot engines) the batcher's
        occupancy/throughput counters."""
        with self._lock:
            d = {
                "engine": self.name,
                "mode": "batcher" if self._streaming else "threads",
                "dispatchers": len(self._threads),
                "inflight": len(self._inflight),
                **self.stats.as_dict(),
            }
        batcher = self.engine.serving_stats()
        if batcher:
            d["batcher"] = batcher
        return d
