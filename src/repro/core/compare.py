"""Model comparison (paper §4.3-4.4): paired significance test (selected per
Table 2) + effect size + CI of the per-example difference.

Two entry points:

* :func:`compare_scores` — the in-memory path, on aligned per-example
  score vectors.
* :func:`compare_stream_stats` — the streaming path, on the O(B) replicate
  state two runs carry in :class:`~repro.stats.streaming.StreamingStats`.
  Because the Poisson-bootstrap weight for an example depends only on
  ``(seed, example position)`` — never on the model — two models evaluated
  over the same chunk layout share their weight streams
  replicate-for-replicate, so the elementwise difference of their
  replicate means *is* the paired bootstrap distribution of the mean
  difference: Δ*_b = Σ w_b·(x^A − x^B) / Σ w_b when both arms score the
  same examples.  Paired inference without per-example scores.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.stages import EvalResult
from repro.stats.bootstrap import compute_ci, replicate_p_value
from repro.stats.effect import (
    EffectSize,
    hedges_g,
    hedges_g_from_moments,
    odds_ratio,
)
from repro.stats.select import TestRecommendation, recommend_test, run_recommended
from repro.stats.significance import TestResult
from repro.stats.streaming import StreamingStats


@dataclasses.dataclass
class Comparison:
    metric: str
    mean_a: float
    mean_b: float
    diff: float
    diff_ci: tuple[float, float]
    test: TestResult
    recommendation: TestRecommendation
    effect: EffectSize
    n: int

    def summary(self, alpha: float = 0.05) -> str:
        sig = "SIGNIFICANT" if self.test.p_value < alpha else "not significant"
        return (
            f"{self.metric}: A={self.mean_a:.4f} B={self.mean_b:.4f} "
            f"Δ={self.diff:+.4f} CI=({self.diff_ci[0]:+.4f},{self.diff_ci[1]:+.4f}) "
            f"{self.test.test} p={self.test.p_value:.4g} [{sig}] "
            f"{self.effect.name}={self.effect.value:.3f} ({self.effect.magnitude})"
        )


def compare_scores(
    metric: str,
    a: np.ndarray,
    b: np.ndarray,
    *,
    confidence: float = 0.95,
    n_boot: int = 1000,
    seed: int = 0,
) -> Comparison:
    """Paired comparison on aligned per-example score vectors."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    keep = ~(np.isnan(a) | np.isnan(b))
    a, b = a[keep], b[keep]
    rec = recommend_test(a, b)
    test = run_recommended(a, b, seed=seed)
    binary = rec.test == "mcnemar"
    effect = odds_ratio(a, b) if binary else hedges_g(a, b)
    diff = a - b
    iv = compute_ci(
        diff, method="percentile", confidence=confidence, n_boot=n_boot, seed=seed
    )
    return Comparison(
        metric=metric,
        mean_a=float(a.mean()),
        mean_b=float(b.mean()),
        diff=float(diff.mean()),
        diff_ci=(iv.lo, iv.hi),
        test=test,
        recommendation=rec,
        effect=effect,
        n=len(a),
    )


def compare_stream_stats(
    metric: str,
    a: StreamingStats,
    b: StreamingStats,
    *,
    confidence: float = 0.95,
) -> Comparison:
    """Paired comparison from two streaming runs' replicate states.

    Valid only when ``a.comparable_with(b)`` is None (same seed, B,
    backend and chunk layout — i.e. shared weight streams); callers
    gate on that.  The test is the paired-delta bootstrap: a CI-inversion
    p-value on the replicate-delta distribution, reported as
    ``paired_bootstrap``.  Effect size is Hedges' g from the two arms'
    moments (the discordant-pair table McNemar needs is not recoverable
    from O(B) state, so binary metrics use the same delta test).
    """
    reason = a.comparable_with(b)
    if reason is not None:
        raise ValueError(f"streaming runs are not paired-comparable: {reason}")
    acc_a, acc_b = a.accs[metric], b.accs[metric]
    deltas = a.engine.view(metric).means() - b.engine.view(metric).means()
    diff = acc_a.mean - acc_b.mean
    alpha = (1 - confidence) / 2
    lo, hi = np.quantile(deltas, [alpha, 1 - alpha])
    se = float(deltas.std(ddof=1)) if deltas.size > 1 else 0.0
    n = min(acc_a.n, acc_b.n)
    test = TestResult(
        "paired_bootstrap",
        diff / se if se > 0 else 0.0,
        replicate_p_value(deltas),
        n,
        detail={"n_boot": int(deltas.size), "backend": a.engine.backend},
    )
    rec = TestRecommendation(
        "paired_bootstrap",
        "streaming: paired Poisson-bootstrap replicate deltas over shared "
        f"weight streams (B={deltas.size}), per-example scores not retained",
    )
    effect = hedges_g_from_moments(
        acc_a.mean, acc_a.variance, acc_a.n,
        acc_b.mean, acc_b.variance, acc_b.n,
    )
    return Comparison(
        metric=metric,
        mean_a=acc_a.mean,
        mean_b=acc_b.mean,
        diff=diff,
        diff_ci=(float(lo), float(hi)),
        test=test,
        recommendation=rec,
        effect=effect,
        n=n,
    )


def compare_results(
    res_a: EvalResult, res_b: EvalResult, **kw
) -> dict[str, Comparison]:
    out: dict[str, Comparison] = {}
    for metric in res_a.scores:
        if metric not in res_b.scores:
            continue
        out[metric] = compare_scores(
            metric, res_a.scores[metric], res_b.scores[metric], **kw
        )
    return out
