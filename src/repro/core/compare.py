"""Model comparison (paper §4.3-4.4): paired significance test (selected per
Table 2) + effect size + CI of the per-example difference."""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.stages import EvalResult
from repro.stats.bootstrap import compute_ci
from repro.stats.effect import EffectSize, hedges_g, odds_ratio
from repro.stats.select import TestRecommendation, recommend_test, run_recommended
from repro.stats.significance import TestResult


@dataclasses.dataclass
class Comparison:
    metric: str
    mean_a: float
    mean_b: float
    diff: float
    diff_ci: tuple[float, float]
    test: TestResult
    recommendation: TestRecommendation
    effect: EffectSize
    n: int

    def summary(self, alpha: float = 0.05) -> str:
        sig = "SIGNIFICANT" if self.test.p_value < alpha else "not significant"
        return (
            f"{self.metric}: A={self.mean_a:.4f} B={self.mean_b:.4f} "
            f"Δ={self.diff:+.4f} CI=({self.diff_ci[0]:+.4f},{self.diff_ci[1]:+.4f}) "
            f"{self.test.test} p={self.test.p_value:.4g} [{sig}] "
            f"{self.effect.name}={self.effect.value:.3f} ({self.effect.magnitude})"
        )


def compare_scores(
    metric: str,
    a: np.ndarray,
    b: np.ndarray,
    *,
    confidence: float = 0.95,
    n_boot: int = 1000,
    seed: int = 0,
) -> Comparison:
    """Paired comparison on aligned per-example score vectors."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    keep = ~(np.isnan(a) | np.isnan(b))
    a, b = a[keep], b[keep]
    rec = recommend_test(a, b)
    test = run_recommended(a, b, seed=seed)
    binary = rec.test == "mcnemar"
    effect = odds_ratio(a, b) if binary else hedges_g(a, b)
    diff = a - b
    iv = compute_ci(
        diff, method="percentile", confidence=confidence, n_boot=n_boot, seed=seed
    )
    return Comparison(
        metric=metric,
        mean_a=float(a.mean()),
        mean_b=float(b.mean()),
        diff=float(diff.mean()),
        diff_ci=(iv.lo, iv.hi),
        test=test,
        recommendation=rec,
        effect=effect,
        n=len(a),
    )


def compare_results(
    res_a: EvalResult, res_b: EvalResult, **kw
) -> dict[str, Comparison]:
    out: dict[str, Comparison] = {}
    for metric in res_a.scores:
        if metric not in res_b.scores:
            continue
        out[metric] = compare_scores(
            metric, res_a.scores[metric], res_b.scores[metric], **kw
        )
    return out
