"""Suite-level inference-budget scheduler: certify verdicts, not datasets.

An exhaustive suite scores every example of every task under every model.
The adaptive scheduler instead treats scored examples as a *budget* and
spends it where the statistics say the answer is still open: after a seed
round, each subsequent round of chunks goes to the tasks whose relevant
anytime-valid intervals are widest, until every task is **certified**
(pairwise verdicts decided at the caller's margin, or a single-arm CI at
target width), its data source is exhausted, or the budget runs out.

Optional stopping is safe here *by construction*: all intervals come from
the confidence sequences of :mod:`repro.stats.sequential`, which hold
simultaneously over all sample sizes — peeking after every round cannot
inflate the error beyond alpha.  Pairwise verdicts ride on the shared
Poisson-bootstrap weight streams of :mod:`repro.stats.streaming` (paired
replicate-delta variance, no per-example scores).

Mechanically the scheduler is a thin loop over the existing machinery:

* **rounds are resumes** — each round re-runs a task over a fresh source
  iterator with its declared example cap
  (``StreamingConfig.max_examples``) raised by a chunk multiple.  Because
  caps are exact chunk multiples, the chunk layout — and therefore every
  chunk digest and bootstrap offset — is identical across rounds, so the
  spill manifest replays all prior rounds' chunks and only the newly
  allocated chunks run inference.  Crash-resume and incremental
  evaluation are literally the same code path.
* **pairing is preserved** — per-arm width stopping is disabled for
  multi-arm tasks (arms stopping at different n would desynchronize the
  shared weight streams and break paired comparison); all pair-level
  stopping happens here, through equal round caps per arm.  Single-arm
  tasks keep their own :class:`~repro.stats.sequential.StoppingRule`.
* **determinism** — allocation decisions are pure functions of the
  (deterministic) round results, so re-running a finished or interrupted
  adaptive suite with the same budget over the same spill dirs reproduces
  the identical stop points, consumed counts and certified matrix.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.core.config import EvalTask
from repro.core.suite import EvalSuite, SuiteJob, SuiteResult, build_comparisons
from repro.metrics.registry import resolve_metrics
from repro.stats.sequential import (
    StoppingRule,
    rho_opt,
    sequential_ci,
    sequential_compare,
)


@dataclasses.dataclass(frozen=True)
class BudgetConfig:
    """Suite-wide adaptive sampling budget, in scored examples.

    ``total_examples`` bounds the *fresh* examples scored across all
    (model, task) arms; chunks replayed from a spill manifest are free.
    Each task's first (seed) round always runs — a certification needs at
    least ``min_examples`` to stand on — even if it overshoots a tiny
    budget; every later allocation is refused once it would exceed the
    total.
    """

    total_examples: int
    #: examples added per arm when a task wins a round (rounded up to the
    #: task's chunk size so the chunk layout never shifts between rounds)
    round_examples: int = 1024
    #: seed-round size per arm, and the sample size the confidence
    #: sequence is tuned to be tightest at (when ``rho`` is 0)
    min_examples: int = 256
    #: single-arm tasks certify when their CI half-width reaches this
    #: (0 = single-arm tasks only finish by stopping rule / exhaustion)
    target_half_width: float = 0.0
    alpha: float = 0.05
    #: certification margin for pairwise verdicts (0 = any difference)
    margin: float = 0.0
    #: metric to certify on ("" = the task's first metric)
    metric: str = ""
    rho: float = 0.0
    method: str = "acs"
    #: backstop on scheduler iterations, not a statistical parameter
    max_rounds: int = 1000

    def effective_rho(self) -> float:
        """Fixed mixture parameter for the whole run: anytime validity
        needs one rho across all looks, so it is tuned once (at
        ``min_examples``), never re-tuned at the current n."""
        if self.rho > 0.0:
            return self.rho
        return rho_opt(max(self.min_examples, 2), self.alpha)


def _round_up(n: int, chunk: int) -> int:
    return ((max(n, 1) + chunk - 1) // chunk) * chunk


def _cert_metric(task: EvalTask, budget: BudgetConfig) -> str:
    names = [name for name, _ in resolve_metrics(task.metrics)]
    if budget.metric:
        if budget.metric not in names:
            raise ValueError(
                f"budget certifies on metric {budget.metric!r} but task "
                f"{task.task_id!r} computes {names}"
            )
        return budget.metric
    return names[0]


def _arm_task(task: EvalTask, n_arms: int) -> EvalTask:
    if n_arms > 1 and task.stopping.enabled:
        # per-arm width stopping would stop arms at different n, which
        # desynchronizes the shared bootstrap weight streams and forfeits
        # the paired comparison — pair-level stopping belongs to the
        # scheduler's equal round caps
        return dataclasses.replace(task, stopping=StoppingRule())
    return task


def run_adaptive_suite(
    session: Any, suite: EvalSuite, budget: BudgetConfig
) -> SuiteResult:
    """Run ``suite`` adaptively under ``budget`` and return a
    :class:`~repro.core.suite.SuiteResult` whose ``adaptive`` payload
    records, per task: examples consumed per arm, whether the source was
    exhausted, the certified verdicts with the sample size they were
    certified at, and the budget spent."""
    jobs = suite.jobs()
    by_task: dict[str, list[SuiteJob]] = {}
    for job in jobs:
        by_task.setdefault(job.task.task_id, []).append(job)
    task_order = suite.task_ids()

    for tid in task_order:
        arms = by_task[tid]
        t = arms[0].task
        if not t.streaming.enabled or not t.streaming.spill_dir:
            raise ValueError(
                f"adaptive suite requires streaming with a spill_dir "
                f"(rounds resume prior rounds' chunks); task {tid!r} has "
                f"enabled={t.streaming.enabled} "
                f"spill_dir={t.streaming.spill_dir!r}"
            )
        if not callable(arms[0].rows):
            raise ValueError(
                f"adaptive suite requires a zero-arg rows factory (each "
                f"round re-slices a fresh iterator); task {tid!r} was "
                "added with a materialized list"
            )

    chunk = {tid: by_task[tid][0].task.streaming.max_memory_rows
             for tid in task_order}
    caps = {tid: _round_up(budget.min_examples, chunk[tid])
            for tid in task_order}
    consumed: dict[tuple[str, str], int] = {}
    results: dict[tuple[str, str], Any] = {}
    state: dict[str, dict] = {
        tid: {"done": False, "reason": "", "half_width": float("inf"),
              "exhausted": False, "verdicts": {}, "metric": "",
              "certified_n": 0}
        for tid in task_order
    }

    def spent() -> int:
        # chunks replayed across rounds are counted once: `consumed` holds
        # the latest (cumulative) count per arm, overwritten each round
        return sum(consumed.values())

    def assess(tid: str) -> None:
        arms = by_task[tid]
        task = arms[0].task
        labels = [j.model_label for j in arms]
        metric = _cert_metric(task, budget)
        st = state[tid]
        st["metric"] = metric
        streams = {
            lab: results[(lab, tid)].stream_stats
            for lab in labels if (lab, tid) in results
        }
        n_max = max(
            (consumed.get((lab, tid), 0) for lab in labels), default=0
        )
        if len(labels) >= 2:
            undecided_w: list[float] = []
            all_w: list[float] = []
            verdicts: dict[str, str] = {}
            for i, a in enumerate(labels):
                for b in labels[i + 1:]:
                    c = sequential_compare(
                        metric, streams[a], streams[b],
                        alpha=budget.alpha, margin=budget.margin,
                        rho=budget.effective_rho(), method=budget.method,
                    )
                    verdicts[f"{a} vs {b}"] = c.verdict
                    all_w.append(c.half_width)
                    if c.verdict == "undecided":
                        undecided_w.append(c.half_width)
            st["verdicts"] = verdicts
            # allocation ranks open tasks by their widest *undecided* pair;
            # once everything is decided this is the half-width at stop
            st["half_width"] = max(
                undecided_w, default=max(all_w, default=0.0)
            )
            if not undecided_w:
                st["done"], st["reason"] = True, "certified"
                st["certified_n"] = n_max
        else:
            lab = labels[0]
            iv = sequential_ci(
                streams[lab].accs[metric], alpha=budget.alpha,
                rho=budget.effective_rho(), method=budget.method,
            )
            st["half_width"] = iv.half_width
            if (
                budget.target_half_width > 0.0
                and iv.half_width <= budget.target_half_width
            ):
                st["done"], st["reason"] = True, "certified"
                st["certified_n"] = n_max
        if not st["done"]:
            adaptive_logs = [
                results[(lab, tid)].logs.get("adaptive") or {}
                for lab in labels if (lab, tid) in results
            ]
            if any(a.get("stopped") for a in adaptive_logs):
                st["done"] = True
                st["reason"] = next(
                    a.get("reason", "stopped")
                    for a in adaptive_logs if a.get("stopped")
                )
                st["certified_n"] = n_max
            elif st["exhausted"]:
                st["done"], st["reason"] = True, "exhausted"
                st["certified_n"] = n_max

    rounds = 0
    pending = set(task_order)
    while pending and rounds < budget.max_rounds:
        rounds += 1
        if rounds == 1:
            order = [t for t in task_order if t in pending]
        else:
            # widest open interval first; suite order breaks ties so the
            # schedule is a pure function of the (deterministic) results
            idx = {t: i for i, t in enumerate(task_order)}
            order = sorted(
                pending, key=lambda t: (-state[t]["half_width"], idx[t])
            )
        ran_any = False
        for tid in order:
            arms = by_task[tid]
            prev = sum(consumed.get((j.model_label, tid), 0) for j in arms)
            alloc = caps[tid] * len(arms) - prev
            if alloc <= 0:
                continue
            # seed rounds (prev == 0) always run — a certification needs
            # min_examples to stand on; every later allocation respects
            # the budget
            if prev > 0 and spent() + alloc > budget.total_examples:
                continue
            for job in arms:
                task = _arm_task(job.task, len(arms)).with_streaming(
                    max_examples=caps[tid]
                )
                res = session.run_task(job.rows(), task)
                key = (job.model_label, tid)
                results[key] = res
                n = res.logs["streaming"]["n_examples"]
                consumed[key] = n
                if n < caps[tid]:
                    state[tid]["exhausted"] = True
            ran_any = True
            assess(tid)
            if state[tid]["done"]:
                pending.discard(tid)
            else:
                caps[tid] += _round_up(budget.round_examples, chunk[tid])
        if not ran_any:
            break  # nothing affordable: remaining tasks end undecided

    for tid in pending:
        state[tid]["reason"] = state[tid]["reason"] or "budget_exhausted"

    comparisons = build_comparisons(suite, results)
    accounting = session.accounting.as_dict()
    serving = session.serving_stats()
    if serving:
        accounting["serving"] = serving
    adaptive = {
        "budget": {
            **dataclasses.asdict(budget),
            "spent": spent(),
            "rounds": rounds,
        },
        "tasks": {
            tid: {
                "consumed": {
                    j.model_label: consumed.get((j.model_label, tid), 0)
                    for j in by_task[tid]
                },
                "exhausted": state[tid]["exhausted"],
                "certified": state[tid]["reason"] == "certified",
                "reason": state[tid]["reason"],
                "metric": state[tid]["metric"],
                "half_width": state[tid]["half_width"],
                "verdicts": state[tid]["verdicts"],
                "n_at_stop": state[tid]["certified_n"] or max(
                    (consumed.get((j.model_label, tid), 0)
                     for j in by_task[tid]), default=0,
                ),
            }
            for tid in task_order
        },
    }
    return SuiteResult(
        name=suite.name,
        models=suite.model_labels(),
        tasks=suite.task_ids(),
        results=results,
        comparisons=comparisons,
        accounting=accounting,
        adaptive=adaptive,
    )
