"""The paper's primary contribution: distributed, statistically rigorous
LLM evaluation — config system, rate-limited cached inference orchestration,
metric computation, statistical aggregation, model comparison, tracking."""

from repro.core.cache import CacheEntry, CacheMiss, ResponseCache
from repro.core.compare import Comparison, compare_results, compare_scores
from repro.core.config import (
    CachePolicy,
    DataConfig,
    EngineModelConfig,
    EvalTask,
    InferenceConfig,
    MetricConfig,
    StatisticsConfig,
    cache_key,
)
from repro.core.engines import (
    InferenceEngine,
    InferenceRequest,
    InferenceResponse,
    LocalJaxEngine,
    SimulatedAPIEngine,
    api_cost,
    create_engine,
    get_engine,
    retry_with_backoff,
)
from repro.core.ratelimit import AdaptiveLimiter, TokenBucket
from repro.core.runner import EvalResult, EvalRunner, MetricValue
from repro.core.tracking import RunTracker

__all__ = [
    "AdaptiveLimiter", "CacheEntry", "CacheMiss", "CachePolicy", "Comparison",
    "DataConfig", "EngineModelConfig", "EvalResult", "EvalRunner", "EvalTask",
    "InferenceConfig", "InferenceEngine", "InferenceRequest",
    "InferenceResponse", "LocalJaxEngine", "MetricConfig", "MetricValue",
    "ResponseCache", "RunTracker", "SimulatedAPIEngine", "StatisticsConfig",
    "TokenBucket", "api_cost", "cache_key", "compare_results",
    "compare_scores", "create_engine", "get_engine", "retry_with_backoff",
]
