"""The paper's primary contribution: distributed, statistically rigorous
LLM evaluation — config system, session-owned shared resources, the
composable stage pipeline, metric computation, statistical aggregation,
multi-model suite comparison, tracking."""

from repro.core.budget import BudgetConfig, run_adaptive_suite
from repro.core.cache import CacheEntry, CacheMiss, ResponseCache
from repro.core.compare import (
    Comparison,
    compare_results,
    compare_scores,
    compare_stream_stats,
)
from repro.core.config import (
    CachePolicy,
    DataConfig,
    EngineModelConfig,
    EvalTask,
    InferenceConfig,
    MetricConfig,
    StatisticsConfig,
    StreamingConfig,
    cache_key,
)
from repro.core.engines import (
    BatcherStats,
    EngineRegistry,
    InferenceEngine,
    InferenceRequest,
    InferenceResponse,
    LocalJaxEngine,
    RecoverableEngineError,
    SimulatedAPIEngine,
    SimulatedSlotEngine,
    api_cost,
    create_engine,
    get_engine,
    retry_with_backoff,
)
from repro.core.ratelimit import AdaptiveLimiter, TokenBucket
from repro.core.runner import EvalRunner
from repro.core.service import (
    InferenceService,
    ReplicaHungError,
    ServiceStats,
    ServiceTicket,
)
from repro.core.session import EvalSession, SessionAccounting
from repro.core.stages import (
    AggregateStage,
    CostBudgetExceeded,
    CostBudgetMiddleware,
    EvalArtifact,
    EvalResult,
    InferStage,
    LockStepInferStage,
    MetricValue,
    Middleware,
    PrepareStage,
    ProgressMiddleware,
    ScoreStage,
    Stage,
    StaticResponsesStage,
    TrackingMiddleware,
    default_stages,
    rescore_stages,
)
from repro.core.streaming import (
    ConcurrentStreamingExecutor,
    ManifestMismatch,
    StreamingPipeline,
)
from repro.core.suite import EvalSuite, SuiteJob, SuiteResult
from repro.core.tracking import RunTracker

__all__ = [
    "AdaptiveLimiter", "AggregateStage", "BudgetConfig", "CacheEntry",
    "CacheMiss",
    "CachePolicy", "Comparison", "ConcurrentStreamingExecutor",
    "CostBudgetExceeded", "CostBudgetMiddleware",
    "DataConfig", "EngineModelConfig", "EngineRegistry", "EvalArtifact",
    "EvalResult", "EvalRunner", "EvalSession", "EvalSuite", "EvalTask",
    "BatcherStats", "InferStage", "InferenceConfig", "InferenceEngine",
    "InferenceRequest", "InferenceResponse", "InferenceService",
    "LocalJaxEngine", "LockStepInferStage", "ManifestMismatch", "MetricConfig",
    "MetricValue", "Middleware", "PrepareStage", "ProgressMiddleware",
    "RecoverableEngineError", "ReplicaHungError",
    "ResponseCache", "RunTracker", "ScoreStage", "SessionAccounting",
    "ServiceStats", "ServiceTicket", "SimulatedAPIEngine",
    "SimulatedSlotEngine", "Stage", "StaticResponsesStage", "StatisticsConfig",
    "StreamingConfig", "StreamingPipeline", "SuiteJob", "SuiteResult",
    "TokenBucket", "TrackingMiddleware", "api_cost",
    "cache_key", "compare_results", "compare_scores", "compare_stream_stats",
    "create_engine",
    "default_stages", "get_engine", "rescore_stages", "retry_with_backoff",
    "run_adaptive_suite",
]
