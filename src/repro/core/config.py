"""Hierarchical evaluation-task configuration (paper §3.4).

The complete specification of an evaluation serializes to JSON and is stored
alongside results — reproducibility by construction.
"""

from __future__ import annotations

import dataclasses
import enum
import hashlib
import json
from typing import Any

from repro.stats.sequential import StoppingRule


class CachePolicy(str, enum.Enum):
    ENABLED = "enabled"      # lookup before inference, cache new responses
    READ_ONLY = "read_only"  # lookup only
    WRITE_ONLY = "write_only"  # cache warming: always infer, always cache
    REPLAY = "replay"        # strict: error on cache miss (zero API calls)
    DISABLED = "disabled"


@dataclasses.dataclass(frozen=True)
class EngineModelConfig:
    """Which model answers the prompts (provider = 'local' runs on-pod)."""

    provider: str = "local"          # local | openai | anthropic | google
    model_name: str = "qwen3-4b"
    temperature: float = 0.0
    max_tokens: int = 64
    # local-engine extras
    reduced: bool = True             # serve the reduced config (CPU tests)
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class InferenceConfig:
    batch_size: int = 16
    n_workers: int = 4
    rate_limit_rpm: float = 10_000.0
    rate_limit_tpm: float = 2_000_000.0
    adaptive_rate: bool = False
    cache_policy: CachePolicy = CachePolicy.ENABLED
    cache_dir: str = ""
    max_retries: int = 3
    retry_delay: float = 1.0
    # straggler mitigation (ft/)
    speculative_reissue: bool = False
    straggler_factor: float = 3.0
    # shared asynchronous inference service (core/service.py):
    # use_service=False restores the legacy lock-step per-shard path
    use_service: bool = True
    #: outstanding-request bound before submit blocks (backpressure)
    service_queue_depth: int = 256
    #: single-flight coalescing of identical in-flight cache keys
    coalesce: bool = True
    #: batch-formation window for a cold batcher loop (slot engines only)
    max_batch_wait_ms: float = 2.0
    #: data-parallel engine replicas behind one service front: each gets
    #: its own engine instance (own batcher / decode slots; local engines
    #: additionally get their own device group from the mesh) while the
    #: flight table stays global — identical in-flight prompts still pay
    #: one engine call no matter which replica serves them.  Responses are
    #: a pure function of the request, so replica count never changes a
    #: metric byte.
    n_replicas: int = 1
    #: replica-placement policy: least_loaded | prefix_affinity | round_robin
    routing: str = "least_loaded"
    #: prompt-prefix bytes hashed by the prefix_affinity policy
    routing_prefix_len: int = 64
    #: per-step prefill admissions cap for slot engines (0 = unlimited):
    #: disaggregates prefill from decode so a long-prompt backlog queues
    #: for a prefill slot instead of stalling every decode step
    max_prefills_per_step: int = 0
    #: paged KV cache for slot engines: 0 = contiguous per-slot cache,
    #: > 0 = page-pool cache with this many tokens per page (enables
    #: hash-chain prompt-prefix sharing across requests — DESIGN.md §8)
    kv_page_size: int = 0
    #: with a paged cache, share resident prompt-prefix pages across
    #: requests (False = paged allocation only, no cross-request reuse)
    prefix_cache: bool = True
    #: KV page storage precision: "bf16" = full-precision pages, "int8" =
    #: absmax block-quantized pages + per-(page, head) f32 scales,
    #: dequantized in-kernel at decode (DESIGN.md §10).  Halves
    #: bytes-per-token, so the same pool byte budget admits ~2x pages;
    #: requires kv_page_size > 0.  Outputs stay byte-identical across
    #: replicas/routing/page sizes at *fixed* dtype; int8-vs-bf16 parity
    #: is a tolerance + token-match-rate gate, not bit equality.
    kv_cache_dtype: str = "bf16"
    # fault tolerance for the serving fabric (DESIGN.md §9):
    #: per-request deadline on the streaming path (0 = none).  On expiry
    #: the service hedges: re-issues the ticket to another alive replica;
    #: first completion wins, the loser's slot is cancelled.  Responses
    #: are a pure function of the request, so hedging never changes a
    #: metric byte.
    request_deadline_s: float = 0.0
    #: bounded-backoff restarts per broken replica before its in-flight
    #: work fails over to the fleet-dead path (0 = legacy: first crash
    #: kills the replica for good)
    max_replica_restarts: int = 2
    #: base delay for the exponential replica-restart backoff
    restart_backoff_s: float = 0.05
    #: health probe: a replica with in-flight work but no engine progress
    #: (no decode steps, no completions) for this many consecutive pumps
    #: is marked suspect and drain-and-restarted (0 = disabled)
    health_probe_steps: int = 0


@dataclasses.dataclass(frozen=True)
class MetricConfig:
    name: str                         # registry key, e.g. "exact_match"
    type: str = "lexical"             # lexical | semantic | llm_judge | rag
    params: dict = dataclasses.field(default_factory=dict)

    def __hash__(self) -> int:
        return hash((self.name, self.type, json.dumps(self.params, sort_keys=True)))


@dataclasses.dataclass(frozen=True)
class StatisticsConfig:
    confidence_level: float = 0.95
    bootstrap_iterations: int = 1000
    ci_method: str = "bca"            # percentile | bca | analytical
    significance_threshold: float = 0.05
    seed: int = 0
    #: bootstrap execution backend for streaming aggregation:
    #: "numpy"  — host Philox(seed, chunk_start) weight blocks, one
    #:            (B, chunk) float64 matrix per metric per chunk;
    #: "pallas" — device-resident chunked partials (one kernel launch per
    #:            chunk covers all metrics; counter-mixer PRNG keyed by the
    #:            absolute example position, O(B x n_metrics) host state).
    #: The two backends draw different (each internally deterministic)
    #: weight streams, so the backend is part of the resume key.
    backend: str = "numpy"


@dataclasses.dataclass(frozen=True)
class StreamingConfig:
    """Bounded-memory chunked execution (paper-scale datasets).

    When ``enabled``, the session runs prepare→infer→score per chunk of
    ``max_memory_rows`` examples, folds scores into mergeable accumulators
    (:mod:`repro.stats.streaming`), and discards raw responses — peak
    per-example state is O(chunk), not O(dataset).  With a ``spill_dir``,
    each completed chunk commits its partial state to a DeltaLite manifest
    so an interrupted run resumes by skipping completed chunks.

    ``max_inflight_chunks > 1`` runs that many whole chunks concurrently
    on a chunk-level worker pool (the paper's executor layer lifted from
    shards to chunks): peak resident examples become
    ``max_inflight_chunks x max_memory_rows``, chunk states are merged
    deterministically in chunk order, and results stay bit-identical to
    the serial pipeline.  Like the other execution-strategy knobs it is
    excluded from the resume key — a restart may retune it freely.
    """

    enabled: bool = False
    max_memory_rows: int = 1024       # chunk size == peak resident examples
    spill_dir: str = ""               # "" = no spill, run is not resumable
    resume: bool = True               # skip chunks already in the manifest
    max_inflight_chunks: int = 1      # >1 = concurrent chunk execution
    #: explicit cap on examples consumed from the source (0 = unbounded).
    #: Unlike silently slicing the source, a declared cap lets a resumed
    #: run distinguish "I stopped at my cap" (committed chunks past it are
    #: fine — a later run with a larger cap will merge them) from "the
    #: data source shrank" (refused).  The budget scheduler
    #: (:mod:`repro.core.budget`) raises this cap round by round; it is
    #: excluded from the resume key like the other execution knobs.
    max_examples: int = 0


@dataclasses.dataclass(frozen=True)
class DataConfig:
    prompt_template: str = "{question}"
    input_columns: tuple[str, ...] = ("question",)
    reference_column: str = "reference"


@dataclasses.dataclass(frozen=True)
class EvalTask:
    task_id: str
    model: EngineModelConfig = EngineModelConfig()
    inference: InferenceConfig = InferenceConfig()
    metrics: tuple[MetricConfig, ...] = (MetricConfig("exact_match"),)
    statistics: StatisticsConfig = StatisticsConfig()
    data: DataConfig = DataConfig()
    streaming: StreamingConfig = StreamingConfig()
    #: per-task adaptive early stopping (:mod:`repro.stats.sequential`):
    #: when enabled, the streaming pipelines consult the rule after every
    #: merged chunk and terminate sampling once it fires.  The rule's
    #: statistical fields are validated against the spill manifest on
    #: resume — one manifest, one certification regime.
    stopping: StoppingRule = StoppingRule()

    def with_model(self, model: "EngineModelConfig") -> "EvalTask":
        """Rebind the task to another model (used by suite model sweeps)."""
        return dataclasses.replace(self, model=model)

    def with_streaming(self, **kw: Any) -> "EvalTask":
        """Enable (or reconfigure) bounded-memory streaming execution.
        Unspecified fields keep their current values.  ``concurrency`` is
        accepted as an alias for ``max_inflight_chunks``:
        ``task.with_streaming(concurrency=4)`` runs four chunks in flight.
        """
        kw.setdefault("enabled", True)
        if "concurrency" in kw:
            kw["max_inflight_chunks"] = kw.pop("concurrency")
        return dataclasses.replace(
            self, streaming=dataclasses.replace(self.streaming, **kw)
        )

    def with_stopping(self, **kw: Any) -> "EvalTask":
        """Enable (or reconfigure) adaptive early stopping, e.g.
        ``task.with_stopping(target_half_width=0.02, min_examples=512)``.
        Unspecified fields keep their current values; requires streaming
        execution to have any effect (the in-memory path scores every row
        it was given)."""
        kw.setdefault("enabled", True)
        return dataclasses.replace(
            self, stopping=dataclasses.replace(self.stopping, **kw)
        )

    def with_metrics(self, *metrics: "MetricConfig") -> "EvalTask":
        """Rebind the metric set (used by cache-replay metric iteration)."""
        return dataclasses.replace(self, metrics=tuple(metrics))

    def to_json(self) -> str:
        def default(o: Any):
            if dataclasses.is_dataclass(o):
                return dataclasses.asdict(o)
            if isinstance(o, enum.Enum):
                return o.value
            raise TypeError(type(o))

        return json.dumps(dataclasses.asdict(self), default=default, sort_keys=True)

    def fingerprint(self) -> str:
        return hashlib.sha256(self.to_json().encode()).hexdigest()[:16]


def cache_key(
    prompt: str,
    model_name: str,
    provider: str,
    temperature: float,
    max_tokens: int,
) -> str:
    """Content-addressable key: SHA256(prompt||model||provider||T||max_tokens)."""
    payload = "\x1f".join(
        [prompt, model_name, provider, f"{temperature:.6g}", str(max_tokens)]
    )
    return hashlib.sha256(payload.encode()).hexdigest()
