"""Composable stage pipeline for the paper's four-stage evaluation (§3).

Each stage conforms to the :class:`Stage` protocol — ``run(artifact,
session) -> artifact`` — and a single typed :class:`EvalArtifact` flows
through the pipeline, accumulating prompts, responses, scores and
aggregates.  The default pipeline is

    PrepareStage -> InferStage -> ScoreStage -> AggregateStage

but new scenarios are a stage swap, not a fork of the runner: the paper's
cache-replay iteration loop re-scores cached responses by replacing
``InferStage`` with :class:`StaticResponsesStage` (zero engine calls), and
custom stages can be inserted anywhere in the list passed to
``EvalSession.run_task``.

Middleware objects observe the pipeline (``on_task_start``,
``on_stage_start``, ``on_stage_end``, ``on_task_end``) and implement
cross-cutting concerns: progress reporting, experiment tracking, and the
session cost-budget abort (:class:`CostBudgetMiddleware`).
"""

from __future__ import annotations

import dataclasses
import sys
import time
from typing import Any, Protocol, runtime_checkable

import numpy as np

from repro.core.cache import CacheEntry
from repro.core.config import CachePolicy, EvalTask, cache_key
from repro.core.engines import (
    InferenceRequest,
    InferenceResponse,
    retry_with_backoff,
)
from repro.core.ratelimit import AdaptiveLimiter
from repro.ft.workers import PoolStats
from repro.data.templates import render
from repro.metrics.registry import (
    BINARY_METRICS,
    JUDGE_METRICS,
    MetricContext,
    resolve_metrics,
)
from repro.stats.bootstrap import Interval, compute_ci

# -- results -------------------------------------------------------------------


@dataclasses.dataclass
class MetricValue:
    name: str
    value: float
    ci: tuple[float, float]
    ci_method: str
    n: int
    n_unscored: int = 0

    def __repr__(self) -> str:  # paper §5.6 display format
        return (
            f"MetricValue(value={self.value:.3f}, "
            f"ci=({self.ci[0]:.3f}, {self.ci[1]:.3f}), n={self.n})"
        )


@dataclasses.dataclass
class EvalResult:
    task_id: str
    metrics: dict[str, MetricValue]
    scores: dict[str, np.ndarray]
    responses: list[str]
    failures: list[dict]
    cache_stats: dict
    engine_stats: dict
    timing: dict
    logs: dict
    #: streaming runs only: merged accumulator + bootstrap-replicate state
    #: (:class:`repro.stats.streaming.StreamingStats`).  O(B) per metric —
    #: this is what makes pairwise significance possible for tasks that
    #: never materialize per-example score vectors.
    stream_stats: Any = None

    @property
    def throughput_per_min(self) -> float:
        dt = self.timing.get("infer_s", 0.0)
        # streaming runs discard responses; the count lives in the logs
        n = len(self.responses) or self.logs.get("streaming", {}).get(
            "n_examples", 0
        )
        return n / dt * 60.0 if dt > 0 else float("inf")


# -- artifact ------------------------------------------------------------------


@dataclasses.dataclass
class EvalArtifact:
    """The typed value flowing between stages.

    ``PrepareStage`` fills ``prompts``; ``InferStage`` (or a replacement)
    fills ``texts``/``responses``/``failures``; ``ScoreStage`` fills
    ``scores``; ``AggregateStage`` fills ``metrics``.  Timing is recorded
    by the pipeline loop under ``{stage.name}_s``.
    """

    rows: list[dict]
    task: EvalTask
    prompts: list[str] = dataclasses.field(default_factory=list)
    responses: list[InferenceResponse | None] = dataclasses.field(
        default_factory=list
    )
    texts: list[str] = dataclasses.field(default_factory=list)
    failures: list[dict] = dataclasses.field(default_factory=list)
    scores: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    metrics: dict[str, MetricValue] = dataclasses.field(default_factory=dict)
    cache_stats: dict = dataclasses.field(default_factory=dict)
    engine_stats: dict = dataclasses.field(default_factory=dict)
    timing: dict = dataclasses.field(default_factory=dict)
    logs: dict = dataclasses.field(default_factory=dict)

    def to_result(self) -> EvalResult:
        return EvalResult(
            task_id=self.task.task_id,
            metrics=self.metrics,
            scores=self.scores,
            responses=self.texts,
            failures=self.failures,
            cache_stats=self.cache_stats,
            engine_stats=self.engine_stats,
            timing=self.timing,
            logs=self.logs,
        )


@runtime_checkable
class Stage(Protocol):
    name: str

    def run(self, artifact: EvalArtifact, session: Any) -> EvalArtifact: ...


# -- stage 1: prompt preparation ------------------------------------------------


class PrepareStage:
    name = "prepare"

    def run(self, art: EvalArtifact, session: Any) -> EvalArtifact:
        # fail fast on unknown metrics before any paid inference happens
        resolve_metrics(art.task.metrics)
        art.prompts = [
            render(art.task.data.prompt_template, r) for r in art.rows
        ]
        return art


# -- stage 2: distributed inference ---------------------------------------------


@dataclasses.dataclass
class _ShardStats:
    """One shard attempt's own traffic, counted at the call site.

    Concurrent chunk workers share one engine, cache and pool, so deltas
    over their *global* counters would attribute another chunk's traffic
    to this stage.  Counting locally per shard and summing keeps per-task
    (and per-chunk) stats exact regardless of what else runs in parallel.

    Two sinks with different semantics: the *result* stats
    (``art.engine_stats`` / ``art.cache_stats``) sum only the winning
    attempt per shard — deterministic, parity with a serial run — while
    ``session.accounting`` receives every attempt's calls and cost as the
    shard finishes (see :meth:`LockStepInferStage.run`): a speculative
    loser's inference really happened and really cost money, and the
    cost-budget guard must see it.
    """

    calls: int = 0
    cost: float = 0.0
    hits: int = 0
    misses: int = 0
    writes: int = 0
    #: submissions that coalesced onto another submission's flight
    #: (service path only): answered, but nobody paid twice
    coalesced: int = 0


def _sum_shard_stats(parts) -> _ShardStats:
    totals = _ShardStats()
    for st in parts:
        for f in dataclasses.fields(_ShardStats):
            setattr(
                totals, f.name, getattr(totals, f.name) + getattr(st, f.name)
            )
    return totals


def _publish_infer_stats(
    art: EvalArtifact, cache, totals: _ShardStats, pool: dict
) -> None:
    """Assemble ``art.cache_stats`` / ``art.engine_stats`` from summed
    shard stats — shared by the service and lock-step paths so their
    result-stat semantics cannot drift apart."""
    if cache is not None:
        stats = cache.stats()  # entries/version stay session-absolute
        h, m = totals.hits, totals.misses
        stats.update(
            hits=h, misses=m, writes=totals.writes,
            hit_rate=h / (h + m) if h + m else 0.0,
        )
        art.cache_stats = stats
    else:
        art.cache_stats = {}
    art.engine_stats = {
        "calls": totals.calls,
        "total_cost": totals.cost,
        "coalesced": totals.coalesced,
        "pool": pool,
    }


class LockStepInferStage:
    """The legacy inference path: sharded lock-step execution over the
    session worker pool, with per-worker rate limiting at the call site.

    Kept as the benchmark baseline and as the escape hatch behind
    ``InferenceConfig.use_service=False``.  :class:`InferStage` (the
    default) routes the same shard/stats accounting through the shared
    :class:`~repro.core.service.InferenceService` instead, so batches form
    across shards, chunks, tasks and suites rather than within one shard.
    """

    name = "infer"

    def run(self, art: EvalArtifact, session: Any) -> EvalArtifact:
        task = art.task
        inf = task.inference
        prompts = art.prompts
        engine = session.engine_for(task.model)
        cache = session.cache_for(inf)
        limiter = session.limiter_for(inf)
        pool = session.pool_for(inf)

        count_lookups = cache is not None and cache.policy not in (
            CachePolicy.DISABLED, CachePolicy.WRITE_ONLY,
        )

        shards = [
            list(range(i, min(i + inf.batch_size, len(prompts))))
            for i in range(0, len(prompts), inf.batch_size)
        ]
        responses: list[InferenceResponse | None] = [None] * len(prompts)
        failures: list[dict] = []
        sleep = session.sleep

        def run_shard(shard_idx: int, idxs: list[int], worker: int):
            st = _ShardStats()
            try:
                return _do_shard(idxs, worker, st), st
            finally:
                # every attempt's spend reaches the session accounting —
                # including speculative losers and failed attempts whose
                # results are discarded by the pool; result-level stats
                # below sum only the winning attempts
                acct = session.accounting
                with acct.lock:
                    acct.engine_calls += st.calls
                    acct.cost_usd += st.cost

        def _do_shard(idxs: list[int], worker: int, st: "_ShardStats"):
            out: list[tuple[int, InferenceResponse, bool]] = []
            to_infer: list[int] = []
            for i in idxs:
                if cache is not None:
                    key = cache.key_for(
                        prompts[i], task.model.model_name, task.model.provider,
                        task.model.temperature, task.model.max_tokens,
                    )
                    hit = cache.lookup(key)
                    if hit is not None:
                        st.hits += 1
                        out.append(
                            (
                                i,
                                InferenceResponse(
                                    text=hit.response_text,
                                    input_tokens=hit.input_tokens or 0,
                                    output_tokens=hit.output_tokens or 0,
                                    latency_ms=0.0,
                                ),
                                True,
                            )
                        )
                        continue
                    if count_lookups:
                        st.misses += 1
                to_infer.append(i)
            w = worker % inf.n_workers
            new_entries: list[CacheEntry] = []
            for i in to_infer:
                est_tokens = len(prompts[i].split()) + task.model.max_tokens
                if isinstance(limiter, AdaptiveLimiter):
                    limiter.acquire(w, est_tokens)
                else:
                    limiter[w].acquire(est_tokens)
                req = InferenceRequest(
                    prompts[i], task.model.max_tokens, task.model.temperature
                )

                def _infer(req=req):
                    st.calls += 1
                    return engine.infer(req)

                resp = retry_with_backoff(
                    _infer,
                    max_retries=inf.max_retries,
                    base_delay=inf.retry_delay,
                    sleep=sleep,
                )
                st.cost += resp.cost_usd
                out.append((i, resp, False))
                if cache is not None and resp.error is None:
                    new_entries.append(
                        CacheEntry(
                            prompt_hash=cache.key_for(
                                prompts[i], task.model.model_name,
                                task.model.provider, task.model.temperature,
                                task.model.max_tokens,
                            ),
                            model_name=task.model.model_name,
                            provider=task.model.provider,
                            prompt_text=prompts[i],
                            response_text=resp.text,
                            input_tokens=resp.input_tokens,
                            output_tokens=resp.output_tokens,
                            latency_ms=resp.latency_ms,
                            created_at=time.time(),
                        )
                    )
            if new_entries:
                st.writes += cache.put(new_entries)
            return out

        n_cached = 0
        in_tok = out_tok = 0
        pool_stats = PoolStats()
        shard_results = pool.map_shards(run_shard, shards, stats_out=pool_stats)
        for sr in shard_results:
            rows, _st = sr.value
            for i, resp, cached in rows:
                responses[i] = resp
                if resp.error is not None:
                    failures.append({"index": i, "error": resp.error})
                elif cached:
                    n_cached += 1
                else:
                    in_tok += resp.input_tokens
                    out_tok += resp.output_tokens
        totals = _sum_shard_stats(sr.value[1] for sr in shard_results)

        art.responses = responses
        art.texts = [
            r.text if r is not None and r.error is None else "" for r in responses
        ]
        art.failures = failures
        _publish_infer_stats(
            art, cache, totals, dataclasses.asdict(pool_stats)
        )

        acct = session.accounting
        with acct.lock:
            acct.input_tokens += in_tok
            acct.output_tokens += out_tok
            if cache is not None:
                acct.cache_hits += n_cached
                acct.cache_misses += len(prompts) - n_cached
        return art


class InferStage:
    """Submit/gather inference through the session's shared
    :class:`~repro.core.service.InferenceService`.

    Every cache miss becomes a service submission *immediately* — before
    any response is gathered — so in-flight batches span shards (and, via
    the shared per-engine service, chunks, tasks and models).  Identical
    in-flight cache keys single-flight: one engine call, N waiters, and
    the spend (call count, cost, tokens, cache write) is credited to
    exactly one shard — the primary submitter's.

    Per-shard stats accounting is preserved exactly: the same shard
    layout, the same local-counting discipline (`_ShardStats`), and in a
    run without concurrent duplicates the same calls/cost/hits/misses/
    writes as :class:`LockStepInferStage`, which remains available behind
    ``InferenceConfig.use_service=False``.

    Shard-level *speculative re-issue* is intentionally subsumed rather
    than re-implemented: a speculative twin of an in-flight engine call is
    precisely the duplicate spend single-flight exists to eliminate, so a
    re-issued chunk's submissions coalesce onto the original flights
    instead of racing them.  Stuck-call mitigation at the request level is
    ``max_retries`` (dispatched centrally); chunk-level speculation still
    covers the non-inference portion of a chunk's work.
    """

    name = "infer"

    def __init__(self) -> None:
        self._lockstep = LockStepInferStage()

    def run(self, art: EvalArtifact, session: Any) -> EvalArtifact:
        if not art.task.inference.use_service:
            return self._lockstep.run(art, session)
        return self._run_service(art, session)

    def _run_service(self, art: EvalArtifact, session: Any) -> EvalArtifact:
        task = art.task
        inf = task.inference
        model = task.model
        prompts = art.prompts
        session.engine_for(model)  # engine init parity with the legacy path
        service = session.service_for(model, inf)
        cache = session.cache_for(inf)
        limiter = session.limiter_for(inf)

        count_lookups = cache is not None and cache.policy not in (
            CachePolicy.DISABLED, CachePolicy.WRITE_ONLY,
        )
        shards = [
            list(range(i, min(i + inf.batch_size, len(prompts))))
            for i in range(0, len(prompts), inf.batch_size)
        ]
        responses: list[InferenceResponse | None] = [None] * len(prompts)
        failures: list[dict] = []
        acct = session.accounting
        plans: list[tuple[_ShardStats, list]] = []
        #: gather cursor over the flattened plan entries, so an aborted
        #: gather can sweep the spend of ungathered flights
        gathered = 0
        n_cached = 0
        in_tok = out_tok = 0

        #: stage-local single-flight: the first occurrence of a key in this
        #: stage submits; later occurrences share its ticket.  This keeps
        #: intra-task dedup *deterministic* (independent of dispatch
        #: timing), while the service-level flight table handles the
        #: inherently-racy cross-stage case (concurrent chunks/tasks).
        local: dict[str, Any] = {}

        service.attach(inf.n_workers)
        try:
            # -- submit phase: cache lookups count per shard exactly as the
            # lock-step path counts them; misses go straight to the service
            for idxs in shards:
                st = _ShardStats()
                pending: list[tuple[int, str, Any, bool]] = []
                plans.append((st, pending))
                for i in idxs:
                    key = cache_key(
                        prompts[i], model.model_name, model.provider,
                        model.temperature, model.max_tokens,
                    )
                    if cache is not None:
                        hit = cache.lookup(key)
                        if hit is not None:
                            st.hits += 1
                            n_cached += 1
                            responses[i] = InferenceResponse(
                                text=hit.response_text,
                                input_tokens=hit.input_tokens or 0,
                                output_tokens=hit.output_tokens or 0,
                                latency_ms=0.0,
                            )
                            continue
                        if count_lookups:
                            st.misses += 1
                    if inf.coalesce and key in local:
                        service.note_coalesced()
                        pending.append((i, key, local[key], False))
                        continue
                    est = len(prompts[i].split()) + model.max_tokens
                    ticket = service.submit(
                        InferenceRequest(
                            prompts[i], model.max_tokens, model.temperature
                        ),
                        key=key,
                        coalesce=inf.coalesce,
                        limiter=limiter,
                        est_tokens=est,
                        max_retries=inf.max_retries,
                        retry_delay=inf.retry_delay,
                        deadline_s=inf.request_deadline_s,
                    )
                    local[key] = ticket
                    pending.append((i, key, ticket, True))

            # -- gather phase: per-shard stats, primary submissions only —
            # a coalesced follower's spend belongs to its leader's shard
            for st, pending in plans:
                new_entries: list[CacheEntry] = []
                for i, key, ticket, owner in pending:
                    resp = ticket.result()
                    gathered += 1
                    responses[i] = resp
                    primary = owner and ticket.primary
                    if primary:
                        st.calls += ticket.attempts
                        st.cost += resp.cost_usd
                    else:
                        st.coalesced += 1
                    if resp.error is not None:
                        failures.append({"index": i, "error": resp.error})
                    elif primary:
                        in_tok += resp.input_tokens
                        out_tok += resp.output_tokens
                        if cache is not None:
                            new_entries.append(
                                CacheEntry(
                                    prompt_hash=key,
                                    model_name=model.model_name,
                                    provider=model.provider,
                                    prompt_text=prompts[i],
                                    response_text=resp.text,
                                    input_tokens=resp.input_tokens,
                                    output_tokens=resp.output_tokens,
                                    latency_ms=resp.latency_ms,
                                    created_at=time.time(),
                                )
                            )
                if new_entries:
                    st.writes += cache.put(new_entries)
        finally:
            service.detach(inf.n_workers)
            # spend reaches the session accounting even if the gather
            # aborts mid-shard (REPLAY miss, dispatcher exception): sweep
            # the flights that already resolved but were never gathered —
            # their engine calls happened and cost money.  Calls still in
            # flight at abort time resolve in the service afterwards; only
            # those escape per-task accounting.
            flat = [
                (st, entry) for st, pending in plans for entry in pending
            ]
            for st, (i, key, ticket, owner) in flat[gathered:]:
                if not (owner and ticket.primary and ticket.done()):
                    continue
                try:
                    resp = ticket.result(0.0)
                except BaseException:  # noqa: BLE001 — failed flight: no spend
                    continue
                st.calls += ticket.attempts
                st.cost += resp.cost_usd
            with acct.lock:
                for st, _ in plans:
                    acct.engine_calls += st.calls
                    acct.cost_usd += st.cost
                    acct.coalesced_requests += st.coalesced

        totals = _sum_shard_stats(st for st, _ in plans)

        art.responses = responses
        art.texts = [
            r.text if r is not None and r.error is None else ""
            for r in responses
        ]
        art.failures = failures
        _publish_infer_stats(art, cache, totals, {})
        with acct.lock:
            acct.input_tokens += in_tok
            acct.output_tokens += out_tok
            if cache is not None:
                acct.cache_hits += n_cached
                acct.cache_misses += len(prompts) - n_cached
        return art


class StaticResponsesStage:
    """Stage-swap replacement for :class:`InferStage`: inject precomputed
    response texts (e.g. from a prior :class:`EvalResult`) and re-score
    them with different metrics at zero engine cost."""

    name = "infer"

    def __init__(self, texts: list[str]):
        self._texts = list(texts)

    def run(self, art: EvalArtifact, session: Any) -> EvalArtifact:
        if len(self._texts) != len(art.rows):
            raise ValueError(
                f"{len(self._texts)} responses for {len(art.rows)} rows"
            )
        art.texts = list(self._texts)
        art.responses = [None] * len(art.rows)
        art.cache_stats = {}
        art.engine_stats = {"calls": 0, "total_cost": 0.0, "pool": {}}
        return art


# -- stage 3: metric computation -------------------------------------------------


class ScoreStage:
    """Vectorized per-example scoring.  Metric resolution (registry lookup +
    params binding) lives behind this stage via
    :func:`repro.metrics.registry.resolve_metrics`, memoized per metric
    tuple — a streaming run re-enters this stage once per chunk, and the
    stage object is shared across concurrent chunk workers, so resolution
    happens once per task instead of once per chunk."""

    name = "metrics"

    def __init__(self) -> None:
        self._resolved: dict[tuple, list] = {}

    def _metrics_for(self, task: EvalTask) -> list:
        # benign race under concurrent chunk workers: two threads may both
        # resolve, the dict assignment is atomic and the values identical
        resolved = self._resolved.get(task.metrics)
        if resolved is None:
            resolved = resolve_metrics(task.metrics)
            self._resolved[task.metrics] = resolved
        return resolved

    def run(self, art: EvalArtifact, session: Any) -> EvalArtifact:
        task = art.task
        judge = session.judge_engine
        if judge is None and any(
            m.type == "llm_judge" or m.name in JUDGE_METRICS
            for m in task.metrics
        ):
            # only judge-backed metrics warrant initializing the task engine
            # here — a lexical-only rescore pipeline stays engine-free
            judge = session.engine_for(task.model)
        ctx = MetricContext(judge_engine=judge, logs=art.logs)
        scores: dict[str, np.ndarray] = {}
        for name, scorer in self._metrics_for(task):
            scores[name] = np.asarray(
                scorer(art.rows, art.texts, ctx), np.float64
            )
        art.scores = scores
        return art


# -- stage 4: statistical aggregation ---------------------------------------------


class AggregateStage:
    name = "stats"

    def run(self, art: EvalArtifact, session: Any) -> EvalArtifact:
        stats_cfg = art.task.statistics
        metric_values: dict[str, MetricValue] = {}
        for name, vals in art.scores.items():
            nan_mask = np.isnan(vals)  # one O(n) scan, reused for both
            ok = vals[~nan_mask]
            n_unscored = int(nan_mask.sum())
            if len(ok) == 0:
                metric_values[name] = MetricValue(
                    name, float("nan"), (float("nan"),) * 2, "none", 0, n_unscored
                )
                continue
            iv: Interval = compute_ci(
                ok,
                method=stats_cfg.ci_method,
                confidence=stats_cfg.confidence_level,
                n_boot=stats_cfg.bootstrap_iterations,
                seed=stats_cfg.seed,
                binary=name in BINARY_METRICS,
            )
            metric_values[name] = MetricValue(
                name, iv.value, (iv.lo, iv.hi), iv.method, iv.n, n_unscored
            )
        art.metrics = metric_values
        return art


def default_stages() -> list[Stage]:
    return [PrepareStage(), InferStage(), ScoreStage(), AggregateStage()]


def rescore_stages(texts: list[str]) -> list[Stage]:
    """Pipeline for the cache-replay iteration loop: re-score existing
    responses without inference."""
    return [
        PrepareStage(),
        StaticResponsesStage(texts),
        ScoreStage(),
        AggregateStage(),
    ]


# -- middleware -----------------------------------------------------------------


class Middleware:
    """No-op base; subclass and override the hooks you need."""

    def on_task_start(self, task: EvalTask, rows: list[dict], session: Any) -> None:
        pass

    def on_stage_start(self, stage: Stage, art: EvalArtifact, session: Any) -> None:
        pass

    def on_stage_end(self, stage: Stage, art: EvalArtifact, session: Any) -> None:
        pass

    def on_chunk_end(self, chunk_index: int, state: dict, session: Any) -> None:
        """Streaming pipeline only: a chunk finished (and was committed to
        the spill manifest, when spill is configured)."""

    def on_task_end(self, task: EvalTask, result: EvalResult, session: Any) -> None:
        pass


class CostBudgetExceeded(RuntimeError):
    """Raised by :class:`CostBudgetMiddleware` when session spend crosses
    the configured budget; aborts the pipeline between stages."""


class CostBudgetMiddleware(Middleware):
    def __init__(self, max_usd: float):
        self.max_usd = max_usd

    def on_stage_end(self, stage, art, session) -> None:
        self._check(session, f"after stage {stage.name!r} of task "
                             f"{art.task.task_id!r}")

    def on_chunk_end(self, chunk_index, state, session) -> None:
        self._check(session, f"after streaming chunk {chunk_index}")

    def _check(self, session, where: str) -> None:
        spent = session.accounting.cost_usd
        if spent > self.max_usd:
            raise CostBudgetExceeded(
                f"session cost ${spent:.4f} exceeds budget "
                f"${self.max_usd:.4f} ({where})"
            )


class ProgressMiddleware(Middleware):
    def __init__(self, stream=None):
        self.stream = stream or sys.stderr
        self._t0: dict[str, float] = {}

    def on_task_start(self, task, rows, session) -> None:
        print(
            f"[{task.task_id}] {len(rows)} examples, "
            f"model={task.model.provider}:{task.model.model_name}",
            file=self.stream,
        )

    def on_stage_start(self, stage, art, session) -> None:
        self._t0[stage.name] = time.monotonic()

    def on_stage_end(self, stage, art, session) -> None:
        dt = time.monotonic() - self._t0.get(stage.name, time.monotonic())
        print(f"[{art.task.task_id}]   {stage.name}: {dt:.2f}s", file=self.stream)

    def on_chunk_end(self, chunk_index, state, session) -> None:
        print(
            f"  chunk {chunk_index}: rows {state['start']}.."
            f"{state['start'] + state['n_rows']}, "
            f"{state['n_failures']} failures",
            file=self.stream,
        )

    def on_task_end(self, task, result, session) -> None:
        vals = ", ".join(
            f"{n}={mv.value:.3f}" for n, mv in result.metrics.items()
        )
        print(f"[{task.task_id}] done: {vals}", file=self.stream)


class TrackingMiddleware(Middleware):
    """Log every completed task to a :class:`repro.core.tracking.RunTracker`."""

    def __init__(self, tracker, **tags: str):
        self.tracker = tracker
        self.tags = tags
        self.run_ids: list[str] = []

    def on_task_end(self, task, result, session) -> None:
        self.run_ids.append(self.tracker.log_run(task, result, **self.tags))
