"""Multi-task, multi-model evaluation suites (paper §4.3–4.4 workloads).

``EvalSuite`` is a fluent builder: declare tasks once, sweep them across a
model list, and hand the suite to ``EvalSession.run_suite``::

    suite = (
        EvalSuite("regression")
        .add_task(qa_task, qa_rows)
        .add_task(summarization_task, sum_rows)
        .sweep_models([gpt4o_mini, haiku])
    )
    with EvalSession() as session:
        res = session.run_suite(suite)
    print(res.to_markdown())

``SuiteResult`` keeps every per-(model, task) :class:`EvalResult` and the
pairwise :class:`Comparison` matrix — per task, per shared metric, per
model pair — computed by the existing ``compare_scores`` machinery, plus
text/markdown reports for regression dashboards.
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Callable, Iterable, Sequence, Union

from repro.core.compare import Comparison, compare_scores, compare_stream_stats
from repro.core.config import EngineModelConfig, EvalTask
from repro.core.stages import EvalResult

#: comparisons key layout: task_id -> metric -> (label_a, label_b)
ComparisonMatrix = dict[str, dict[str, dict[tuple[str, str], Comparison]]]

#: examples for a task: a materialized list, or (for streaming tasks) a
#: zero-arg factory returning a fresh iterator per run
RowSource = Union[list[dict], Callable[[], Iterable[dict]]]


@dataclasses.dataclass(frozen=True)
class SuiteJob:
    model_label: str
    task: EvalTask
    rows: RowSource


class EvalSuite:
    def __init__(self, name: str = "suite"):
        self.name = name
        self._tasks: list[tuple[EvalTask, RowSource]] = []
        self._models: list[EngineModelConfig] = []

    # -- fluent builder ----------------------------------------------------------

    def add_task(
        self, task: EvalTask, rows: Sequence[dict] | Callable[[], Iterable[dict]]
    ) -> "EvalSuite":
        """Register a task template and its examples.  The task's own
        ``model`` is used unless :meth:`sweep_models` overrides it.

        For streaming tasks pass a zero-arg callable (e.g.
        ``lambda: iter_qa_examples(1_000_000)``) so each (model, task) job
        consumes a fresh iterator without materializing the dataset."""
        if task.task_id in self.task_ids():
            raise ValueError(f"duplicate task_id {task.task_id!r}")
        self._tasks.append((task, rows if callable(rows) else list(rows)))
        return self

    def sweep_models(
        self, models: Sequence[EngineModelConfig]
    ) -> "EvalSuite":
        """Evaluate every registered task under each of these models."""
        self._models.extend(models)
        return self

    def with_streaming(self, **kw) -> "EvalSuite":
        """Apply :meth:`EvalTask.with_streaming` to every registered task —
        e.g. ``.with_streaming(concurrency=4, max_memory_rows=2048)`` turns
        on N-way concurrent chunk execution suite-wide.  Tasks added later
        are not affected; call this after the last ``add_task``."""
        self._tasks = [
            (task.with_streaming(**kw), rows) for task, rows in self._tasks
        ]
        return self

    # -- expansion ---------------------------------------------------------------

    def task_ids(self) -> list[str]:
        return [t.task_id for t, _ in self._tasks]

    def model_configs(self) -> list[EngineModelConfig]:
        if self._models:
            return list(self._models)
        # no sweep: each task runs under its own configured model
        seen: list[EngineModelConfig] = []
        for task, _ in self._tasks:
            if task.model not in seen:
                seen.append(task.model)
        return seen

    def model_labels(self) -> list[str]:
        cfgs = self.model_configs()
        names = [c.model_name for c in cfgs]
        return [
            c.model_name
            if names.count(c.model_name) == 1
            else f"{c.provider}:{c.model_name}"
            for c in cfgs
        ]

    def jobs(self) -> list[SuiteJob]:
        """Expand to the (model × task) job list, grouped by model so a
        session touches each engine's working set contiguously."""
        if not self._tasks:
            raise ValueError("suite has no tasks; call add_task first")
        labels = self.model_labels()
        out: list[SuiteJob] = []
        if self._models:
            for label, model in zip(labels, self._models):
                for task, rows in self._tasks:
                    out.append(
                        SuiteJob(label, task.with_model(model), rows)
                    )
        else:
            by_cfg = dict(zip(self.model_configs(), labels))
            for task, rows in self._tasks:
                out.append(SuiteJob(by_cfg[task.model], task, rows))
        return out


def build_comparisons(
    suite: EvalSuite, results: dict[tuple[str, str], EvalResult]
) -> ComparisonMatrix:
    """Pairwise significance matrix: for each task and each metric shared
    by all models, compare every model pair — on aligned score vectors for
    in-memory runs, or on shared-weight-stream bootstrap replicate state
    (:func:`repro.core.compare.compare_stream_stats`) for streaming runs
    that never materialize per-example scores."""
    labels = suite.model_labels()
    out: ComparisonMatrix = {}
    for task, _ in suite._tasks:
        stats = task.statistics
        per_result = {
            label: results[(label, task.task_id)]
            for label in labels
            if (label, task.task_id) in results
        }
        per_model = {label: r.scores for label, r in per_result.items()}
        if len(per_model) < 2:
            out[task.task_id] = {}
            continue
        task_cmp: dict[str, dict[tuple[str, str], Comparison]] = {}
        present = [lab for lab in labels if lab in per_model]
        shared = set.intersection(*(set(s) for s in per_model.values()))
        for metric in sorted(shared):
            cells: dict[tuple[str, str], Comparison] = {}
            for i, a in enumerate(present):
                for b in present[i + 1:]:
                    cells[(a, b)] = compare_scores(
                        metric,
                        per_model[a][metric],
                        per_model[b][metric],
                        confidence=stats.confidence_level,
                        n_boot=stats.bootstrap_iterations,
                        seed=stats.seed,
                    )
            task_cmp[metric] = cells
        if not shared and any(not s for s in per_model.values()):
            task_cmp = _stream_comparisons(task, per_result, present)
        out[task.task_id] = task_cmp
    return out


def _stream_comparisons(
    task: EvalTask,
    per_result: dict[str, EvalResult],
    present: list[str],
) -> dict[str, dict[tuple[str, str], Comparison]]:
    """Pairwise comparisons for streaming runs: paired-delta bootstrap on
    the replicate state the runs carried instead of per-example scores.
    Warns (and yields no cells) when that state is absent — analytical
    ``ci_method`` maintains no replicates — or when two runs' weight
    streams are not shared (mismatched seed/B/backend/chunk layout)."""
    stats = task.statistics
    streams = {
        label: r.stream_stats
        for label, r in per_result.items()
        if r.stream_stats is not None
    }
    if len(streams) < 2:
        warnings.warn(
            f"task {task.task_id!r}: no per-example scores and no streaming "
            "replicate state to compare",
            stacklevel=3,
        )
        return {}
    shared = set.intersection(*(set(s.accs) for s in streams.values()))
    task_cmp: dict[str, dict[tuple[str, str], Comparison]] = {}
    warned: set[tuple[str, str]] = set()
    for metric in sorted(shared):
        cells: dict[tuple[str, str], Comparison] = {}
        for i, a in enumerate(present):
            for b in present[i + 1:]:
                if a not in streams or b not in streams:
                    continue
                reason = streams[a].comparable_with(streams[b])
                if reason is not None:
                    if (a, b) not in warned:
                        warned.add((a, b))
                        warnings.warn(
                            f"task {task.task_id!r}: streaming runs "
                            f"{a!r} vs {b!r} are not paired-comparable: "
                            f"{reason}",
                            stacklevel=3,
                        )
                    continue
                cells[(a, b)] = compare_stream_stats(
                    metric, streams[a], streams[b],
                    confidence=stats.confidence_level,
                )
        if cells:
            task_cmp[metric] = cells
    return task_cmp


@dataclasses.dataclass
class SuiteResult:
    name: str
    models: list[str]
    tasks: list[str]
    results: dict[tuple[str, str], EvalResult]
    comparisons: ComparisonMatrix
    accounting: dict
    #: adaptive-run payload from :func:`repro.core.budget.
    #: run_adaptive_suite` (empty for exhaustive runs): per-task consumed
    #: examples, certified verdicts, stop reasons and the budget spent
    adaptive: dict = dataclasses.field(default_factory=dict)

    # -- lookups -----------------------------------------------------------------

    def result(self, model: str, task_id: str) -> EvalResult:
        return self.results[(model, task_id)]

    def comparison(
        self, task_id: str, metric: str, a: str, b: str
    ) -> Comparison:
        cells = self.comparisons[task_id][metric]
        if (a, b) in cells:
            return cells[(a, b)]
        return cells[(b, a)]

    def significant_pairs(
        self, alpha: float = 0.05
    ) -> list[tuple[str, str, str, str, Comparison]]:
        out = []
        for task_id, metrics in self.comparisons.items():
            for metric, cells in metrics.items():
                for (a, b), cmp in cells.items():
                    if cmp.test.p_value < alpha:
                        out.append((task_id, metric, a, b, cmp))
        return out

    # -- reports -----------------------------------------------------------------

    def summary(self, alpha: float = 0.05) -> str:
        lines = [f"suite {self.name!r}: {len(self.models)} models × "
                 f"{len(self.tasks)} tasks"]
        for task_id in self.tasks:
            lines.append(f"  task {task_id}:")
            for model in self.models:
                res = self.results.get((model, task_id))
                if res is None:
                    continue
                vals = ", ".join(
                    f"{n}={mv.value:.3f}" for n, mv in res.metrics.items()
                )
                lines.append(f"    {model:28s} {vals}")
            for metric, cells in self.comparisons.get(task_id, {}).items():
                for (a, b), cmp in cells.items():
                    lines.append(f"    {cmp.summary(alpha)}")
        return "\n".join(lines)

    def to_markdown(self, alpha: float = 0.05) -> str:
        lines = [f"# Suite report: {self.name}", ""]
        for task_id in self.tasks:
            lines.append(f"## Task `{task_id}`")
            metrics: list[str] = []
            for model in self.models:
                res = self.results.get((model, task_id))
                if res is not None:
                    for m in res.metrics:
                        if m not in metrics:
                            metrics.append(m)
            lines.append("")
            lines.append("| model | " + " | ".join(metrics) + " |")
            lines.append("|---" * (len(metrics) + 1) + "|")
            for model in self.models:
                res = self.results.get((model, task_id))
                if res is None:
                    continue
                cells = []
                for m in metrics:
                    mv = res.metrics.get(m)
                    cells.append(
                        f"{mv.value:.3f} [{mv.ci[0]:.3f}, {mv.ci[1]:.3f}]"
                        if mv is not None else "—"
                    )
                lines.append(f"| {model} | " + " | ".join(cells) + " |")
            cmp_rows = [
                (metric, pair, cmp)
                for metric, cellmap in self.comparisons.get(task_id, {}).items()
                for pair, cmp in cellmap.items()
            ]
            if cmp_rows:
                lines.append("")
                lines.append("| metric | pair | Δ | 95% CI | test | p | verdict |")
                lines.append("|---|---|---|---|---|---|---|")
                for metric, (a, b), cmp in cmp_rows:
                    verdict = (
                        "**significant**"
                        if cmp.test.p_value < alpha else "n.s."
                    )
                    lines.append(
                        f"| {metric} | {a} vs {b} | {cmp.diff:+.4f} "
                        f"| ({cmp.diff_ci[0]:+.4f}, {cmp.diff_ci[1]:+.4f}) "
                        f"| {cmp.test.test} | {cmp.test.p_value:.4g} "
                        f"| {verdict} |"
                    )
            lines.append("")
        if self.adaptive:
            b = self.adaptive.get("budget", {})
            lines.append("## Adaptive evaluation")
            lines.append("")
            lines.append(
                f"budget: {b.get('spent', 0)} / {b.get('total_examples', 0)} "
                f"examples spent over {b.get('rounds', 0)} round(s) "
                f"(alpha={b.get('alpha', 0)}, margin={b.get('margin', 0)})"
            )
            lines.append("")
            lines.append(
                "| task | metric | consumed | exhausted | outcome "
                "| n at stop | half-width | certified verdicts |"
            )
            lines.append("|---" * 8 + "|")
            for tid in self.tasks:
                t = self.adaptive.get("tasks", {}).get(tid)
                if t is None:
                    continue
                consumed = ", ".join(
                    f"{lab}: {n}" for lab, n in t.get("consumed", {}).items()
                )
                verdicts = "; ".join(
                    f"{pair}: {v}" for pair, v in t.get("verdicts", {}).items()
                ) or "—"
                hw = t.get("half_width", float("inf"))
                hw_s = f"{hw:.4f}" if math.isfinite(hw) else "inf"
                lines.append(
                    f"| {tid} | {t.get('metric', '?')} | {consumed} "
                    f"| {'yes' if t.get('exhausted') else 'no'} "
                    f"| {t.get('reason') or 'open'} "
                    f"| {t.get('n_at_stop', 0)} "
                    f"| {hw_s} | {verdicts} |"
                )
            lines.append("")
        serving = self.accounting.get("serving") or []
        if serving:
            lines.append("## Inference service")
            lines.append("")
            lines.append(
                "| engine | mode | replicas | submitted | dispatched "
                "| coalesced | dedup | occupancy | tok/step | admissions "
                "| recompiles | prefix hits | prefix tok saved "
                "| kv B/tok | preempt | restarts | hedges |"
            )
            lines.append("|---" * 17 + "|")
            for s in serving:
                b = s.get("batcher") or {}
                lines.append(
                    f"| {s.get('engine', '?')} | {s.get('mode', '?')} "
                    f"| {s.get('replicas', 1)} "
                    f"| {s.get('submitted', 0)} | {s.get('dispatched', 0)} "
                    f"| {s.get('coalesced', 0)} "
                    f"| {s.get('dedup_rate', 0.0):.1%} "
                    f"| {b.get('slot_occupancy', '—')} "
                    f"| {b.get('tokens_per_step', '—')} "
                    f"| {b.get('admissions', '—')} "
                    f"| {b.get('prefill_recompiles', '—')} "
                    f"| {b.get('prefix_pages_hit', '—')} "
                    f"| {b.get('prefix_tokens_saved', '—')} "
                    f"| {b.get('kv_bytes_per_token', '—')} "
                    f"| {b.get('preemptions', '—')} "
                    f"| {s.get('restarts', 0)} "
                    f"| {s.get('hedges_issued', 0)}/{s.get('hedges_won', 0)} |"
                )
            lines.append("")
        acct = ", ".join(
            f"{k}={v}" for k, v in self.accounting.items() if k != "serving"
        )
        lines.append(f"_session accounting: {acct}_")
        return "\n".join(lines)
