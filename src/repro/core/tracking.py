"""MLflow-style local experiment tracking (paper §A.5).

One directory per run: ``params.json`` (full task config), ``metrics.json``
(values + CI bounds as separate entries, matching the paper's layout),
``tags.json``, ``artifacts/`` (raw per-example scores and responses)."""

from __future__ import annotations

import gzip
import json
import os
import time
import uuid

import numpy as np

from repro.core.config import EvalTask
from repro.core.stages import EvalResult


class RunTracker:
    def __init__(self, root: str = "experiments/runs"):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def log_run(self, task: EvalTask, result: EvalResult, **tags: str) -> str:
        run_id = f"{time.strftime('%Y%m%d-%H%M%S')}-{uuid.uuid4().hex[:6]}"
        rdir = os.path.join(self.root, run_id)
        os.makedirs(os.path.join(rdir, "artifacts"), exist_ok=True)

        with open(os.path.join(rdir, "params.json"), "w") as f:
            f.write(task.to_json())

        metrics_flat: dict[str, float] = {}
        for name, mv in result.metrics.items():
            metrics_flat[name] = mv.value
            metrics_flat[f"{name}_ci_lower"] = mv.ci[0]
            metrics_flat[f"{name}_ci_upper"] = mv.ci[1]
            metrics_flat[f"{name}_n"] = mv.n
            metrics_flat[f"{name}_unscored"] = mv.n_unscored
        metrics_flat["throughput_per_min"] = result.throughput_per_min
        for k, v in result.timing.items():
            metrics_flat[f"time_{k}"] = v
        with open(os.path.join(rdir, "metrics.json"), "w") as f:
            json.dump(metrics_flat, f, indent=1)

        all_tags = {
            "model": task.model.model_name,
            "provider": task.model.provider,
            "task_id": task.task_id,
            "fingerprint": task.fingerprint(),
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
            **tags,
        }
        with open(os.path.join(rdir, "tags.json"), "w") as f:
            json.dump(all_tags, f, indent=1)

        with gzip.open(
            os.path.join(rdir, "artifacts", "results.jsonl.gz"), "wt"
        ) as f:
            for i, text in enumerate(result.responses):
                row = {"index": i, "response": text}
                for m, vals in result.scores.items():
                    v = float(vals[i])
                    row[m] = None if np.isnan(v) else v
                f.write(json.dumps(row) + "\n")
        with open(os.path.join(rdir, "artifacts", "run_stats.json"), "w") as f:
            json.dump(
                {
                    "cache": result.cache_stats,
                    "engine": result.engine_stats,
                    "failures": result.failures,
                },
                f,
                indent=1,
                default=str,
            )
        return run_id

    def log_suite(self, suite_result, **tags: str) -> str:
        """Persist a :class:`repro.core.suite.SuiteResult`: the markdown
        report, the pairwise comparison summaries, and session accounting,
        in one directory alongside the per-run logs."""
        suite_id = (
            f"suite-{time.strftime('%Y%m%d-%H%M%S')}-{uuid.uuid4().hex[:6]}"
        )
        sdir = os.path.join(self.root, suite_id)
        os.makedirs(sdir, exist_ok=True)
        with open(os.path.join(sdir, "report.md"), "w") as f:
            f.write(suite_result.to_markdown())
        comparisons = [
            {
                "task": task_id,
                "metric": metric,
                "a": a,
                "b": b,
                "diff": cmp.diff,
                "p_value": cmp.test.p_value,
                "test": cmp.test.test,
                "effect": cmp.effect.value,
                "summary": cmp.summary(),
            }
            for task_id, metrics in suite_result.comparisons.items()
            for metric, cells in metrics.items()
            for (a, b), cmp in cells.items()
        ]
        with open(os.path.join(sdir, "comparisons.json"), "w") as f:
            json.dump(comparisons, f, indent=1)
        with open(os.path.join(sdir, "tags.json"), "w") as f:
            json.dump(
                {
                    "suite": suite_result.name,
                    "models": suite_result.models,
                    "tasks": suite_result.tasks,
                    "accounting": suite_result.accounting,
                    "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
                    **tags,
                },
                f,
                indent=1,
            )
        return suite_id

    def list_runs(self) -> list[str]:
        return sorted(
            d for d in os.listdir(self.root)
            if os.path.isdir(os.path.join(self.root, d))
        )

    def load_metrics(self, run_id: str) -> dict:
        with open(os.path.join(self.root, run_id, "metrics.json")) as f:
            return json.load(f)

    def load_tags(self, run_id: str) -> dict:
        with open(os.path.join(self.root, run_id, "tags.json")) as f:
            return json.load(f)
