"""EvalRunner: the paper's four-stage evaluation pipeline (§3, Figure 1).

  1. prompt preparation  — template rendering,
  2. distributed inference — sharded across the worker pool with
     per-worker token-bucket rate limiting, caching, retries and
     speculative re-issue,
  3. metric computation   — vectorized per-example scoring,
  4. statistical aggregation — CIs for every metric (Wilson for binary,
     bootstrap/BCa otherwise), unscored counts reported.

A killed evaluation resumes for free: re-running the same task in ENABLED
(or REPLAY) cache mode skips every already-answered prompt — the response
cache doubles as the fault-tolerance journal (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import numpy as np

from repro.core.cache import CacheEntry, ResponseCache
from repro.core.config import CachePolicy, EvalTask
from repro.core.engines import (
    InferenceRequest,
    InferenceResponse,
    create_engine,
    retry_with_backoff,
)
from repro.core.ratelimit import AdaptiveLimiter, TokenBucket
from repro.data.templates import render
from repro.ft.workers import WorkerPool
from repro.metrics.registry import BINARY_METRICS, MetricContext, get_metric
from repro.stats.bootstrap import Interval, compute_ci


@dataclasses.dataclass
class MetricValue:
    name: str
    value: float
    ci: tuple[float, float]
    ci_method: str
    n: int
    n_unscored: int = 0

    def __repr__(self) -> str:  # paper §5.6 display format
        return (
            f"MetricValue(value={self.value:.3f}, "
            f"ci=({self.ci[0]:.3f}, {self.ci[1]:.3f}), n={self.n})"
        )


@dataclasses.dataclass
class EvalResult:
    task_id: str
    metrics: dict[str, MetricValue]
    scores: dict[str, np.ndarray]
    responses: list[str]
    failures: list[dict]
    cache_stats: dict
    engine_stats: dict
    timing: dict
    logs: dict

    @property
    def throughput_per_min(self) -> float:
        dt = self.timing.get("infer_s", 0.0)
        return len(self.responses) / dt * 60.0 if dt > 0 else float("inf")


class EvalRunner:
    def __init__(self, *, judge_engine: Any = None, wall_clock_rate_limit: bool = False):
        self._judge_engine = judge_engine
        self._wall_clock = wall_clock_rate_limit

    # -- stage 2 helpers ---------------------------------------------------------

    def _make_limiter(self, task: EvalTask):
        inf = task.inference
        sleep = time.sleep if self._wall_clock else (lambda s: None)
        if inf.adaptive_rate:
            return AdaptiveLimiter(
                inf.rate_limit_rpm, inf.rate_limit_tpm, inf.n_workers, sleep=sleep
            )
        return [
            TokenBucket(
                inf.rate_limit_rpm, inf.rate_limit_tpm, inf.n_workers, sleep=sleep
            )
            for _ in range(inf.n_workers)
        ]

    def evaluate(self, rows: list[dict], task: EvalTask) -> EvalResult:
        timing: dict[str, float] = {}
        logs: dict[str, Any] = {}

        # ---- stage 1: prompt preparation -----------------------------------
        t0 = time.monotonic()
        prompts = [render(task.data.prompt_template, r) for r in rows]
        timing["prepare_s"] = time.monotonic() - t0

        # ---- stage 2: distributed inference ---------------------------------
        t0 = time.monotonic()
        inf = task.inference
        cache = (
            ResponseCache(inf.cache_dir, inf.cache_policy)
            if inf.cache_dir and inf.cache_policy != CachePolicy.DISABLED
            else None
        )
        engine = create_engine(task.model)
        engine.initialize()
        limiter = self._make_limiter(task)
        pool = WorkerPool(
            n_workers=inf.n_workers,
            max_retries=inf.max_retries,
            straggler_factor=inf.straggler_factor if inf.speculative_reissue else 0.0,
        )

        shards = [
            list(range(i, min(i + inf.batch_size, len(prompts))))
            for i in range(0, len(prompts), inf.batch_size)
        ]
        responses: list[InferenceResponse | None] = [None] * len(prompts)
        failures: list[dict] = []

        def run_shard(shard_idx: int, idxs: list[int], worker: int):
            out: list[tuple[int, InferenceResponse, bool]] = []
            to_infer: list[int] = []
            for i in idxs:
                key = None
                if cache is not None:
                    key = cache.key_for(
                        prompts[i], task.model.model_name, task.model.provider,
                        task.model.temperature, task.model.max_tokens,
                    )
                    hit = cache.lookup(key)
                    if hit is not None:
                        out.append(
                            (
                                i,
                                InferenceResponse(
                                    text=hit.response_text,
                                    input_tokens=hit.input_tokens or 0,
                                    output_tokens=hit.output_tokens or 0,
                                    latency_ms=0.0,
                                ),
                                True,
                            )
                        )
                        continue
                to_infer.append(i)
            w = worker % inf.n_workers
            new_entries: list[CacheEntry] = []
            for i in to_infer:
                est_tokens = len(prompts[i].split()) + task.model.max_tokens
                if isinstance(limiter, AdaptiveLimiter):
                    limiter.acquire(w, est_tokens)
                else:
                    limiter[w].acquire(est_tokens)
                req = InferenceRequest(
                    prompts[i], task.model.max_tokens, task.model.temperature
                )
                resp = retry_with_backoff(
                    lambda req=req: engine.infer(req),
                    max_retries=inf.max_retries,
                    base_delay=inf.retry_delay,
                    sleep=time.sleep if self._wall_clock else (lambda s: None),
                )
                out.append((i, resp, False))
                if cache is not None and resp.error is None:
                    new_entries.append(
                        CacheEntry(
                            prompt_hash=cache.key_for(
                                prompts[i], task.model.model_name,
                                task.model.provider, task.model.temperature,
                                task.model.max_tokens,
                            ),
                            model_name=task.model.model_name,
                            provider=task.model.provider,
                            prompt_text=prompts[i],
                            response_text=resp.text,
                            input_tokens=resp.input_tokens,
                            output_tokens=resp.output_tokens,
                            latency_ms=resp.latency_ms,
                            created_at=time.time(),
                        )
                    )
            if new_entries:
                cache.put(new_entries)
            return out

        shard_results = pool.map_shards(run_shard, shards)
        for sr in shard_results:
            for i, resp, _cached in sr.value:
                responses[i] = resp
                if resp.error is not None:
                    failures.append({"index": i, "error": resp.error})
        timing["infer_s"] = time.monotonic() - t0

        # ---- stage 3: metric computation -------------------------------------
        t0 = time.monotonic()
        texts = [r.text if r is not None and r.error is None else "" for r in responses]
        ctx = MetricContext(judge_engine=self._judge_engine or engine, logs=logs)
        scores: dict[str, np.ndarray] = {}
        for mcfg in task.metrics:
            scores[mcfg.name] = np.asarray(
                get_metric(mcfg)(rows, texts, ctx), np.float64
            )
        timing["metrics_s"] = time.monotonic() - t0

        # ---- stage 4: statistical aggregation ---------------------------------
        t0 = time.monotonic()
        stats_cfg = task.statistics
        metric_values: dict[str, MetricValue] = {}
        for name, vals in scores.items():
            ok = vals[~np.isnan(vals)]
            n_unscored = int(np.isnan(vals).sum())
            if len(ok) == 0:
                metric_values[name] = MetricValue(
                    name, float("nan"), (float("nan"),) * 2, "none", 0, n_unscored
                )
                continue
            iv: Interval = compute_ci(
                ok,
                method=stats_cfg.ci_method,
                confidence=stats_cfg.confidence_level,
                n_boot=stats_cfg.bootstrap_iterations,
                seed=stats_cfg.seed,
                binary=name in BINARY_METRICS,
            )
            metric_values[name] = MetricValue(
                name, iv.value, (iv.lo, iv.hi), iv.method, iv.n, n_unscored
            )
        timing["stats_s"] = time.monotonic() - t0

        return EvalResult(
            task_id=task.task_id,
            metrics=metric_values,
            scores=scores,
            responses=texts,
            failures=failures,
            cache_stats=cache.stats() if cache is not None else {},
            engine_stats={
                "calls": getattr(engine, "calls", None),
                "total_cost": getattr(engine, "total_cost", 0.0),
                "pool": dataclasses.asdict(pool.stats),
            },
            timing=timing,
            logs=logs,
        )
