"""EvalRunner: legacy single-task facade over the stage-pipeline API.

The paper's four-stage evaluation (§3, Figure 1) now lives in
:mod:`repro.core.stages` as composable stage objects —

  1. ``PrepareStage``   — template rendering,
  2. ``InferStage``     — sharded inference with per-worker token-bucket
     rate limiting, caching, retries and speculative re-issue,
  3. ``ScoreStage``     — vectorized per-example metric computation,
  4. ``AggregateStage`` — CIs for every metric (Wilson for binary,
     bootstrap/BCa otherwise), unscored counts reported —

executed by a long-lived :class:`repro.core.session.EvalSession` that
owns the shared engine registry, response caches, limiters and worker
pools, and by ``EvalSession.run_suite`` for multi-task × multi-model
suites with pairwise significance testing
(:mod:`repro.core.suite`).

``EvalRunner`` is kept as a thin backward-compatible shim: each
``evaluate`` call opens a fresh single-task session, so its results are
identical to the historical monolithic runner (fresh engine, fresh
cache handle, per-call stats).  New code should hold an ``EvalSession``
instead and amortize setup across tasks.

A killed evaluation still resumes for free: re-running the same task in
ENABLED (or REPLAY) cache mode skips every already-answered prompt — the
response cache doubles as the fault-tolerance journal (DESIGN.md §5).
"""

from __future__ import annotations

from typing import Any

from repro.core.config import EvalTask
from repro.core.session import EvalSession
from repro.core.stages import EvalResult, MetricValue

__all__ = ["EvalResult", "EvalRunner", "MetricValue"]


class EvalRunner:
    def __init__(
        self, *, judge_engine: Any = None, wall_clock_rate_limit: bool = False
    ):
        self._judge_engine = judge_engine
        self._wall_clock = wall_clock_rate_limit

    def evaluate(self, rows: list[dict], task: EvalTask) -> EvalResult:
        with EvalSession(
            judge_engine=self._judge_engine,
            wall_clock_rate_limit=self._wall_clock,
        ) as session:
            return session.run_task(rows, task)
