"""Test selection heuristics (paper Table 2) + Shapiro-Wilk normality screen.

Shapiro-Wilk follows Royston's AS R94 approximation (the same algorithm
scipy wraps), implemented from scratch: weights from Blom-scored normal
order statistics with the Royston polynomial corrections, p-value from the
log-normal transform of (1 - W).  Valid for 4 <= n <= 5000.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.stats.significance import (
    TestResult,
    mcnemar_test,
    paired_t_test,
    permutation_test,
    wilcoxon_signed_rank,
)
from repro.stats.special import norm_ppf, norm_sf


def _polyval(coeffs: list[float], x: float) -> float:
    out = 0.0
    for c in reversed(coeffs):
        out = out * x + c
    return out


def shapiro_wilk(x) -> tuple[float, float]:
    """Returns (W, p). Royston (1992, 1995) approximation."""
    x = np.sort(np.asarray(x, np.float64))
    n = len(x)
    if n < 4:
        return 1.0, 1.0
    if n > 5000:
        x = x[:: n // 5000 + 1]
        n = len(x)

    m = np.array([norm_ppf((i - 0.375) / (n + 0.25)) for i in range(1, n + 1)])
    mm = float(m @ m)
    c = m / math.sqrt(mm)
    u = 1.0 / math.sqrt(n)

    a = np.empty(n)
    an = _polyval([c[-1], 0.221157, -0.147981, -2.071190, 4.434685, -2.706056], u)
    an1 = _polyval([c[-2], 0.042981, -0.293762, -1.752461, 5.682633, -3.582633], u)
    if n <= 5:
        phi = (mm - 2 * m[-1] ** 2) / (1 - 2 * an**2)
        a = m / math.sqrt(phi)
        a[-1] = an
        a[0] = -an
    else:
        phi = (mm - 2 * m[-1] ** 2 - 2 * m[-2] ** 2) / (1 - 2 * an**2 - 2 * an1**2)
        a = m / math.sqrt(phi)
        a[-1], a[-2] = an, an1
        a[0], a[1] = -an, -an1

    xm = x.mean()
    ssq = float(np.sum((x - xm) ** 2))
    if ssq <= 0:
        return 1.0, 1.0
    w = float((a @ x) ** 2 / ssq)
    w = min(w, 1.0)

    # p-value: Royston's normalizing transform
    lw = math.log(max(1e-12, 1.0 - w))
    ln_n = math.log(n)
    if n <= 11:
        g = -2.273 + 0.459 * n
        mu = 0.5440 - 0.39978 * n + 0.025054 * n**2 - 0.0006714 * n**3
        sigma = math.exp(
            1.3822 - 0.77857 * n + 0.062767 * n**2 - 0.0020322 * n**3
        )
        if g <= lw:
            return w, 1e-12
        z = (-math.log(g - lw) - mu) / sigma
    else:
        mu = -1.5861 - 0.31082 * ln_n - 0.083751 * ln_n**2 + 0.0038915 * ln_n**3
        sigma = math.exp(
            -0.4803 - 0.082676 * ln_n + 0.0030302 * ln_n**2
        )
        z = (lw - mu) / sigma
    return w, float(min(1.0, max(0.0, norm_sf(z))))


@dataclasses.dataclass(frozen=True)
class TestRecommendation:
    test: str
    reason: str
    normal_p: float | None = None


def is_binary(x) -> bool:
    vals = np.unique(np.asarray(x, np.float64))
    return len(vals) <= 2 and bool(np.all(np.isin(vals, (0.0, 1.0))))


def recommend_test(a, b, *, alpha: float = 0.05) -> TestRecommendation:
    """Table 2: metric type x sample size -> test."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    n = len(a)
    if is_binary(a) and is_binary(b):
        return TestRecommendation(
            "mcnemar", f"binary metric (exact for <10 discordant pairs), n={n}"
        )
    d = a - b
    nz = d[d != 0]
    if len(nz) >= 4:
        _, p_norm = shapiro_wilk(nz)
    else:
        p_norm = 0.0
    if n > 30 and p_norm > alpha:
        return TestRecommendation(
            "paired_t",
            f"continuous, normality not rejected (SW p={p_norm:.3f}), n={n}",
            p_norm,
        )
    return TestRecommendation(
        "wilcoxon",
        f"continuous/ordinal, non-normal or small sample (SW p={p_norm:.3f}), n={n}",
        p_norm,
    )


def run_recommended(a, b, *, alpha: float = 0.05, seed: int = 0) -> TestResult:
    rec = recommend_test(a, b, alpha=alpha)
    if rec.test == "mcnemar":
        return mcnemar_test(a, b)
    if rec.test == "paired_t":
        return paired_t_test(a, b)
    if rec.test == "wilcoxon":
        return wilcoxon_signed_rank(a, b)
    return permutation_test(a, b, seed=seed)
