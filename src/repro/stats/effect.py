"""Effect sizes (paper §4.4): Cohen's d, Hedges' g, odds ratio."""

from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class EffectSize:
    name: str
    value: float
    magnitude: str  # negligible | small | medium | large


def _magnitude(d: float) -> str:
    ad = abs(d)
    if ad < 0.2:
        return "negligible"
    if ad < 0.5:
        return "small"
    if ad < 0.8:
        return "medium"
    return "large"


def _d_from_moments(
    mean_a: float, var_a: float, n_a: int,
    mean_b: float, var_b: float, n_b: int,
) -> float:
    """Cohen's d from sufficient statistics (single home of the
    pooled-SD formula; both the array and the streaming-moments fronts
    delegate here)."""
    pooled = math.sqrt(
        ((n_a - 1) * var_a + (n_b - 1) * var_b) / max(n_a + n_b - 2, 1)
    )
    return (mean_a - mean_b) / pooled if pooled > 0 else 0.0


def _j_correction(n: int) -> float:
    """Hedges' small-sample correction factor."""
    return 1.0 - 3.0 / (4.0 * (n - 2) - 1.0) if n > 2 else 1.0


def cohens_d(a, b) -> EffectSize:
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    na, nb = len(a), len(b)
    d = _d_from_moments(
        float(a.mean()), a.var(ddof=1) if na > 1 else 0.0, na,
        float(b.mean()), b.var(ddof=1) if nb > 1 else 0.0, nb,
    )
    return EffectSize("cohens_d", float(d), _magnitude(d))


def hedges_g(a, b) -> EffectSize:
    g = cohens_d(a, b).value * _j_correction(len(a) + len(b))
    return EffectSize("hedges_g", float(g), _magnitude(g))


def hedges_g_from_moments(
    mean_a: float, var_a: float, n_a: int,
    mean_b: float, var_b: float, n_b: int,
) -> EffectSize:
    """Hedges' g from sufficient statistics (streaming runs keep moments,
    not per-example scores); identical to :func:`hedges_g` on the same
    data up to float summation order."""
    d = _d_from_moments(mean_a, var_a, n_a, mean_b, var_b, n_b)
    g = d * _j_correction(n_a + n_b)
    return EffectSize("hedges_g", float(g), _magnitude(g))


def odds_ratio(a, b, *, haldane: bool = True) -> EffectSize:
    """Binary outcomes; Haldane-Anscombe 0.5 correction for zero cells."""
    a = np.asarray(a).astype(bool)
    b = np.asarray(b).astype(bool)
    sa, fa = float(a.sum()), float((~a).sum())
    sb, fb = float(b.sum()), float((~b).sum())
    if haldane and 0.0 in (sa, fa, sb, fb):
        sa, fa, sb, fb = sa + 0.5, fa + 0.5, sb + 0.5, fb + 0.5
    oratio = (sa / fa) / (sb / fb)
    # magnitude buckets via log-odds ~ d conversion (Chinn 2000: d = ln(OR)/1.81)
    d_equiv = math.log(oratio) / 1.81 if oratio > 0 else 0.0
    return EffectSize("odds_ratio", float(oratio), _magnitude(d_equiv))
