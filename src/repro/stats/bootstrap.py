"""Confidence intervals: percentile bootstrap, BCa bootstrap, analytical
(t-interval, Wilson score) — paper §4.2.

The resampling engine is JAX (threefry: bit-for-bit deterministic given the
seed, identical on one host or across a pod — DESIGN.md §8) with exact
multinomial resampling via ``jax.random.randint`` index draws; the large-n
Poisson-bootstrap Pallas kernel lives in ``repro/kernels/bootstrap``.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.stats.special import norm_cdf, norm_ppf, t_ppf


@dataclasses.dataclass(frozen=True)
class Interval:
    value: float
    lo: float
    hi: float
    method: str
    n: int

    def as_tuple(self) -> tuple[float, float]:
        return (self.lo, self.hi)


@functools.partial(jax.jit, static_argnames=("n_boot", "stat_fn"))
def _resample_jit(data, seed, *, n_boot: int, stat_fn):
    n = data.shape[0]
    keys = jax.random.split(jax.random.key(seed), n_boot)

    def one(key):
        idx = jax.random.randint(key, (n,), 0, n)
        return stat_fn(jnp.take(data, idx, axis=0))

    return jax.lax.map(one, keys, batch_size=min(n_boot, 128))


def _resample_stats(
    data: jnp.ndarray,
    stat_fn: Callable[[jnp.ndarray], jnp.ndarray],
    n_boot: int,
    seed: int,
) -> np.ndarray:
    """(n_boot,) statistic over exact multinomial resamples (jit-cached
    per (n, n_boot, stat_fn) so repeated CI calls don't retrace)."""
    return np.asarray(
        _resample_jit(
            jnp.asarray(data, jnp.float32), seed, n_boot=n_boot, stat_fn=stat_fn
        )
    )


def percentile_bootstrap(
    data,
    stat_fn: Callable = jnp.mean,
    *,
    n_boot: int = 1000,
    confidence: float = 0.95,
    seed: int = 0,
) -> Interval:
    data = jnp.asarray(data, jnp.float32)
    stats = _resample_stats(data, stat_fn, n_boot, seed)
    alpha = (1 - confidence) / 2
    lo, hi = np.quantile(stats, [alpha, 1 - alpha])
    return Interval(
        float(stat_fn(data)), float(lo), float(hi), "percentile", data.shape[0]
    )


def bca_bootstrap(
    data,
    stat_fn: Callable = jnp.mean,
    *,
    n_boot: int = 1000,
    confidence: float = 0.95,
    seed: int = 0,
) -> Interval:
    """Bias-corrected and accelerated bootstrap (Efron & Tibshirani, ch. 14)."""
    data = jnp.asarray(data, jnp.float32)
    n = data.shape[0]
    theta_hat = float(stat_fn(data))
    stats = _resample_stats(data, stat_fn, n_boot, seed)

    # bias correction z0: proportion of bootstrap stats below the estimate
    prop = np.clip(
        np.mean(stats < theta_hat) + 0.5 * np.mean(stats == theta_hat),
        1.0 / (2 * n_boot),
        1.0 - 1.0 / (2 * n_boot),
    )
    z0 = norm_ppf(float(prop))

    # acceleration a from jackknife values (closed form for the mean:
    # jack_i = (sum - x_i) / (n-1); general statistics fall back to the
    # O(n) leave-one-out loop)
    data_np = np.asarray(data, np.float64)
    if stat_fn is jnp.mean or stat_fn is np.mean:
        jack = (data_np.sum() - data_np) / (n - 1)
    else:
        jack = np.empty(n, np.float64)
        for i in range(n):
            jack[i] = float(stat_fn(jnp.asarray(np.delete(data_np, i, axis=0))))
    jmean = jack.mean()
    num = np.sum((jmean - jack) ** 3)
    den = 6.0 * (np.sum((jmean - jack) ** 2) ** 1.5)
    a = float(num / den) if den > 0 else 0.0

    alpha = (1 - confidence) / 2
    z_lo, z_hi = norm_ppf(alpha), norm_ppf(1 - alpha)

    def adj(z: float) -> float:
        w = z0 + (z0 + z) / (1 - a * (z0 + z))
        return norm_cdf(w)

    lo, hi = np.quantile(stats, [adj(z_lo), adj(z_hi)])
    return Interval(theta_hat, float(lo), float(hi), "bca", n)


def t_interval(data, *, confidence: float = 0.95) -> Interval:
    data = np.asarray(data, np.float64)
    n = data.shape[0]
    mean = float(data.mean())
    se = float(data.std(ddof=1) / math.sqrt(n)) if n > 1 else 0.0
    tcrit = t_ppf(1 - (1 - confidence) / 2, n - 1) if n > 1 else 0.0
    return Interval(mean, mean - tcrit * se, mean + tcrit * se, "t", n)


def wilson_interval(successes: int, n: int, *, confidence: float = 0.95) -> Interval:
    """Wilson score interval for proportions (robust near 0/1)."""
    if n == 0:
        return Interval(0.0, 0.0, 1.0, "wilson", 0)
    z = norm_ppf(1 - (1 - confidence) / 2)
    p = successes / n
    denom = 1 + z * z / n
    center = (p + z * z / (2 * n)) / denom
    half = z * math.sqrt(p * (1 - p) / n + z * z / (4 * n * n)) / denom
    lo = min(max(0.0, center - half), p)   # clamp numerical dust at the edges
    hi = max(min(1.0, center + half), p)
    return Interval(p, lo, hi, "wilson", n)


def replicate_p_value(replicates, null: float = 0.0) -> float:
    """Two-sided bootstrap p-value from a replicate distribution: the
    smallest alpha at which the percentile interval excludes ``null``
    (CI-inversion; add-one correction keeps p in (0, 1] at finite B)."""
    reps = np.asarray(replicates, np.float64)
    n_boot = reps.size
    if n_boot == 0:
        return 1.0
    p_lo = (1.0 + np.sum(reps <= null)) / (n_boot + 1.0)
    p_hi = (1.0 + np.sum(reps >= null)) / (n_boot + 1.0)
    return float(min(1.0, 2.0 * min(p_lo, p_hi)))


def compute_ci(
    data,
    *,
    method: str = "bca",
    confidence: float = 0.95,
    n_boot: int = 1000,
    seed: int = 0,
    binary: bool = False,
) -> Interval:
    """Dispatch per StatisticsConfig.ci_method (+ Wilson for binary metrics)."""
    if method == "analytical":
        if binary:
            arr = np.asarray(data)
            return wilson_interval(int(arr.sum()), len(arr), confidence=confidence)
        return t_interval(data, confidence=confidence)
    if method == "percentile":
        return percentile_bootstrap(
            data, n_boot=n_boot, confidence=confidence, seed=seed
        )
    if method == "bca":
        return bca_bootstrap(data, n_boot=n_boot, confidence=confidence, seed=seed)
    raise ValueError(f"unknown ci method {method!r}")
