"""Distribution functions from first principles, in float64.

No scipy at runtime (scipy is only a test oracle) and no jax here either —
jax defaults to f32 which is not enough for tail p-values.  The incomplete
beta/gamma functions use the standard continued-fraction / series forms
(Numerical Recipes 6.2-6.4); the normal PPF is Acklam's rational
approximation refined with one Halley step.
"""

from __future__ import annotations

import math

_EPS = 3e-16
_FPMIN = 1e-300


def norm_cdf(x: float) -> float:
    return 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))


def norm_sf(x: float) -> float:
    return 0.5 * math.erfc(x / math.sqrt(2.0))


_ACKLAM_A = (-3.969683028665376e+01, 2.209460984245205e+02,
             -2.759285104469687e+02, 1.383577518672690e+02,
             -3.066479806614716e+01, 2.506628277459239e+00)
_ACKLAM_B = (-5.447609879822406e+01, 1.615858368580409e+02,
             -1.556989798598866e+02, 6.680131188771972e+01,
             -1.328068155288572e+01)
_ACKLAM_C = (-7.784894002430293e-03, -3.223964580411365e-01,
             -2.400758277161838e+00, -2.549732539343734e+00,
             4.374664141464968e+00, 2.938163982698783e+00)
_ACKLAM_D = (7.784695709041462e-03, 3.224671290700398e-01,
             2.445134137142996e+00, 3.754408661907416e+00)


def norm_ppf(p: float) -> float:
    if not 0.0 < p < 1.0:
        if p == 0.0:
            return -math.inf
        if p == 1.0:
            return math.inf
        raise ValueError(p)
    a, b, c, d = _ACKLAM_A, _ACKLAM_B, _ACKLAM_C, _ACKLAM_D
    p_low, p_high = 0.02425, 1 - 0.02425
    if p < p_low:
        q = math.sqrt(-2 * math.log(p))
        x = (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / (
            (((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1
        )
    elif p <= p_high:
        q = p - 0.5
        r = q * q
        num = ((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]
        x = num * q / (
            ((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1
        )
    else:
        q = math.sqrt(-2 * math.log(1 - p))
        x = -(((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / (
            (((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1
        )
    # one Halley refinement
    e = norm_cdf(x) - p
    u = e * math.sqrt(2 * math.pi) * math.exp(x * x / 2.0)
    x = x - u / (1 + x * u / 2)
    return x


# -- incomplete beta (NR betacf / betai) ---------------------------------------


def _betacf(a: float, b: float, x: float) -> float:
    qab, qap, qam = a + b, a + 1.0, a - 1.0
    c = 1.0
    d = 1.0 - qab * x / qap
    if abs(d) < _FPMIN:
        d = _FPMIN
    d = 1.0 / d
    h = d
    for m in range(1, 400):
        m2 = 2 * m
        aa = m * (b - m) * x / ((qam + m2) * (a + m2))
        d = 1.0 + aa * d
        if abs(d) < _FPMIN:
            d = _FPMIN
        c = 1.0 + aa / c
        if abs(c) < _FPMIN:
            c = _FPMIN
        d = 1.0 / d
        h *= d * c
        aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2))
        d = 1.0 + aa * d
        if abs(d) < _FPMIN:
            d = _FPMIN
        c = 1.0 + aa / c
        if abs(c) < _FPMIN:
            c = _FPMIN
        d = 1.0 / d
        delta = d * c
        h *= delta
        if abs(delta - 1.0) < _EPS:
            break
    return h


def betainc(a: float, b: float, x: float) -> float:
    """Regularized incomplete beta I_x(a, b)."""
    if x <= 0.0:
        return 0.0
    if x >= 1.0:
        return 1.0
    ln_bt = (
        math.lgamma(a + b) - math.lgamma(a) - math.lgamma(b)
        + a * math.log(x) + b * math.log1p(-x)
    )
    bt = math.exp(ln_bt)
    if x < (a + 1.0) / (a + b + 2.0):
        return bt * _betacf(a, b, x) / a
    return 1.0 - bt * _betacf(b, a, 1.0 - x) / b


# -- incomplete gamma (NR gser / gcf) --------------------------------------------


def _gser(a: float, x: float) -> float:
    ap = a
    summ = 1.0 / a
    delta = summ
    for _ in range(500):
        ap += 1.0
        delta *= x / ap
        summ += delta
        if abs(delta) < abs(summ) * _EPS:
            break
    return summ * math.exp(-x + a * math.log(x) - math.lgamma(a))


def _gcf(a: float, x: float) -> float:
    b = x + 1.0 - a
    c = 1.0 / _FPMIN
    d = 1.0 / b
    h = d
    for i in range(1, 500):
        an = -i * (i - a)
        b += 2.0
        d = an * d + b
        if abs(d) < _FPMIN:
            d = _FPMIN
        c = b + an / c
        if abs(c) < _FPMIN:
            c = _FPMIN
        d = 1.0 / d
        delta = d * c
        h *= delta
        if abs(delta - 1.0) < _EPS:
            break
    return math.exp(-x + a * math.log(x) - math.lgamma(a)) * h


def gammainc(a: float, x: float) -> float:
    """Regularized lower incomplete gamma P(a, x)."""
    if x < 0 or a <= 0:
        raise ValueError((a, x))
    if x == 0:
        return 0.0
    if x < a + 1.0:
        return _gser(a, x)
    return 1.0 - _gcf(a, x)


# -- distributions ------------------------------------------------------------------


def t_cdf(x: float, df: float) -> float:
    if df <= 0:
        raise ValueError("df must be positive")
    ib = betainc(df / 2.0, 0.5, df / (df + x * x))
    return 1.0 - 0.5 * ib if x >= 0 else 0.5 * ib


def t_sf(x: float, df: float) -> float:
    return 1.0 - t_cdf(x, df)


def t_ppf(p: float, df: float, *, tol: float = 1e-12) -> float:
    if not 0.0 < p < 1.0:
        raise ValueError(p)
    lo, hi = -1e8, 1e8
    for _ in range(400):
        mid = 0.5 * (lo + hi)
        if t_cdf(mid, df) < p:
            lo = mid
        else:
            hi = mid
        if hi - lo < tol * max(1.0, abs(mid)):
            break
    return 0.5 * (lo + hi)


def chi2_sf(x: float, df: float) -> float:
    if x < 0:
        return 1.0
    return 1.0 - gammainc(df / 2.0, x / 2.0)


def binom_pmf(k: int, n: int, p: float) -> float:
    return math.comb(n, k) * p**k * (1 - p) ** (n - k)


def binom_test_two_sided(k: int, n: int, p: float = 0.5) -> float:
    """Exact two-sided binomial test (sum of outcomes as or less likely)."""
    pk = binom_pmf(k, n, p)
    total = sum(
        binom_pmf(i, n, p)
        for i in range(n + 1)
        if binom_pmf(i, n, p) <= pk * (1 + 1e-12)
    )
    return min(1.0, total)
