from repro.stats.bootstrap import (
    Interval,
    bca_bootstrap,
    compute_ci,
    percentile_bootstrap,
    replicate_p_value,
    t_interval,
    wilson_interval,
)
from repro.stats.effect import (
    EffectSize,
    cohens_d,
    hedges_g,
    hedges_g_from_moments,
    odds_ratio,
)
from repro.stats.sequential import (
    SeqInterval,
    SequentialComparison,
    StopDecision,
    StoppingRule,
    certify_verdict,
    mixture_half_width,
    paired_delta_variance,
    rho_opt,
    sequential_ci,
    sequential_compare,
)
from repro.stats.select import (
    TestRecommendation,
    is_binary,
    recommend_test,
    run_recommended,
    shapiro_wilk,
)
from repro.stats.significance import (
    TestResult,
    mcnemar_test,
    paired_t_test,
    permutation_test,
    wilcoxon_signed_rank,
)
from repro.stats.streaming import (
    BootstrapEngine,
    MetricAccumulator,
    NumpyBootstrapEngine,
    PallasBootstrapEngine,
    PoissonBootstrap,
    StreamingStats,
    bootstrap_engine_from_state,
    make_bootstrap_engine,
    streaming_ci,
)

__all__ = [
    "BootstrapEngine", "EffectSize", "Interval", "MetricAccumulator",
    "NumpyBootstrapEngine", "PallasBootstrapEngine", "PoissonBootstrap",
    "SeqInterval", "SequentialComparison", "StopDecision", "StoppingRule",
    "StreamingStats", "TestRecommendation", "TestResult", "bca_bootstrap",
    "bootstrap_engine_from_state", "certify_verdict", "cohens_d",
    "compute_ci", "hedges_g",
    "hedges_g_from_moments", "is_binary", "make_bootstrap_engine",
    "mcnemar_test", "mixture_half_width", "odds_ratio",
    "paired_delta_variance", "paired_t_test", "percentile_bootstrap",
    "permutation_test", "recommend_test", "replicate_p_value", "rho_opt",
    "run_recommended", "sequential_ci", "sequential_compare",
    "shapiro_wilk", "streaming_ci", "t_interval",
    "wilcoxon_signed_rank", "wilson_interval",
]
