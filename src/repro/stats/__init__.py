from repro.stats.bootstrap import (
    Interval,
    bca_bootstrap,
    compute_ci,
    percentile_bootstrap,
    t_interval,
    wilson_interval,
)
from repro.stats.effect import EffectSize, cohens_d, hedges_g, odds_ratio
from repro.stats.select import (
    TestRecommendation,
    is_binary,
    recommend_test,
    run_recommended,
    shapiro_wilk,
)
from repro.stats.significance import (
    TestResult,
    mcnemar_test,
    paired_t_test,
    permutation_test,
    wilcoxon_signed_rank,
)
from repro.stats.streaming import (
    MetricAccumulator,
    PoissonBootstrap,
    streaming_ci,
)

__all__ = [
    "EffectSize", "Interval", "MetricAccumulator", "PoissonBootstrap",
    "TestRecommendation", "TestResult", "bca_bootstrap", "cohens_d",
    "compute_ci", "hedges_g", "is_binary", "mcnemar_test", "odds_ratio",
    "paired_t_test", "percentile_bootstrap", "permutation_test",
    "recommend_test", "run_recommended", "shapiro_wilk", "streaming_ci",
    "t_interval", "wilcoxon_signed_rank", "wilson_interval",
]
