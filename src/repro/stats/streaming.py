"""Mergeable streaming statistics for chunked evaluation at paper scale.

The in-memory :class:`~repro.core.stages.AggregateStage` holds every
per-example score so it can bootstrap a CI; at the paper's "hundreds of
thousands or millions of samples" that is O(dataset) memory.  This module
keeps the rigor story with O(B) state per metric:

* :class:`MetricAccumulator` — count / sum / sum-of-squares moments plus a
  NaN (unscorable) counter.  Mergeable, JSON-serializable, and sufficient
  for the exact mean, the analytical t-interval, and the Wilson interval
  for binary metrics.
* :class:`PoissonBootstrap` — B replicate ``(sum w*x, sum w)`` pairs under
  i.i.d. Poisson(1) resampling weights: the standard streaming /
  distributed bootstrap (Chamandy et al.; same scheme as the Pallas kernel
  in ``repro/kernels/bootstrap``).  Each chunk's weights come from a
  counter-based Philox stream keyed by ``(seed, chunk_start)``, so the
  accumulated replicates are deterministic given the chunk layout and
  independent of processing order — merging partial states from a resumed
  run reproduces the uninterrupted result bit-for-bit.

Both accumulators serialize to plain dicts (``state()`` / ``from_state``)
so per-chunk partials can spill to a DeltaLite manifest and be merged on
resume.
"""

from __future__ import annotations

import math

import numpy as np

from repro.stats.bootstrap import Interval, wilson_interval
from repro.stats.special import t_ppf


class MetricAccumulator:
    """Mergeable moment accumulator for one metric's per-example scores."""

    def __init__(self) -> None:
        self.n = 0
        self.total = 0.0
        self.total_sq = 0.0
        self.n_nan = 0

    def update(self, scores: np.ndarray) -> None:
        scores = np.asarray(scores, np.float64)
        ok = scores[~np.isnan(scores)]
        self.n += int(ok.size)
        self.total += float(ok.sum())
        self.total_sq += float((ok * ok).sum())
        self.n_nan += int(scores.size - ok.size)

    def merge(self, other: "MetricAccumulator") -> "MetricAccumulator":
        self.n += other.n
        self.total += other.total
        self.total_sq += other.total_sq
        self.n_nan += other.n_nan
        return self

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else float("nan")

    @property
    def variance(self) -> float:
        """Unbiased (ddof=1) variance from the accumulated moments."""
        if self.n < 2:
            return 0.0
        var = (self.total_sq - self.total * self.total / self.n) / (self.n - 1)
        return max(var, 0.0)  # clamp catastrophic-cancellation dust

    def state(self) -> dict:
        return {
            "n": self.n, "total": self.total,
            "total_sq": self.total_sq, "n_nan": self.n_nan,
        }

    @classmethod
    def from_state(cls, state: dict) -> "MetricAccumulator":
        acc = cls()
        acc.n = int(state["n"])
        acc.total = float(state["total"])
        acc.total_sq = float(state["total_sq"])
        acc.n_nan = int(state["n_nan"])
        return acc


class PoissonBootstrap:
    """B mergeable bootstrap replicates under Poisson(1) resample weights.

    ``update(scores, start)`` draws a ``(n_boot, len(scores))`` weight block
    from ``Philox(key=(seed, start))`` — ``start`` is the chunk's global
    example offset — and folds it into the running ``sum(w*x)`` / ``sum(w)``
    pairs.  NaN scores get weight zero (excluded, matching the in-memory
    path's NaN filtering).  ``means()`` yields the B replicate means, whose
    percentiles form the CI.
    """

    def __init__(self, n_boot: int = 1000, seed: int = 0):
        self.n_boot = int(n_boot)
        self.seed = int(seed)
        self.sum_wx = np.zeros(self.n_boot, np.float64)
        self.sum_w = np.zeros(self.n_boot, np.float64)

    def update(self, scores: np.ndarray, start: int) -> None:
        scores = np.asarray(scores, np.float64)
        if scores.size == 0:
            return
        rng = np.random.Generator(np.random.Philox(key=[self.seed, start]))
        w = rng.poisson(1.0, (self.n_boot, scores.size)).astype(np.float64)
        valid = ~np.isnan(scores)
        w *= valid[None, :]
        self.sum_wx += w @ np.where(valid, scores, 0.0)
        self.sum_w += w.sum(axis=1)

    def merge(self, other: "PoissonBootstrap") -> "PoissonBootstrap":
        if (other.n_boot, other.seed) != (self.n_boot, self.seed):
            raise ValueError("cannot merge bootstraps with different (B, seed)")
        self.sum_wx += other.sum_wx
        self.sum_w += other.sum_w
        return self

    def means(self) -> np.ndarray:
        return self.sum_wx / np.maximum(self.sum_w, 1.0)

    def interval(
        self, value: float, n: int, *, confidence: float = 0.95
    ) -> Interval:
        alpha = (1 - confidence) / 2
        lo, hi = np.quantile(self.means(), [alpha, 1 - alpha])
        return Interval(value, float(lo), float(hi), "poisson", n)

    def state(self) -> dict:
        return {
            "n_boot": self.n_boot, "seed": self.seed,
            "sum_wx": self.sum_wx.tolist(), "sum_w": self.sum_w.tolist(),
        }

    @classmethod
    def from_state(cls, state: dict) -> "PoissonBootstrap":
        boot = cls(int(state["n_boot"]), int(state["seed"]))
        boot.sum_wx = np.asarray(state["sum_wx"], np.float64)
        boot.sum_w = np.asarray(state["sum_w"], np.float64)
        return boot


def streaming_ci(
    acc: MetricAccumulator,
    boot: PoissonBootstrap | None,
    *,
    method: str = "bca",
    confidence: float = 0.95,
    binary: bool = False,
) -> Interval:
    """Streaming counterpart of :func:`repro.stats.bootstrap.compute_ci`.

    ``analytical`` is exact from the moments (Wilson for binary metrics, t
    otherwise).  The bootstrap methods (``percentile`` / ``bca``) map to the
    Poisson-bootstrap percentile interval — statistically equivalent to the
    in-memory multinomial bootstrap within Monte-Carlo noise, but computable
    without per-example scores.
    """
    if acc.n == 0:
        return Interval(float("nan"), float("nan"), float("nan"), "none", 0)
    if method == "analytical":
        if binary:
            return wilson_interval(
                int(round(acc.total)), acc.n, confidence=confidence
            )
        se = math.sqrt(acc.variance / acc.n) if acc.n > 1 else 0.0
        tcrit = t_ppf(1 - (1 - confidence) / 2, acc.n - 1) if acc.n > 1 else 0.0
        return Interval(
            acc.mean, acc.mean - tcrit * se, acc.mean + tcrit * se, "t", acc.n
        )
    if method not in ("percentile", "bca"):
        raise ValueError(f"unknown ci method {method!r}")
    if boot is None:
        raise ValueError(f"ci method {method!r} needs a PoissonBootstrap")
    return boot.interval(acc.mean, acc.n, confidence=confidence)
