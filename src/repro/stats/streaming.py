"""Mergeable streaming statistics for chunked evaluation at paper scale.

The in-memory :class:`~repro.core.stages.AggregateStage` holds every
per-example score so it can bootstrap a CI; at the paper's "hundreds of
thousands or millions of samples" that is O(dataset) memory.  This module
keeps the rigor story with O(B) state per metric:

* :class:`MetricAccumulator` — count / sum / sum-of-squares moments plus a
  NaN (unscorable) counter.  Mergeable, JSON-serializable, and sufficient
  for the exact mean, the analytical t-interval, and the Wilson interval
  for binary metrics.
* :class:`PoissonBootstrap` — B replicate ``(sum w*x, sum w)`` pairs under
  i.i.d. Poisson(1) resampling weights: the standard streaming /
  distributed bootstrap (Chamandy et al.; same scheme as the Pallas kernel
  in ``repro/kernels/bootstrap``).  Each chunk's weights come from a
  counter-based Philox stream keyed by ``(seed, chunk_start)``, so the
  accumulated replicates are deterministic given the chunk layout and
  independent of processing order — merging partial states from a resumed
  run reproduces the uninterrupted result bit-for-bit.

On top sit the pluggable **bootstrap engines**
(``StatisticsConfig.backend``) that the streaming pipeline drives — one
replicate state covering every metric of a task:

* :class:`NumpyBootstrapEngine` (``backend="numpy"``) — one host-Philox
  :class:`PoissonBootstrap` per metric; the authoritative stream is
  ``Philox(seed, chunk_start)``, and ``update`` materializes a
  (B, chunk) float64 weight block per metric.
* :class:`PallasBootstrapEngine` (``backend="pallas"``) — the
  chunked-partials kernel in ``repro/kernels/bootstrap``: weights are
  regenerated on the fly from the murmur3-finalizer counter mixer keyed by
  ``(seed, absolute example position, replicate)``, one launch covers all
  metrics of a chunk (a (chunk, n_metrics) score matrix), and nothing of
  O(B x chunk) ever touches the host heap.  On CPU the same stream runs
  through the blocked jnp oracle.

Both engines expose identical mergeable ``(sum w*x, sum w)`` state, and —
because the weight for an example depends only on the seed and the
example's position, never on the model being evaluated — two models
evaluated over the same chunk layout share their weight streams
replicate-for-replicate.  :class:`StreamingStats` carries that state on
the :class:`~repro.core.stages.EvalResult`, which is what lets
``repro.core.compare.compare_stream_stats`` build paired-delta bootstrap
comparisons without ever retaining per-example scores.

All accumulators serialize to plain dicts (``state()`` / ``from_state``)
so per-chunk partials can spill to a DeltaLite manifest and be merged on
resume.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.stats.bootstrap import Interval, wilson_interval
from repro.stats.special import t_ppf


class MetricAccumulator:
    """Mergeable moment accumulator for one metric's per-example scores."""

    def __init__(self) -> None:
        self.n = 0
        self.total = 0.0
        self.total_sq = 0.0
        self.n_nan = 0

    def update(self, scores: np.ndarray) -> None:
        scores = np.asarray(scores, np.float64)
        ok = scores[~np.isnan(scores)]
        self.n += int(ok.size)
        self.total += float(ok.sum())
        self.total_sq += float((ok * ok).sum())
        self.n_nan += int(scores.size - ok.size)

    def merge(self, other: "MetricAccumulator") -> "MetricAccumulator":
        self.n += other.n
        self.total += other.total
        self.total_sq += other.total_sq
        self.n_nan += other.n_nan
        return self

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else float("nan")

    @property
    def variance(self) -> float:
        """Unbiased (ddof=1) variance from the accumulated moments."""
        if self.n < 2:
            return 0.0
        var = (self.total_sq - self.total * self.total / self.n) / (self.n - 1)
        return max(var, 0.0)  # clamp catastrophic-cancellation dust

    def state(self) -> dict:
        return {
            "n": self.n, "total": self.total,
            "total_sq": self.total_sq, "n_nan": self.n_nan,
        }

    @classmethod
    def from_state(cls, state: dict) -> "MetricAccumulator":
        acc = cls()
        acc.n = int(state["n"])
        acc.total = float(state["total"])
        acc.total_sq = float(state["total_sq"])
        acc.n_nan = int(state["n_nan"])
        return acc


class PoissonBootstrap:
    """B mergeable bootstrap replicates under Poisson(1) resample weights.

    ``update(scores, start)`` draws a ``(n_boot, len(scores))`` weight block
    from ``Philox(key=(seed, start))`` — ``start`` is the chunk's global
    example offset — and folds it into the running ``sum(w*x)`` / ``sum(w)``
    pairs.  NaN scores get weight zero (excluded, matching the in-memory
    path's NaN filtering).  ``means()`` yields the B replicate means, whose
    percentiles form the CI.
    """

    def __init__(self, n_boot: int = 1000, seed: int = 0):
        self.n_boot = int(n_boot)
        self.seed = int(seed)
        self.sum_wx = np.zeros(self.n_boot, np.float64)
        self.sum_w = np.zeros(self.n_boot, np.float64)

    def update(self, scores: np.ndarray, start: int) -> None:
        scores = np.asarray(scores, np.float64)
        if scores.size == 0:
            return
        rng = np.random.Generator(np.random.Philox(key=[self.seed, start]))
        w = rng.poisson(1.0, (self.n_boot, scores.size)).astype(np.float64)
        valid = ~np.isnan(scores)
        w *= valid[None, :]
        self.sum_wx += w @ np.where(valid, scores, 0.0)
        self.sum_w += w.sum(axis=1)

    def merge(self, other: "PoissonBootstrap") -> "PoissonBootstrap":
        if (other.n_boot, other.seed) != (self.n_boot, self.seed):
            raise ValueError("cannot merge bootstraps with different (B, seed)")
        self.sum_wx += other.sum_wx
        self.sum_w += other.sum_w
        return self

    def means(self) -> np.ndarray:
        return self.sum_wx / np.maximum(self.sum_w, 1.0)

    def interval(
        self, value: float, n: int, *, confidence: float = 0.95
    ) -> Interval:
        alpha = (1 - confidence) / 2
        lo, hi = np.quantile(self.means(), [alpha, 1 - alpha])
        return Interval(value, float(lo), float(hi), "poisson", n)

    def state(self) -> dict:
        return {
            "n_boot": self.n_boot, "seed": self.seed,
            "sum_wx": self.sum_wx.tolist(), "sum_w": self.sum_w.tolist(),
        }

    @classmethod
    def from_state(cls, state: dict) -> "PoissonBootstrap":
        boot = cls(int(state["n_boot"]), int(state["seed"]))
        boot.sum_wx = np.asarray(state["sum_wx"], np.float64)
        boot.sum_w = np.asarray(state["sum_w"], np.float64)
        return boot


# -- pluggable bootstrap engines (StatisticsConfig.backend) ---------------------


class BootstrapEngine:
    """Mergeable multi-metric Poisson-bootstrap replicate state.

    Subclasses own B replicate ``(sum w*x, sum w)`` pairs per metric and
    differ only in where the weights come from (host Philox vs the device
    counter-mixer kernel) and how ``update`` is executed.  ``view(metric)``
    adapts one metric's state to a :class:`PoissonBootstrap` so interval
    extraction (:func:`streaming_ci`) and paired-delta comparisons share a
    single code path regardless of backend.
    """

    backend = ""

    def __init__(self, n_boot: int, seed: int, metrics: tuple[str, ...]):
        self.n_boot = int(n_boot)
        self.seed = int(seed)
        self.metrics = tuple(metrics)
        self.sum_wx = np.zeros((self.n_boot, len(self.metrics)), np.float64)
        self.sum_w = np.zeros((self.n_boot, len(self.metrics)), np.float64)

    # -- accumulation ----------------------------------------------------------

    def update(self, scores: dict[str, np.ndarray], start: int) -> None:
        raise NotImplementedError

    def stream_id(self) -> str:
        """Identifies the exact float-accumulation variant of the weight
        stream.  Partials are bit-mergeable only within one stream: the
        pallas backend resolves this per process (TPU kernel vs blocked
        CPU oracle), so a spill written on one platform refuses to resume
        float-inexactly on another."""
        return self.backend

    def _check_mergeable(self, backend: str, n_boot: int, seed: int,
                         metrics: tuple[str, ...], stream: str) -> None:
        ours = (
            self.backend, self.n_boot, self.seed, self.metrics,
            self.stream_id(),
        )
        theirs = (backend, int(n_boot), int(seed), tuple(metrics), stream)
        if ours != theirs:
            raise ValueError(
                f"cannot merge bootstrap engine states: {ours} != {theirs}"
            )

    def merge(self, other: "BootstrapEngine") -> "BootstrapEngine":
        self._check_mergeable(
            other.backend, other.n_boot, other.seed, other.metrics,
            other.stream_id(),
        )
        self.sum_wx += other.sum_wx
        self.sum_w += other.sum_w
        return self

    def merge_state(self, state: dict) -> "BootstrapEngine":
        """Fold a serialized chunk partial (spill-manifest row) in."""
        self._check_mergeable(
            state["backend"], state["n_boot"], state["seed"],
            tuple(state["metrics"]), state["stream"],
        )
        self.sum_wx += np.asarray(state["sum_wx"], np.float64)
        self.sum_w += np.asarray(state["sum_w"], np.float64)
        return self

    # -- extraction ------------------------------------------------------------

    def view(self, metric: str) -> PoissonBootstrap:
        """One metric's replicate state as a :class:`PoissonBootstrap`."""
        j = self.metrics.index(metric)
        boot = PoissonBootstrap(self.n_boot, self.seed)
        boot.sum_wx = self.sum_wx[:, j].copy()
        boot.sum_w = self.sum_w[:, j].copy()
        return boot

    # -- serialization ---------------------------------------------------------

    def state(self) -> dict:
        return {
            "backend": self.backend,
            "stream": self.stream_id(),
            "n_boot": self.n_boot,
            "seed": self.seed,
            "metrics": list(self.metrics),
            "sum_wx": self.sum_wx.tolist(),
            "sum_w": self.sum_w.tolist(),
        }

    def spawn(self) -> "BootstrapEngine":
        """A fresh zero-state engine with this engine's configuration
        (per-chunk partials that merge into the running state)."""
        return type(self)(self.n_boot, self.seed, self.metrics)


class NumpyBootstrapEngine(BootstrapEngine):
    """Host backend: ``Philox(seed, chunk_start)`` weight blocks — the
    exact stream :class:`PoissonBootstrap` has always drawn, kept for
    backward compatibility and host-scale runs.  Every metric uses the
    same key, so the (B, chunk) block is drawn once and masked per metric
    — bit-identical to M independent :class:`PoissonBootstrap` updates at
    1/M the RNG cost."""

    backend = "numpy"

    def update(self, scores: dict[str, np.ndarray], start: int) -> None:
        chunk = np.asarray(scores[self.metrics[0]], np.float64).size
        if chunk == 0:
            return
        rng = np.random.Generator(np.random.Philox(key=[self.seed, start]))
        w = rng.poisson(1.0, (self.n_boot, chunk)).astype(np.float64)
        for j, m in enumerate(self.metrics):
            x = np.asarray(scores[m], np.float64)
            valid = ~np.isnan(x)
            wm = w * valid[None, :]
            self.sum_wx[:, j] += wm @ np.where(valid, x, 0.0)
            self.sum_w[:, j] += wm.sum(axis=1)


class PallasBootstrapEngine(BootstrapEngine):
    """Device backend: one chunked-partials launch per chunk covers every
    metric; weights come from the kernel's counter mixer keyed by
    ``(seed, start + i, replicate)`` so partials are order-independent and
    bit-identical across crash/resume for an unchanged chunk layout."""

    backend = "pallas"

    #: execution path override ("auto" | "kernel" | "interpret" | "ref") —
    #: class-wide so tests can force the Pallas interpreter
    mode = "auto"

    def stream_id(self) -> str:
        from repro.kernels.bootstrap.ops import resolve_partials_mode

        return f"pallas-{resolve_partials_mode(self.mode)}"

    def update(self, scores: dict[str, np.ndarray], start: int) -> None:
        from repro.kernels.bootstrap.ops import bootstrap_partials

        mat = np.stack(
            [np.asarray(scores[m], np.float64) for m in self.metrics], axis=1
        )
        if mat.shape[0] == 0:
            return
        swx, sw = bootstrap_partials(
            mat, self.seed, start, n_boot=self.n_boot, mode=self.mode
        )
        self.sum_wx += swx.astype(np.float64)
        self.sum_w += sw.astype(np.float64)


_ENGINES = {
    NumpyBootstrapEngine.backend: NumpyBootstrapEngine,
    PallasBootstrapEngine.backend: PallasBootstrapEngine,
}


def make_bootstrap_engine(
    backend: str, n_boot: int, seed: int, metrics: tuple[str, ...]
) -> BootstrapEngine:
    if backend not in _ENGINES:
        raise ValueError(
            f"unknown statistics backend {backend!r}; "
            f"available: {sorted(_ENGINES)}"
        )
    return _ENGINES[backend](n_boot, seed, metrics)


def bootstrap_engine_from_state(state: dict) -> BootstrapEngine:
    eng = make_bootstrap_engine(
        state["backend"], state["n_boot"], state["seed"],
        tuple(state["metrics"]),
    )
    return eng.merge_state(state)


@dataclasses.dataclass
class StreamingStats:
    """The streaming run's aggregate statistical state, carried on the
    :class:`~repro.core.stages.EvalResult` in place of per-example scores.

    ``engine`` is None when the run used an analytical CI (no replicate
    state was maintained).  ``chunk_size`` and ``n_examples`` identify the
    chunk layout: two runs are paired-comparable only when seed, B,
    backend and layout all match — then their weight streams are
    replicate-for-replicate identical.
    """

    accs: dict[str, MetricAccumulator]
    engine: BootstrapEngine | None
    chunk_size: int
    n_examples: int

    def comparable_with(self, other: "StreamingStats") -> str | None:
        """None when paired deltas are valid, else the human-readable
        reason they are not."""
        if self.engine is None or other.engine is None:
            return (
                "no bootstrap replicate state (analytical ci_method); "
                "use a bootstrap ci_method to enable paired comparisons"
            )
        a, b = self.engine, other.engine
        if (a.stream_id(), a.n_boot, a.seed) != (
            b.stream_id(), b.n_boot, b.seed
        ):
            return (
                f"bootstrap streams differ: "
                f"({a.stream_id()}, B={a.n_boot}, seed={a.seed}) vs "
                f"({b.stream_id()}, B={b.n_boot}, seed={b.seed})"
            )
        if (self.chunk_size, self.n_examples) != (
            other.chunk_size, other.n_examples
        ):
            return (
                f"chunk layouts differ: "
                f"(chunk={self.chunk_size}, n={self.n_examples}) vs "
                f"(chunk={other.chunk_size}, n={other.n_examples})"
            )
        return None


def streaming_ci(
    acc: MetricAccumulator,
    boot: PoissonBootstrap | None,
    *,
    method: str = "bca",
    confidence: float = 0.95,
    binary: bool = False,
) -> Interval:
    """Streaming counterpart of :func:`repro.stats.bootstrap.compute_ci`.

    ``analytical`` is exact from the moments (Wilson for binary metrics, t
    otherwise).  The bootstrap methods (``percentile`` / ``bca``) map to the
    Poisson-bootstrap percentile interval — statistically equivalent to the
    in-memory multinomial bootstrap within Monte-Carlo noise, but computable
    without per-example scores.
    """
    if acc.n == 0:
        return Interval(float("nan"), float("nan"), float("nan"), "none", 0)
    if method == "analytical":
        if binary:
            return wilson_interval(
                int(round(acc.total)), acc.n, confidence=confidence
            )
        se = math.sqrt(acc.variance / acc.n) if acc.n > 1 else 0.0
        tcrit = t_ppf(1 - (1 - confidence) / 2, acc.n - 1) if acc.n > 1 else 0.0
        return Interval(
            acc.mean, acc.mean - tcrit * se, acc.mean + tcrit * se, "t", acc.n
        )
    if method not in ("percentile", "bca"):
        raise ValueError(f"unknown ci method {method!r}")
    if boot is None:
        raise ValueError(f"ci method {method!r} needs a PoissonBootstrap")
    return boot.interval(acc.mean, acc.n, confidence=confidence)
