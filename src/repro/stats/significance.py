"""Significance tests for paired model comparison (paper §4.3).

All implemented from first principles and validated against scipy in tests:
paired t, McNemar (exact binomial for <10 discordant pairs, chi-squared with
continuity correction otherwise), Wilcoxon signed-rank (normal approximation
with tie correction; exact enumeration for small n), sign-flip bootstrap
permutation.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.stats.special import (
    binom_test_two_sided,
    chi2_sf,
    norm_sf,
    t_sf,
)


@dataclasses.dataclass(frozen=True)
class TestResult:
    test: str
    statistic: float
    p_value: float
    n: int
    detail: dict | None = None

    def significant(self, alpha: float = 0.05) -> bool:
        return self.p_value < alpha


def paired_t_test(a, b) -> TestResult:
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    d = a - b
    n = d.shape[0]
    if n < 2:
        return TestResult("paired_t", 0.0, 1.0, n)
    sd = d.std(ddof=1)
    if sd == 0:
        return TestResult("paired_t", 0.0, 1.0 if d.mean() == 0 else 0.0, n)
    t = d.mean() / (sd / math.sqrt(n))
    p = 2.0 * t_sf(abs(t), n - 1)
    return TestResult("paired_t", float(t), min(1.0, p), n)


def mcnemar_test(a, b, *, exact_threshold: int = 10) -> TestResult:
    """Binary outcomes; considers only discordant pairs."""
    a = np.asarray(a).astype(bool)
    b = np.asarray(b).astype(bool)
    n01 = int(np.sum(~a & b))
    n10 = int(np.sum(a & ~b))
    disc = n01 + n10
    if disc == 0:
        return TestResult("mcnemar", 0.0, 1.0, len(a), {"n01": n01, "n10": n10})
    if disc < exact_threshold:
        p = binom_test_two_sided(min(n01, n10), disc, 0.5)
        return TestResult(
            "mcnemar_exact", float(min(n01, n10)), p, len(a),
            {"n01": n01, "n10": n10},
        )
    stat = (abs(n01 - n10) - 1.0) ** 2 / disc  # continuity-corrected chi2(1)
    p = chi2_sf(stat, 1.0)
    return TestResult(
        "mcnemar", float(stat), min(1.0, p), len(a), {"n01": n01, "n10": n10}
    )


def _wilcoxon_exact_p(w: float, ranks: np.ndarray) -> float:
    """Exact two-sided p by DP over the signed-rank distribution."""
    # distribution of W+ over all 2^n sign assignments, supports tied ranks
    scale = 2  # work in half-units so tied (x.5) ranks stay integral
    r_int = np.round(ranks * scale).astype(int)
    total = int(r_int.sum())
    poly = np.zeros(total + 1, np.float64)
    poly[0] = 1.0
    for r in r_int:
        nxt = poly.copy()
        nxt[r:] += poly[: total + 1 - r]
        poly = nxt
    poly /= poly.sum()
    w_int = int(round(w * scale))
    mu = total / 2.0
    lo = min(w_int, int(2 * mu) - w_int)
    hi = max(w_int, int(2 * mu) - w_int)
    p = poly[: lo + 1].sum() + poly[hi:].sum()
    return float(min(1.0, p))


def wilcoxon_signed_rank(a, b, *, exact_threshold: int = 25) -> TestResult:
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    d = a - b
    d = d[d != 0]  # standard practice: drop zero differences
    n = d.shape[0]
    if n == 0:
        return TestResult("wilcoxon", 0.0, 1.0, 0)
    order = np.argsort(np.abs(d))
    ranks = np.empty(n, np.float64)
    absd = np.abs(d)[order]
    # average ranks over ties
    i = 0
    while i < n:
        j = i
        while j + 1 < n and absd[j + 1] == absd[i]:
            j += 1
        ranks[i : j + 1] = (i + j) / 2.0 + 1.0
        i = j + 1
    signed = np.empty(n, np.float64)
    signed[order] = ranks
    w_plus = float(signed[d > 0].sum())
    w_minus = float(signed[d < 0].sum())
    w = min(w_plus, w_minus)

    if n <= exact_threshold:
        p = _wilcoxon_exact_p(w_plus, ranks)
        return TestResult("wilcoxon_exact", w, p, n)

    mu = n * (n + 1) / 4.0
    sigma2 = n * (n + 1) * (2 * n + 1) / 24.0
    # tie correction
    _, counts = np.unique(np.abs(d), return_counts=True)
    sigma2 -= np.sum(counts**3 - counts) / 48.0
    if sigma2 <= 0:
        return TestResult("wilcoxon", w, 1.0, n)
    z = (w - mu + 0.5) / math.sqrt(sigma2)  # continuity correction
    p = 2.0 * norm_sf(abs(z))
    return TestResult("wilcoxon", w, min(1.0, p), n)


def permutation_test(
    a, b, *, n_perm: int = 2000, seed: int = 0, stat: str = "mean"
) -> TestResult:
    """Sign-flip permutation test on paired differences."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    d = a - b
    n = d.shape[0]
    rng = np.random.default_rng(seed)
    observed = abs(d.mean() if stat == "mean" else np.median(d))
    signs = rng.choice([-1.0, 1.0], size=(n_perm, n))
    flipped = signs * d[None, :]
    perm_stats = np.abs(
        flipped.mean(axis=1) if stat == "mean" else np.median(flipped, axis=1)
    )
    p = (1.0 + np.sum(perm_stats >= observed - 1e-15)) / (n_perm + 1.0)
    return TestResult("permutation", float(observed), float(min(1.0, p)), n)
