"""Anytime-valid sequential confidence intervals and certified verdicts.

The fixed-n intervals in :mod:`repro.stats.bootstrap` are valid only when
the sample size was chosen before looking at the data.  An *adaptive*
evaluation — "stop sampling this task once the answer is settled" — peeks
after every chunk, and a fixed-n CI peeked at repeatedly inflates the
type-1 error without bound.  Confidence *sequences* fix this: a family of
intervals ``CI_n`` such that

    P( exists n >= 1 : true mean not in CI_n ) <= alpha

holds simultaneously over all n, so stopping the moment the interval is
tight enough (or certifies a verdict) cannot break coverage — optional
stopping is free by construction (Robbins; Waudby-Smith et al. 2021,
"Time-uniform central limit theory and asymptotic confidence sequences";
the Cer-Eval and error-bars-for-evals papers motivate exactly this use).

Two boundaries, both computable from the O(1) mergeable moment state of
:class:`~repro.stats.streaming.MetricAccumulator` — nothing per-example:

* ``acs`` (default) — the asymptotic confidence sequence from the Robbins
  normal-mixture boundary with the empirical variance plugged in:

      x̄_n ± sqrt( 2(σ̂²ρ²n + 1)/(n²ρ²) · log( sqrt(σ̂²ρ²n + 1)/α ) )

  Width shrinks like sqrt(log n / n) — a ~1.5-1.8x premium over the
  fixed-n interval is the price of unlimited peeking.
* ``mixture`` — the same mixture boundary with the a-priori sub-Gaussian
  scale ``scale`` (default 1/2: any [0,1]-bounded metric) in place of
  σ̂.  Non-asymptotic, conservative; use it when n is small enough that
  plugging in σ̂ feels optimistic.

``rho`` tunes *where* the sequence is tightest (it is valid everywhere):
:func:`rho_opt` picks the ρ minimizing the boundary at a target n.

Paired verdicts ride on the PR-4 replicate-delta machinery: two streaming
runs over the same chunk layout share their Poisson-bootstrap weight
streams, so the variance of the replicate-mean deltas estimates the
per-example paired-delta variance at zero extra cost —
:func:`sequential_compare` turns that into an anytime-valid CI on the mean
difference and a :func:`certify_verdict` at a caller-set margin.

:class:`StoppingRule` packages the per-task early-stopping policy the
streaming pipelines consult after every committed chunk
(:mod:`repro.core.streaming`), and is a frozen, JSON-serializable
dataclass so it can live on :class:`~repro.core.config.EvalTask` and be
fingerprinted into the spill-manifest resume contract.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from typing import TYPE_CHECKING, Mapping

if TYPE_CHECKING:  # pragma: no cover — annotation-only import
    from repro.stats.streaming import StreamingStats

#: verdict values produced by :func:`certify_verdict`
VERDICTS = ("a_better", "b_better", "equivalent", "undecided")


@dataclasses.dataclass(frozen=True)
class SeqInterval:
    """One element of a confidence sequence (anytime-valid at level alpha)."""

    value: float
    lo: float
    hi: float
    half_width: float
    n: int
    method: str
    alpha: float

    def as_tuple(self) -> tuple[float, float]:
        return (self.lo, self.hi)


def rho_opt(n_opt: int, alpha: float = 0.05) -> float:
    """Mixture parameter minimizing the boundary width at sample size
    ``n_opt`` (Waudby-Smith et al., eq. for the AsympCS tuning).  Any
    ``rho > 0`` is valid; this only moves where the sequence is tightest.
    """
    if n_opt < 1:
        raise ValueError(f"n_opt must be >= 1, got {n_opt}")
    if not 0.0 < alpha < 1.0:
        raise ValueError(f"alpha must be in (0, 1), got {alpha}")
    la = -2.0 * math.log(alpha)
    return math.sqrt((la + math.log(la + 1.0)) / n_opt)


def mixture_half_width(
    var: float, n: int, *, alpha: float = 0.05, rho: float = 0.0
) -> float:
    """Half-width of the Robbins normal-mixture boundary at sample size n
    for increments of variance ``var`` — the shared kernel of both the
    ``acs`` (plug in σ̂²) and ``mixture`` (plug in a-priori scale²)
    sequences.  Infinite below n=1 so callers never stop on no data."""
    if n < 1:
        return float("inf")
    if rho <= 0.0:
        rho = rho_opt(max(n, 1), alpha)
    vr = var * n * rho * rho + 1.0
    return math.sqrt(
        (2.0 * vr / (n * n * rho * rho)) * math.log(math.sqrt(vr) / alpha)
    )


def sequential_ci(
    acc,
    *,
    alpha: float = 0.05,
    rho: float = 0.0,
    method: str = "acs",
    scale: float = 0.5,
) -> SeqInterval:
    """Anytime-valid CI for a metric mean from its moment accumulator.

    ``acc`` is anything with ``mean`` / ``variance`` / ``n`` — in practice
    a :class:`~repro.stats.streaming.MetricAccumulator`, so the interval
    is computable incrementally after every merged chunk, resumed or live.
    """
    if method not in ("acs", "mixture"):
        raise ValueError(f"unknown sequential method {method!r}")
    n = int(acc.n)
    if n == 0:
        nan = float("nan")
        return SeqInterval(nan, nan, nan, float("inf"), 0, method, alpha)
    # acs needs >= 2 points for a variance estimate; mixture does not
    if method == "acs":
        var = acc.variance if n >= 2 else float("inf")
    else:
        var = scale * scale
    hw = (
        mixture_half_width(var, n, alpha=alpha, rho=rho)
        if math.isfinite(var)
        else float("inf")
    )
    return SeqInterval(
        acc.mean, acc.mean - hw, acc.mean + hw, hw, n, method, alpha
    )


def certify_verdict(lo: float, hi: float, margin: float = 0.0) -> str:
    """Map a CI on (mean_A - mean_B) to a certified verdict.

    * ``a_better`` / ``b_better`` — the interval clears ``±margin``
      entirely (superiority beyond the margin; margin 0 = any difference);
    * ``equivalent`` — the interval is contained in ``(-margin, margin)``
      (only reachable with ``margin > 0``);
    * ``undecided`` — keep sampling.

    Because the interval is anytime-valid, a certified verdict is wrong
    with probability at most alpha *regardless of the stopping rule*.
    """
    if not (math.isfinite(lo) and math.isfinite(hi)):
        return "undecided"
    if lo > margin:
        return "a_better"
    if hi < -margin:
        return "b_better"
    if margin > 0.0 and lo > -margin and hi < margin:
        return "equivalent"
    return "undecided"


@dataclasses.dataclass(frozen=True)
class SequentialComparison:
    """Anytime-valid paired comparison of two streaming runs on one metric."""

    metric: str
    mean_a: float
    mean_b: float
    diff: float
    lo: float
    hi: float
    half_width: float
    n: int
    verdict: str
    alpha: float
    margin: float
    #: True when the delta variance came from shared-weight-stream
    #: replicate deltas; False = conservative unpaired var_a + var_b
    paired: bool

    def summary(self) -> str:
        return (
            f"{self.metric}: Δ={self.diff:+.4f} "
            f"CS=({self.lo:+.4f},{self.hi:+.4f}) n={self.n} "
            f"verdict={self.verdict} (margin={self.margin:g}, "
            f"alpha={self.alpha:g}, {'paired' if self.paired else 'unpaired'})"
        )


def paired_delta_variance(
    metric: str, a: "StreamingStats", b: "StreamingStats"
) -> tuple[float, bool]:
    """Per-example variance of the paired score delta, and whether it was
    actually paired.

    The replicate-mean deltas of two runs sharing a weight stream have
    variance ~ Var(x_A - x_B)/n (the paired bootstrap), so scaling back by
    n recovers the per-example delta variance — free from the PR-4 state,
    no per-example scores.  Falls back to the unpaired upper bound
    ``var_a + var_b`` (correlation ignored) when replicate state is absent
    or the streams are not shared.
    """
    acc_a, acc_b = a.accs[metric], b.accs[metric]
    n = min(acc_a.n, acc_b.n)
    if (
        a.engine is not None
        and b.engine is not None
        and a.comparable_with(b) is None
        and n >= 2
    ):
        import numpy as np

        deltas = a.engine.view(metric).means() - b.engine.view(metric).means()
        var = float(np.var(deltas, ddof=1)) * n
        if math.isfinite(var):
            return max(var, 0.0), True
    return acc_a.variance + acc_b.variance, False


def sequential_compare(
    metric: str,
    a: "StreamingStats",
    b: "StreamingStats",
    *,
    alpha: float = 0.05,
    margin: float = 0.0,
    rho: float = 0.0,
    method: str = "acs",
) -> SequentialComparison:
    """Anytime-valid CI + certified verdict on mean_A - mean_B.

    Safe to call after every round of an adaptive suite: the confidence
    sequence keeps its level under continued monitoring, so the first
    round whose verdict is not ``undecided`` may stop sampling the pair.
    """
    acc_a, acc_b = a.accs[metric], b.accs[metric]
    n = min(acc_a.n, acc_b.n)
    diff = acc_a.mean - acc_b.mean
    var_d, paired = paired_delta_variance(metric, a, b)
    if method == "mixture":
        # deltas of [0,1]-bounded scores live in [-1,1]: scale 1
        hw = mixture_half_width(1.0, n, alpha=alpha, rho=rho)
    else:
        hw = (
            mixture_half_width(var_d, n, alpha=alpha, rho=rho)
            if n >= 2
            else float("inf")
        )
    lo, hi = diff - hw, diff + hw
    return SequentialComparison(
        metric=metric,
        mean_a=acc_a.mean,
        mean_b=acc_b.mean,
        diff=diff,
        lo=lo,
        hi=hi,
        half_width=hw,
        n=n,
        verdict=certify_verdict(lo, hi, margin),
        alpha=alpha,
        margin=margin,
        paired=paired,
    )


# -- per-task early stopping ---------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StopDecision:
    stop: bool
    reason: str = ""          # "" | "target_half_width" | "max_examples"
    metric: str = ""
    half_width: float = float("inf")
    n: int = 0


@dataclasses.dataclass(frozen=True)
class StoppingRule:
    """Per-task early-stopping policy, consulted after every merged chunk.

    Lives on :class:`~repro.core.config.EvalTask` (``task.stopping``,
    ``task.with_stopping(...)``).  The statistical fields are part of the
    spill-manifest resume contract (:meth:`fingerprint`): a resumed run
    must certify under the *same* rule that wrote the manifest, or refuse
    — mixing stopping regimes inside one manifest would make the recorded
    stop point meaningless.

    * ``target_half_width`` — stop once the anytime-valid CI half-width of
      ``metric`` (or of *every* metric when ``metric`` is empty) is at or
      below this; 0 disables the width trigger.
    * ``max_examples`` — hard sampling cap; reaching it is a final stop
      with reason ``max_examples`` (verdict possibly undecided).  0 means
      unbounded.  Round-level caps belong to the budget scheduler
      (:mod:`repro.core.budget`), which slices the source instead.
    * ``min_examples`` — never stop before this many scored examples; also
      the sample size :func:`rho_opt` tunes the sequence to be tightest at
      when ``rho`` is 0 (auto).
    * ``margin`` — certification margin used for paired verdicts at the
      suite level; carried here so one rule object describes the whole
      certification regime.
    """

    enabled: bool = False
    metric: str = ""
    target_half_width: float = 0.0
    margin: float = 0.0
    min_examples: int = 256
    max_examples: int = 0
    alpha: float = 0.05
    rho: float = 0.0
    method: str = "acs"       # acs | mixture

    def effective_rho(self) -> float:
        if self.rho > 0.0:
            return self.rho
        return rho_opt(max(self.min_examples, 2), self.alpha)

    def fingerprint(self) -> str:
        """Identity of the certification regime — every statistical field.
        Two rules with equal fingerprints make bit-identical stop
        decisions on the same accumulator stream."""
        payload = json.dumps(dataclasses.asdict(self), sort_keys=True)
        return hashlib.sha256(payload.encode()).hexdigest()[:16]

    def ci(self, acc) -> SeqInterval:
        return sequential_ci(
            acc, alpha=self.alpha, rho=self.effective_rho(), method=self.method
        )

    def should_stop(self, accs: Mapping[str, object], n_examples: int) -> StopDecision:
        """Decide after a merged chunk.  Deterministic in (rule, accs):
        resumed runs replay the identical decision sequence."""
        if not self.enabled:
            return StopDecision(False)
        watched = [self.metric] if self.metric else sorted(accs)
        missing = [m for m in watched if m not in accs]
        if missing:
            raise KeyError(
                f"stopping rule watches unknown metric(s) {missing}; "
                f"task computes {sorted(accs)}"
            )
        widths = {m: self.ci(accs[m]).half_width for m in watched}
        worst = max(watched, key=lambda m: widths[m])
        if n_examples < self.min_examples:
            return StopDecision(False)
        if self.target_half_width > 0.0 and all(
            widths[m] <= self.target_half_width for m in watched
        ):
            return StopDecision(
                True, "target_half_width", worst, widths[worst], n_examples
            )
        if self.max_examples > 0 and n_examples >= self.max_examples:
            return StopDecision(
                True, "max_examples", worst, widths[worst], n_examples
            )
        return StopDecision(False)
