"""Mamba2 (SSD — state-space duality) blocks.

Train/prefill use the chunked SSD form: intra-chunk quadratic (attention-like,
MXU-friendly) + inter-chunk linear state recurrence — the TPU-native
adaptation of the paper's algorithm (chunk size sized so the quadratic tile
lives in VMEM; see ``repro/kernels/ssd`` for the Pallas version).
Decode is the O(1) recurrent step on a (B, H, P, N) state.

Reference: Dao & Gu, "Transformers are SSMs" (arXiv:2405.21060), minimal SSD.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.params import ParamSpec
from repro.models.unroll import maybe_scan

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def mamba2_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_inner = cfg.d_inner
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    h = cfg.ssm_nheads
    conv_dim = d_inner + 2 * g * n
    return {
        # fused in_proj of the reference is split per component for clean TP
        "wz": layers.dense_spec(d, d_inner, ("embed", "ssm_inner")),
        "wxBC": layers.dense_spec(d, conv_dim, ("embed", "ssm_inner")),
        "wdt": layers.dense_spec(d, h, ("embed", "ssm_heads")),
        "conv_w": ParamSpec((cfg.ssm_conv, conv_dim), jnp.float32, (None, "ssm_inner")),
        "conv_b": ParamSpec((conv_dim,), jnp.float32, ("ssm_inner",), init="zeros"),
        "A_log": ParamSpec((h,), jnp.float32, ("ssm_heads",), init="ones"),
        "D": ParamSpec((h,), jnp.float32, ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec((h,), jnp.float32, ("ssm_heads",), init="zeros"),
        "norm": layers.rms_norm_spec(d_inner, "ssm_inner"),
        "out_proj": layers.dense_spec(d_inner, d, ("ssm_inner", "embed")),
    }


def mamba2_init_cache(
    cfg: ModelConfig, batch: int, dtype: Any = jnp.float32
) -> dict:
    d_inner = cfg.d_inner
    g, n, h, p = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_head_dim
    conv_dim = d_inner + 2 * g * n
    return {
        "conv": ParamSpec(
            (batch, cfg.ssm_conv - 1, conv_dim),
            dtype,
            ("batch", None, "ssm_inner"),
            init="zeros",
        ),
        "state": ParamSpec(
            (batch, h, p, n), dtype, ("batch", "ssm_heads", None, None), init="zeros"
        ),
    }


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def _segsum(x: jax.Array) -> jax.Array:
    """segsum(x)[..., i, j] = sum_{k=j+1..i} x_k for i >= j, -inf above diag."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, NEG_INF)


def ssd_chunked(
    x: jax.Array,   # (B, L, H, P) — conv output, pre-dt
    dt: jax.Array,  # (B, L, H) — post-softplus
    a: jax.Array,   # (H,) — negative
    b_mat: jax.Array,  # (B, L, H, N) — already broadcast to heads
    c_mat: jax.Array,  # (B, L, H, N)
    chunk: int,
    initial_state: jax.Array | None = None,  # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Chunked state-space-dual scan. Returns (y, final_state)."""
    bsz, slen, h, p = x.shape
    n = b_mat.shape[-1]
    chunk = min(chunk, slen)
    assert slen % chunk == 0, (slen, chunk)
    nc = slen // chunk

    f32 = jnp.float32
    xd = (x * dt[..., None]).astype(f32)  # dt folded into x
    da = (dt.astype(f32) * a.astype(f32))  # (B,L,H)

    xc = xd.reshape(bsz, nc, chunk, h, p)
    bc = b_mat.reshape(bsz, nc, chunk, h, n).astype(f32)
    cc = c_mat.reshape(bsz, nc, chunk, h, n).astype(f32)
    dac = da.reshape(bsz, nc, chunk, h)
    dacs = jnp.cumsum(dac, axis=2)  # (B,nc,q,H)

    # --- intra-chunk (quadratic, attention-like — the MXU part) ------------
    lmat = jnp.exp(_segsum(jnp.moveaxis(dac, 3, 2)))  # (B,nc,H,q,s)
    scores = jnp.einsum("bcqhn,bcshn->bchqs", cc, bc)
    y_diag = jnp.einsum("bchqs,bcshp->bcqhp", scores * lmat, xc)

    # --- per-chunk final states --------------------------------------------
    decay_states = jnp.exp(dacs[:, :, -1:, :] - dacs)  # (B,nc,q,H)
    chunk_states = jnp.einsum("bcshn,bcsh,bcshp->bchpn", bc, decay_states, xc)

    # --- inter-chunk recurrence ---------------------------------------------
    chunk_decay = jnp.exp(dacs[:, :, -1, :])  # (B,nc,H)

    def step(state, inp):
        s_c, d_c = inp
        entering = state
        state = state * d_c[:, :, None, None] + s_c
        return state, entering

    init = (
        initial_state.astype(f32)
        if initial_state is not None
        else jnp.zeros((bsz, h, p, n), f32)
    )
    final_state, entering_states = maybe_scan(
        step,
        init,
        (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    entering_states = jnp.moveaxis(entering_states, 0, 1)  # (B,nc,H,P,N)

    # --- off-diagonal (cross-chunk) contribution ----------------------------
    state_decay = jnp.exp(dacs)  # (B,nc,q,H)
    y_off = jnp.einsum(
        "bcqhn,bchpn,bcqh->bcqhp", cc, entering_states, state_decay
    )

    y = (y_diag + y_off).reshape(bsz, slen, h, p)
    return y.astype(x.dtype), final_state


# ---------------------------------------------------------------------------
# Block forward (full-sequence and decode)
# ---------------------------------------------------------------------------


def _depthwise_causal_conv(
    x: jax.Array, w: jax.Array, b: jax.Array
) -> jax.Array:
    """x (B, L, C), w (K, C): left-padded depthwise conv."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32),
        w[:, None, :].astype(jnp.float32),  # (K, 1, C) HIO depthwise
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _split_xbc(cfg: ModelConfig, xbc: jax.Array):
    d_inner = cfg.d_inner
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    x_ssm, b_mat, c_mat = jnp.split(xbc, [d_inner, d_inner + g * n], axis=-1)
    return x_ssm, b_mat, c_mat


def _to_heads(cfg: ModelConfig, x_ssm, b_mat, c_mat):
    bsz, slen = x_ssm.shape[:2]
    h, p = cfg.ssm_nheads, cfg.ssm_head_dim
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    x_h = x_ssm.reshape(bsz, slen, h, p)
    rep = h // g
    b_h = jnp.repeat(b_mat.reshape(bsz, slen, g, n), rep, axis=2)
    c_h = jnp.repeat(c_mat.reshape(bsz, slen, g, n), rep, axis=2)
    return x_h, b_h, c_h


def mamba2_full(
    params: dict, cfg: ModelConfig, x: jax.Array
) -> jax.Array:
    """Full-sequence Mamba2 block (train / prefill)."""
    bsz, slen, _ = x.shape
    z = layers.dense(params["wz"], x)
    xbc = layers.dense(params["wxBC"], x)
    dt_raw = layers.dense(params["wdt"], x)  # (B,L,H)

    xbc = jax.nn.silu(_depthwise_causal_conv(xbc, params["conv_w"], params["conv_b"]))
    xbc = sharding.constrain(xbc, ("batch", "seq", "ssm_inner"))
    x_ssm, b_mat, c_mat = _split_xbc(cfg, xbc)
    x_h, b_h, c_h = _to_heads(cfg, x_ssm, b_mat, c_mat)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )
    a = -jnp.exp(params["A_log"].astype(jnp.float32))

    y, _ = ssd_chunked(x_h, dt, a, b_h, c_h, cfg.ssm_chunk)
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * x_h
    y = y.reshape(bsz, slen, cfg.d_inner)

    y = layers.rms_norm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    y = sharding.constrain(y, ("batch", "seq", "ssm_inner"))
    return layers.dense(params["out_proj"], y)


def mamba2_prefill(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, L, D)
    cache: dict,  # {"conv": (B, K-1, C), "state": (B, H, P, N)}
) -> tuple[jax.Array, dict]:
    """Full-sequence pass that also produces the decode cache.

    Identical math to :func:`mamba2_full`, but returns the final SSD state and
    the trailing conv window so decoding can continue from position L.
    """
    bsz, slen, _ = x.shape
    z = layers.dense(params["wz"], x)
    xbc_raw = layers.dense(params["wxBC"], x)
    dt_raw = layers.dense(params["wdt"], x)

    xbc = jax.nn.silu(
        _depthwise_causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
    )
    x_ssm, b_mat, c_mat = _split_xbc(cfg, xbc)
    x_h, b_h, c_h = _to_heads(cfg, x_ssm, b_mat, c_mat)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )
    a = -jnp.exp(params["A_log"].astype(jnp.float32))

    y, final_state = ssd_chunked(x_h, dt, a, b_h, c_h, cfg.ssm_chunk)
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * x_h
    y = y.reshape(bsz, slen, cfg.d_inner)
    y = layers.rms_norm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = layers.dense(params["out_proj"], y)

    k = cfg.ssm_conv
    window = xbc_raw[:, -(k - 1):, :] if slen >= k - 1 else jnp.concatenate(
        [cache["conv"].astype(xbc_raw.dtype)[:, slen:], xbc_raw], axis=1
    )
    new_cache = {
        "conv": window.astype(cache["conv"].dtype),
        "state": final_state.astype(cache["state"].dtype),
    }
    return out, new_cache


def mamba2_decode(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, 1, D)
    cache: dict,  # {"conv": (B, K-1, C), "state": (B, H, P, N)}
) -> tuple[jax.Array, dict]:
    """Single-token recurrent step."""
    bsz = x.shape[0]
    h, p, n = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
    g = cfg.ssm_ngroups

    z = layers.dense(params["wz"], x)[:, 0]  # (B, d_inner)
    xbc_new = layers.dense(params["wxBC"], x)[:, 0]  # (B, C)
    dt_raw = layers.dense(params["wdt"], x)[:, 0]  # (B, H)

    # rolling conv buffer: window = [cache, new]
    window = jnp.concatenate(
        [cache["conv"].astype(xbc_new.dtype), xbc_new[:, None, :]], axis=1
    )  # (B, K, C)
    conv_out = jnp.einsum(
        "bkc,kc->bc", window.astype(jnp.float32), params["conv_w"].astype(jnp.float32)
    ) + params["conv_b"].astype(jnp.float32)
    xbc = jax.nn.silu(conv_out).astype(x.dtype)
    conv_cache = window[:, 1:, :].astype(cache["conv"].dtype)

    x_ssm, b_mat, c_mat = _split_xbc(cfg, xbc)
    x_h = x_ssm.reshape(bsz, h, p)
    rep = h // g
    b_h = jnp.repeat(b_mat.reshape(bsz, g, n), rep, axis=1)  # (B,H,N)
    c_h = jnp.repeat(c_mat.reshape(bsz, g, n), rep, axis=1)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (B,H)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    da = jnp.exp(dt * a)  # (B,H)

    state = cache["state"].astype(jnp.float32)
    state = state * da[:, :, None, None] + jnp.einsum(
        "bhp,bhn->bhpn",
        dt[..., None] * x_h.astype(jnp.float32),
        b_h.astype(jnp.float32),
    )
    y = jnp.einsum("bhpn,bhn->bhp", state, c_h.astype(jnp.float32))
    y = y + params["D"].astype(jnp.float32)[None, :, None] * x_h.astype(jnp.float32)
    y = y.reshape(bsz, cfg.d_inner).astype(x.dtype)

    y = layers.rms_norm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = layers.dense(params["out_proj"], y[:, None, :])
    return out, {"conv": conv_cache, "state": state.astype(cache["state"].dtype)}
