"""Mixture-of-Experts layer (token-choice top-k, GShard/MaxText style).

TPU-native design notes (DESIGN.md §5):

* **Dense-dispatch einsum formulation** — dispatch/combine are one-hot
  ``(B, S, E, C)`` tensors contracted on the MXU.  Experts shard over the
  ``model`` mesh axis (expert parallelism), tokens over ``(pod, data)``;
  XLA SPMD inserts the all-to-all equivalent collectives automatically.
  This is the GShard formulation that MaxText ships as its "dropping"
  strategy — no scatter/gather, fully static shapes, scan-compatible.
* **Capacity-factor dropping** — each expert accepts at most
  ``C = round_up(k * S * capacity_factor / E, 4)`` tokens per batch row.
  Overflowing tokens fall through on the residual path (standard GShard
  behaviour).
* **Aux load-balancing loss** (Switch-style) is returned alongside the
  output so the training loss can add ``router_aux_weight * aux``.
* **Shared experts** (DeepSeek-V2) are plain always-on MLPs added to the
  routed output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.params import ParamSpec


def expert_capacity(cfg: ModelConfig, seq_len: int) -> int:
    """Per-expert, per-batch-row token capacity (multiple of 4 for layout)."""
    cap = cfg.n_experts_per_token * seq_len * cfg.capacity_factor / cfg.n_experts
    cap = int(cap + 0.999)
    return max(4, ((cap + 3) // 4) * 4)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def moe_specs(cfg: ModelConfig) -> dict:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    spec: dict = {
        "router": ParamSpec((d, e), jnp.float32, ("embed", "expert")),
        # stacked expert weights: leading expert axis shards over "model"
        "wi": ParamSpec((e, d, ff), jnp.float32, ("expert", "expert_data", None)),
        "wg": ParamSpec((e, d, ff), jnp.float32, ("expert", "expert_data", None)),
        "wo": ParamSpec((e, ff, d), jnp.float32, ("expert", None, "expert_data")),
    }
    if cfg.n_shared_experts:
        # shared experts = one fused MLP with n_shared * moe_d_ff hidden
        spec["shared"] = layers.gated_mlp_spec(d, cfg.n_shared_experts * ff)
    return spec


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------


def _top_k_mask(
    probs: jax.Array, k: int
) -> tuple[jax.Array, jax.Array]:
    """Return (weights, mask) of shape (..., E): top-k gate values, 0 elsewhere."""
    top_vals, _ = jax.lax.top_k(probs, k)
    thresh = top_vals[..., -1:]
    mask = probs >= thresh
    # guard against ties admitting >k experts: keep weights but renormalize
    weights = jnp.where(mask, probs, 0.0)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9
    )
    return weights, mask


def moe_block(
    params: dict, cfg: ModelConfig, x: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Routed-experts forward.  ``x`` is (B, S, D); returns (out, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_per_token
    cap = expert_capacity(cfg, s)
    f32 = jnp.float32

    logits = jnp.einsum(
        "bsd,de->bse", x.astype(f32), params["router"].astype(f32)
    )
    probs = jax.nn.softmax(logits, axis=-1)  # (B,S,E)
    weights, mask = _top_k_mask(probs, k)

    # --- Switch-style aux load-balancing loss ------------------------------
    # fraction of tokens routed to each expert x mean router prob per expert
    frac_tokens = jnp.mean(mask.astype(f32), axis=(0, 1))  # (E,)
    frac_probs = jnp.mean(probs, axis=(0, 1))  # (E,)
    aux = e * jnp.sum(frac_tokens * frac_probs)

    # --- capacity assignment -------------------------------------------------
    # position of each token within its expert's queue (per batch row)
    pos_in_expert = jnp.cumsum(mask.astype(jnp.int32), axis=1) - 1  # (B,S,E)
    keep = mask & (pos_in_expert < cap)
    # one-hot over capacity slots: (B,S,E,C)
    slot_oh = jax.nn.one_hot(
        jnp.where(keep, pos_in_expert, -1), cap, dtype=x.dtype
    )
    dispatch = slot_oh  # (B,S,E,C), 1 where token -> (expert, slot)
    combine = slot_oh.astype(f32) * weights[..., None].astype(f32)

    # --- dispatch -> expert MLP -> combine ----------------------------------
    expert_in = jnp.einsum("bsec,bsd->ebcd", dispatch, x)
    rules = sharding.current_rules()
    if rules is not None and rules.moe_dispatch == "weight_stationary":
        # decode-time 2D expert sharding: keep weights still, reshard the
        # (tiny) dispatched token block to match the weights' d_model shards
        expert_in = sharding.constrain(
            expert_in, ("expert", None, None, "expert_data")
        )
    else:
        expert_in = sharding.constrain(expert_in, ("expert", "batch", None, None))
    h_g = jnp.einsum("ebcd,edf->ebcf", expert_in, params["wg"].astype(x.dtype))
    h_i = jnp.einsum("ebcd,edf->ebcf", expert_in, params["wi"].astype(x.dtype))
    h = layers.activation(cfg.act, h_g) * h_i
    h = sharding.constrain(h, ("expert", "batch", None, None))
    expert_out = jnp.einsum("ebcf,efd->ebcd", h, params["wo"].astype(x.dtype))

    out = jnp.einsum(
        "bsec,ebcd->bsd", combine.astype(f32), expert_out.astype(f32)
    ).astype(x.dtype)

    if cfg.n_shared_experts:
        out = out + layers.gated_mlp(params["shared"], x, cfg.act)
    out = sharding.constrain(out, ("batch", None, "embed"))
    return out, aux.astype(f32)
