"""Attention blocks: GQA/MQA/MHA, MLA (multi-head latent), cross-attention.

Three execution paths:

* ``naive``    — full score matrix; only safe for short sequences.
* ``chunked``  — double-scan (q-blocks x k-blocks) online-softmax, the jnp
  twin of the Pallas flash kernel; default for train/prefill. O(block²)
  memory instead of O(S²).
* ``pallas``   — the TPU kernel in :mod:`repro.kernels.flash_attention`
  (selected via config; dry-run always uses a jnp path because Mosaic does
  not lower on the CPU backend).

Decode paths use a pre-allocated KV cache, per-sequence positions (so the
continuous-batching scheduler can step ragged batches), and — for MLA — the
*absorbed* formulation that keeps the cache in the compressed latent space.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.params import ParamSpec
from repro.models.unroll import maybe_scan

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Dense (non-flash) grouped attention
# ---------------------------------------------------------------------------


def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, -1)


def naive_attention(
    q: jax.Array,  # (B, Sq, H, dh)
    k: jax.Array,  # (B, Sk, K, dh)
    v: jax.Array,  # (B, Sk, K, dv)
    mask: jax.Array | None,  # broadcastable to (B, K, G, Sq, Sk) or None
    scale: float,
) -> jax.Array:
    b, sq, h, dh = q.shape
    kheads = k.shape[2]
    g = h // kheads
    qg = q.reshape(b, sq, kheads, g, dh)
    scores = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, h, -1).astype(q.dtype)


def _divisor_block(s: int, target: int) -> int:
    """Largest divisor of ``s`` that is <= ``target`` (block-size picker)."""
    target = min(target, s)
    for b in range(target, 0, -1):
        if s % b == 0:
            return b
    return s


def chunked_attention(
    q: jax.Array,  # (B, Sq, H, dh)
    k: jax.Array,  # (B, Sk, K, dh)
    v: jax.Array,  # (B, Sk, K, dv)
    *,
    causal: bool,
    scale: float,
    q_offset: int = 0,
    prefix_len: int = 0,
    block_q: int = 512,
    block_k: int = 512,
) -> jax.Array:
    """Online-softmax tiled attention (jnp twin of the flash kernel).

    ``prefix_len`` > 0 gives a prefix-LM mask: positions < prefix_len are
    mutually visible (PaliGemma); the causal rule applies after the prefix.
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    kheads = k.shape[2]
    g = h // kheads
    dv = v.shape[-1]
    block_q = _divisor_block(sq, block_q)
    block_k = _divisor_block(sk, block_k)
    nq, nk = sq // block_q, sk // block_k

    qg = q.reshape(b, nq, block_q, kheads, g, dh).astype(jnp.float32)
    kb = k.reshape(b, nk, block_k, kheads, dh).astype(jnp.float32)
    vb = v.reshape(b, nk, block_k, kheads, dv).astype(jnp.float32)

    q_pos_base = jnp.arange(block_q) + q_offset
    k_pos_base = jnp.arange(block_k)

    def q_block_step(_, qi):
        qblk = qg[:, qi]  # (B, bq, K, G, dh)
        q_pos = q_pos_base + qi * block_q

        def k_block_step(carry, ki):
            m, denom, acc = carry
            kblk, vblk = kb[:, ki], vb[:, ki]
            s = jnp.einsum("bqkgd,bskd->bkgqs", qblk, kblk) * scale
            if causal:
                k_pos = k_pos_base + ki * block_k
                visible = q_pos[:, None] >= k_pos[None, :]
                if prefix_len:
                    in_prefix = (q_pos[:, None] < prefix_len) & (
                        k_pos[None, :] < prefix_len
                    )
                    visible = visible | in_prefix
                s = jnp.where(visible, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            correction = jnp.exp(m - m_new)
            denom_new = denom * correction + jnp.sum(p, axis=-1)
            acc_new = acc * correction[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vblk
            )
            return (m_new, denom_new, acc_new), None

        m0 = jnp.full((b, kheads, g, block_q), NEG_INF, jnp.float32)
        denom0 = jnp.zeros((b, kheads, g, block_q), jnp.float32)
        acc0 = jnp.zeros((b, kheads, g, block_q, dv), jnp.float32)
        (m, denom, acc), _ = maybe_scan(
            k_block_step, (m0, denom0, acc0), jnp.arange(nk)
        )
        out = acc / jnp.maximum(denom, 1e-37)[..., None]  # (B,K,G,bq,dv)
        return None, out

    _, outs = maybe_scan(q_block_step, None, jnp.arange(nq))
    # outs: (nq, B, K, G, bq, dv) -> (B, Sq, H, dv)
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 4, 1, 2, 3, 5)
    out = out.reshape(b, nq, block_q, h, dv).reshape(b, sq, h, dv)
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,  # (B, 1, H, dh)
    k_cache: jax.Array,  # (B, S, K, dh)
    v_cache: jax.Array,  # (B, S, K, dv)
    positions: jax.Array,  # (B,) current token position per sequence
    scale: float,
) -> jax.Array:
    sk = k_cache.shape[1]
    valid = jnp.arange(sk)[None, :] <= positions[:, None]  # (B, S)
    mask = valid[:, None, None, None, :]  # (B, K, G, 1, S)
    return naive_attention(q, k_cache, v_cache, mask, scale)


def make_causal_mask(
    sq: int, sk: int, prefix_len: int = 0, q_offset: int = 0
) -> jax.Array:
    """``q_offset`` > 0 places the queries at global positions
    ``q_offset .. q_offset+sq`` over ``sk`` keys starting at position 0 —
    the suffix-prefill mask (queries see the whole cached prefix plus the
    causal part of their own block)."""
    q_pos = jnp.arange(sq)[:, None] + q_offset
    k_pos = jnp.arange(sk)[None, :]
    visible = q_pos >= k_pos
    if prefix_len:
        visible = visible | ((q_pos < prefix_len) & (k_pos < prefix_len))
    return visible  # (Sq, Sk)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv_heads: int
    head_dim: int


def gqa_specs(cfg: ModelConfig, cross: bool = False) -> dict:
    h, k, dh, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    bias = cfg.qkv_bias
    spec = {
        "wq": layers.dense_spec(d, h * dh, ("embed", "heads"), bias, "heads"),
        "wk": layers.dense_spec(d, k * dh, ("embed", "kv_heads"), bias, "kv_heads"),
        "wv": layers.dense_spec(d, k * dh, ("embed", "kv_heads"), bias, "kv_heads"),
        "wo": layers.dense_spec(h * dh, d, ("heads", "embed")),
    }
    if cfg.qk_norm and not cross:
        spec["q_norm"] = layers.rms_norm_spec(dh, None)
        spec["k_norm"] = layers.rms_norm_spec(dh, None)
    return spec


def gqa_project_kv(
    params: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array | None
) -> tuple[jax.Array, jax.Array]:
    """Project keys/values (used for self-attn and to build cross caches)."""
    k = _split_heads(layers.dense(params["wk"], x), cfg.n_kv_heads)
    v = _split_heads(layers.dense(params["wv"], x), cfg.n_kv_heads)
    if "k_norm" in params:
        k = layers.rms_norm(params["k_norm"], k, cfg.norm_eps)
    if cfg.pos_emb == "rope" and positions is not None:
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    return k, v


def gqa_project_q(
    params: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array | None
) -> jax.Array:
    q = _split_heads(layers.dense(params["wq"], x), cfg.n_heads)
    if "q_norm" in params:
        q = layers.rms_norm(params["q_norm"], q, cfg.norm_eps)
    if cfg.pos_emb == "rope" and positions is not None:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
    return sharding.constrain(q, ("batch", "seq", "heads", None))


def gqa_full(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    causal: bool,
    prefix_len: int = 0,
    impl: str = "chunked",
    kv: tuple[jax.Array, jax.Array] | None = None,
) -> jax.Array:
    """Full-sequence attention (train / prefill / encoder / cross)."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q = gqa_project_q(params, cfg, x, positions if cfg.pos_emb == "rope" else None)
    if kv is None:
        k, v = gqa_project_kv(
            params, cfg, x, positions if cfg.pos_emb == "rope" else None
        )
    else:
        k, v = kv
    scale = cfg.head_dim**-0.5
    if impl == "chunked" and s >= 512:
        out = chunked_attention(
            q, k, v, causal=causal, scale=scale, prefix_len=prefix_len
        )
    else:
        mask = None
        if causal:
            mask = make_causal_mask(s, k.shape[1], prefix_len)
        out = naive_attention(q, k, v, mask, scale)
    out = sharding.constrain(out, ("batch", "seq", "heads", None))
    return layers.dense(params["wo"], out.reshape(b, s, -1))


def gqa_suffix(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,      # (B, s, D) — hidden states of the prompt *suffix*
    k_ctx: jax.Array,  # (B, start+s, K, dh) — cached prefix ++ fresh suffix
    v_ctx: jax.Array,  # (B, start+s, K, dh)
    start: int,
) -> jax.Array:
    """Suffix prefill attention: queries at global positions
    ``start .. start+s`` attend over the full context ``[0, start+s)``.
    Because a transformer's suffix hidden states depend on the prefix only
    through the prefix KV, this reproduces what full prefill would compute
    for the same positions (DESIGN.md §8)."""
    b, s, _ = x.shape
    positions = jnp.arange(start, start + s)[None, :]
    q = gqa_project_q(params, cfg, x, positions if cfg.pos_emb == "rope" else None)
    mask = make_causal_mask(s, k_ctx.shape[1], q_offset=start)
    out = naive_attention(q, k_ctx, v_ctx, mask, cfg.head_dim**-0.5)
    out = sharding.constrain(out, ("batch", "seq", "heads", None))
    return layers.dense(params["wo"], out.reshape(b, s, -1))


def gqa_init_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype: Any = jnp.bfloat16
) -> dict:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    axes = ("batch", "cache_seq", "kv_heads", None)
    return {
        "k": ParamSpec(shape, dtype, axes, init="zeros"),
        "v": ParamSpec(shape, dtype, axes, init="zeros"),
    }


def cache_update(
    cache: jax.Array,  # (B, S, ...) — seq axis possibly sharded
    new: jax.Array,    # (B, 1, ...) values for the current position
    positions: jax.Array,  # (B,)
) -> jax.Array:
    """Write one token per row via a masked select instead of a scatter.

    A per-row scatter into a sequence-sharded cache forces the SPMD
    partitioner to all-gather the cache (observed: +43 GB/device on the
    110B decode cell); the one-hot select partitions elementwise and stays
    local under any sharding.
    """
    s = cache.shape[1]
    mask = jnp.arange(s)[None, :] == positions[:, None]  # (B, S)
    mask = mask.reshape(mask.shape + (1,) * (cache.ndim - 2))
    return jnp.where(mask, new.astype(cache.dtype), cache)


def gqa_decode(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, 1, D)
    cache: dict,  # {"k": (B,S,K,dh), "v": ...}
    positions: jax.Array,  # (B,)
) -> tuple[jax.Array, dict]:
    b = x.shape[0]
    pos2d = positions[:, None]  # (B,1) for rope on Sq=1
    q = gqa_project_q(params, cfg, x, pos2d if cfg.pos_emb == "rope" else None)
    k_new, v_new = gqa_project_kv(
        params, cfg, x, pos2d if cfg.pos_emb == "rope" else None
    )
    k_cache = cache_update(cache["k"], k_new, positions)
    v_cache = cache_update(cache["v"], v_new, positions)
    out = decode_attention(q, k_cache, v_cache, positions, cfg.head_dim**-0.5)
    out = layers.dense(params["wo"], out.reshape(b, 1, -1))
    return out, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (MiniCPM3 / DeepSeek-V2)
# ---------------------------------------------------------------------------


def mla_specs(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    nope, rope, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    qlr, kvlr = cfg.q_lora_rank, cfg.kv_lora_rank
    spec: dict = {}
    if qlr:
        spec["wq_a"] = layers.dense_spec(d, qlr, ("embed", None))
        spec["q_a_norm"] = layers.rms_norm_spec(qlr, None)
        spec["wq_b"] = layers.dense_spec(qlr, h * (nope + rope), (None, "heads"))
    else:
        spec["wq"] = layers.dense_spec(d, h * (nope + rope), ("embed", "heads"))
    spec["wkv_a"] = layers.dense_spec(d, kvlr + rope, ("embed", None))
    spec["kv_a_norm"] = layers.rms_norm_spec(kvlr, None)
    spec["wkv_b"] = layers.dense_spec(kvlr, h * (nope + dv), ("kv_lora", "heads"))
    spec["wo"] = layers.dense_spec(h * dv, d, ("heads", "embed"))
    return spec


def _mla_q(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.q_lora_rank:
        cq = layers.dense(params["wq_a"], x)
        cq = layers.rms_norm(params["q_a_norm"], cq, cfg.norm_eps)
        q = layers.dense(params["wq_b"], cq)
    else:
        q = layers.dense(params["wq"], x)
    return _split_heads(q, cfg.n_heads)  # (B,S,H,nope+rope)


def _mla_ckv(params: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array):
    ckv_full = layers.dense(params["wkv_a"], x)  # (B,S,kvlr+rope)
    c_kv, k_rope = jnp.split(ckv_full, [cfg.kv_lora_rank], axis=-1)
    c_kv = layers.rms_norm(params["kv_a_norm"], c_kv, cfg.norm_eps)
    k_rope = layers.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return c_kv, k_rope[:, :, 0, :]  # (B,S,kvlr), (B,S,rope)


def mla_full(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    causal: bool = True,
    impl: str = "chunked",
) -> jax.Array:
    """Naive (decompressed) MLA for train/prefill."""
    b, s, _ = x.shape
    nope, rope_d, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    positions = jnp.arange(s)[None, :]
    q = _mla_q(params, cfg, x)
    q_nope, q_rope = jnp.split(q, [nope], axis=-1)
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv, k_rope = _mla_ckv(params, cfg, x, positions)
    kv = layers.dense(params["wkv_b"], c_kv)  # (B,S,H*(nope+dv))
    kv = _split_heads(kv, cfg.n_heads)
    k_nope, v = jnp.split(kv, [nope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, cfg.n_heads, rope_d))],
        axis=-1,
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = (nope + rope_d) ** -0.5
    if impl == "chunked" and s >= 512:
        out = chunked_attention(q, k, v, causal=causal, scale=scale)
    else:
        mask = make_causal_mask(s, s) if causal else None
        out = naive_attention(q, k, v, mask, scale)
    return layers.dense(params["wo"], out.reshape(b, s, -1))


def mla_init_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype: Any = jnp.bfloat16
) -> dict:
    return {
        "c_kv": ParamSpec(
            (batch, max_len, cfg.kv_lora_rank),
            dtype,
            ("batch", "cache_seq", None),
            init="zeros",
        ),
        "k_rope": ParamSpec(
            (batch, max_len, cfg.qk_rope_head_dim),
            dtype,
            ("batch", "cache_seq", None),
            init="zeros",
        ),
    }


def mla_decode(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B,1,D)
    cache: dict,  # {"c_kv": (B,S,kvlr), "k_rope": (B,S,rope)}
    positions: jax.Array,  # (B,)
) -> tuple[jax.Array, dict]:
    """Absorbed-matmul MLA decode: the cache stays compressed (the point of
    MLA — (kv_lora + rope) bytes/token instead of 2·H·dh)."""
    b = x.shape[0]
    h, nope, rope_d = cfg.n_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    dv, kvlr = cfg.v_head_dim, cfg.kv_lora_rank
    pos2d = positions[:, None]

    q = _mla_q(params, cfg, x)  # (B,1,H,nope+rope)
    q_nope, q_rope = jnp.split(q, [nope], axis=-1)
    q_rope = layers.apply_rope(q_rope, pos2d, cfg.rope_theta)

    c_kv_new, k_rope_new = _mla_ckv(params, cfg, x, pos2d)
    c_kv = cache_update(cache["c_kv"], c_kv_new, positions)
    k_rope = cache_update(cache["k_rope"], k_rope_new, positions)

    w_kv_b = params["wkv_b"]["kernel"].reshape(kvlr, h, nope + dv)
    w_uk = w_kv_b[:, :, :nope]  # (kvlr, H, nope)
    w_uv = w_kv_b[:, :, nope:]  # (kvlr, H, dv)

    q_lat = jnp.einsum("bqhn,lhn->bqhl", q_nope.astype(jnp.float32), w_uk)
    scores = jnp.einsum(
        "bqhl,bsl->bhqs", q_lat, c_kv.astype(jnp.float32)
    ) + jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                   k_rope.astype(jnp.float32))
    scores = scores * ((nope + rope_d) ** -0.5)
    valid = (jnp.arange(c_kv.shape[1])[None, :] <= positions[:, None])
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhqs,bsl->bqhl", probs, c_kv.astype(jnp.float32))
    out = jnp.einsum("bqhl,lhv->bqhv", o_lat, w_uv).astype(x.dtype)
    out = layers.dense(params["wo"], out.reshape(b, 1, -1))
    return out, {"c_kv": c_kv, "k_rope": k_rope}
