"""Shared neural-net layers: norms, RoPE, MLPs, embeddings.

All layers are pure functions over explicit parameter pytrees declared via
:class:`repro.models.params.ParamSpec`.  Logical sharding axes ride on the
specs; activation constraints go through :func:`repro.sharding.constrain`.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro import sharding
from repro.models.params import ParamSpec

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm_spec(dim: int, axis: str | None = "embed") -> dict[str, ParamSpec]:
    return {"scale": ParamSpec((dim,), jnp.float32, (axis,), init="ones")}


def rms_norm(params: dict, x: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


def layer_norm_spec(dim: int, axis: str | None = "embed") -> dict[str, ParamSpec]:
    return {
        "scale": ParamSpec((dim,), jnp.float32, (axis,), init="ones"),
        "bias": ParamSpec((dim,), jnp.float32, (axis,), init="zeros"),
    }


def layer_norm(params: dict, x: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    out = x * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding (NeoX half-rotation convention)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies, f32."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float
) -> jax.Array:
    """Rotate ``x`` (..., seq, heads, head_dim) by ``positions`` (..., seq)."""
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)
    angles = positions[..., :, None].astype(jnp.float32) * inv_freq  # (..., S, hd/2)
    angles = angles[..., None, :]  # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate((x1 * cos - x2 * sin, x2 * cos + x1 * sin), axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------


def dense_spec(
    d_in: int,
    d_out: int,
    axes: tuple[str | None, str | None],
    bias: bool = False,
    bias_axis: str | None = None,
    scale: float = 1.0,
) -> dict[str, ParamSpec]:
    spec = {"kernel": ParamSpec((d_in, d_out), jnp.float32, axes, scale=scale)}
    if bias:
        spec["bias"] = ParamSpec((d_out,), jnp.float32, (bias_axis,), init="zeros")
    return spec


def dense(params: dict, x: jax.Array, compute_dtype: Any = None) -> jax.Array:
    dtype = compute_dtype or x.dtype
    y = jnp.einsum(
        "...d,df->...f", x.astype(dtype), params["kernel"].astype(dtype)
    )
    if "bias" in params:
        y = y + params["bias"].astype(dtype)
    return y


def activation(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(f"unknown activation {name!r}")


def gated_mlp_spec(d_model: int, d_ff: int) -> dict:
    """SwiGLU/GeGLU MLP (llama/qwen/gemma style)."""
    return {
        "wi": dense_spec(d_model, d_ff, ("embed", "mlp")),
        "wg": dense_spec(d_model, d_ff, ("embed", "mlp")),
        "wo": dense_spec(d_ff, d_model, ("mlp", "embed")),
    }


def gated_mlp(params: dict, x: jax.Array, act: str) -> jax.Array:
    h = activation(act, dense(params["wg"], x)) * dense(params["wi"], x)
    h = sharding.constrain(h, ("batch", "seq", "mlp"))
    return dense(params["wo"], h)


def mlp_spec(d_model: int, d_ff: int, bias: bool = False) -> dict:
    """Plain 2-layer MLP (whisper style)."""
    return {
        "wi": dense_spec(d_model, d_ff, ("embed", "mlp"), bias=bias, bias_axis="mlp"),
        "wo": dense_spec(d_ff, d_model, ("mlp", "embed"), bias=bias, bias_axis="embed"),
    }


def mlp(params: dict, x: jax.Array, act: str) -> jax.Array:
    h = activation(act, dense(params["wi"], x))
    h = sharding.constrain(h, ("batch", "seq", "mlp"))
    return dense(params["wo"], h)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def embedding_spec(vocab: int, d_model: int) -> dict[str, ParamSpec]:
    return {
        "table": ParamSpec((vocab, d_model), jnp.float32, ("vocab", "embed"), scale=1.0)
    }


def embed(params: dict, tokens: jax.Array, compute_dtype: Any) -> jax.Array:
    table = params["table"].astype(compute_dtype)
    return jnp.take(table, tokens, axis=0)


def unembed(params: dict, x: jax.Array, compute_dtype: Any) -> jax.Array:
    """Project to (padded) vocab logits; returns f32 for a stable softmax."""
    table = params["table"].astype(compute_dtype)
    logits = jnp.einsum("...d,vd->...v", x.astype(compute_dtype), table)
    return sharding.constrain(logits.astype(jnp.float32), ("batch", "seq", "vocab"))


def learned_pos_spec(max_len: int, d_model: int) -> dict[str, ParamSpec]:
    return {"table": ParamSpec((max_len, d_model), jnp.float32, (None, "embed"))}
