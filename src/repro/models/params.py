"""Parameter-spec machinery.

Models declare an *abstract* parameter tree of :class:`ParamSpec` leaves.
From that single declaration we derive:

* ``init_params``      — real arrays (smoke tests / examples),
* ``shape_structs``    — ``jax.ShapeDtypeStruct`` stand-ins (the multi-pod
  dry-run lowers against these; a 236B-param model never allocates),
* ``partition_specs``  — ``PartitionSpec`` tree from logical-axis names via
  the sharding rule table in :mod:`repro.sharding`.

This mirrors how production frameworks (MaxText, T5X) separate the logical
model definition from physical placement.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Abstract description of one parameter tensor."""

    shape: tuple[int, ...]
    dtype: Any = jnp.float32
    #: logical axis names, same length as ``shape``; ``None`` = unsharded axis.
    axes: tuple[str | None, ...] = ()
    #: "normal" (fan-in scaled), "zeros", "ones".
    init: str = "normal"
    #: multiplier on the init scale (e.g. depth-scaled residual inits).
    scale: float = 1.0

    def __post_init__(self) -> None:
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} rank does not match shape {self.shape}"
            )

    @property
    def size(self) -> int:
        return math.prod(self.shape)


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def _init_one(key: jax.Array, spec: ParamSpec) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "normal":
        # Fan-in scaling: last-but-one axis is the contraction axis by
        # convention (kernels are stored (in, out)).
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale / math.sqrt(max(fan_in, 1))
        return (std * jax.random.normal(key, spec.shape)).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def init_params(key: jax.Array, specs: PyTree) -> PyTree:
    """Materialize real parameters for a spec tree (small configs only)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    arrays = [_init_one(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrays)


def shape_structs(specs: PyTree) -> PyTree:
    """ShapeDtypeStruct stand-ins — zero allocation, used by the dry-run."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=is_spec
    )


def logical_axes(specs: PyTree) -> PyTree:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def param_count(specs: PyTree) -> int:
    return sum(s.size for s in jax.tree.leaves(specs, is_leaf=is_spec))


def param_bytes(specs: PyTree) -> int:
    return sum(
        s.size * jnp.dtype(s.dtype).itemsize
        for s in jax.tree.leaves(specs, is_leaf=is_spec)
    )


def cast_specs(specs: PyTree, dtype: Any) -> PyTree:
    """Return a spec tree with every leaf re-typed (e.g. bf16 inference)."""
    return jax.tree.map(
        lambda s: dataclasses.replace(s, dtype=dtype), specs, is_leaf=is_spec
    )


def map_with_path(
    fn: Callable[[tuple[str, ...], ParamSpec], Any], specs: PyTree
) -> PyTree:
    """tree-map with the dict path (useful for naming / filtering)."""

    def walk(node: PyTree, path: tuple[str, ...]) -> PyTree:
        if is_spec(node):
            return fn(path, node)
        if isinstance(node, dict):
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        raise TypeError(f"unexpected node at {path}: {type(node)}")

    return walk(specs, ())


def summarize(specs: PyTree) -> str:
    """Human-readable parameter inventory."""
    lines: list[str] = []

    def fmt(path: tuple[str, ...], s: ParamSpec) -> ParamSpec:
        lines.append(
            f"{'/'.join(path):60s} {str(s.shape):28s} {np.dtype(s.dtype).name:10s}"
            f" {s.size:,}"
        )
        return s

    map_with_path(fmt, specs)
    lines.append(f"TOTAL params: {param_count(specs):,}")
    return "\n".join(lines)
