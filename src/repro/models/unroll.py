"""Scan/unroll switch for the dry-run accounting pass.

XLA's ``HloCostAnalysis`` counts a while-loop body ONCE regardless of trip
count, so FLOPs / bytes / collective traffic inside ``jax.lax.scan`` are
invisible to ``compiled.cost_analysis()``.  The dry-run therefore lowers an
*accounting* variant with every scan fully unrolled (at reduced sequence
lengths — see ``repro.launch.accounting``).  Model code routes every scan
through :func:`maybe_scan`, which unrolls when the context flag is active.
"""

from __future__ import annotations

from typing import Any

import jax

_UNROLL: list[bool] = [False]


class unroll_scans:
    """Context manager: fully unroll every ``maybe_scan`` inside."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._prev = False

    def __enter__(self) -> None:
        self._prev = _UNROLL[0]
        _UNROLL[0] = self.enabled

    def __exit__(self, *exc: Any) -> None:
        _UNROLL[0] = self._prev


def unrolling() -> bool:
    return _UNROLL[0]


def maybe_scan(body, init, xs, *, length: int | None = None):
    """``jax.lax.scan`` that fully unrolls under :class:`unroll_scans`."""
    return jax.lax.scan(
        body, init, xs, length=length, unroll=True if _UNROLL[0] else 1
    )
