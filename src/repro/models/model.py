"""Model assemblies for all six assigned families.

Every family exposes the same surface (duck-typed; see :func:`build_model`):

* ``param_specs()``                   — abstract parameter tree (ParamSpec).
* ``forward(params, batch, ...)``     — full-sequence logits (train path).
* ``cache_specs(batch, max_len)``     — decode-cache ParamSpec tree.
* ``prefill(params, batch, cache)``   — fill cache, return last-pos logits.
* ``decode_step(params, tokens, cache, positions)`` — one decode token.

Layers are **stacked + scanned** (MaxText-style): one ParamSpec per layer
stack with a leading ``layers`` axis, ``jax.lax.scan`` over the stack.  This
keeps HLO size O(1) in depth, which is what makes 512-way SPMD lowering of an
80-layer model tractable.  Activation remat wraps the scan body.

Batch dict convention (all optional except ``tokens``):
  ``tokens``   (B, S) int32   — text tokens
  ``frames``   (B, Se, D)     — whisper: precomputed mel/conv frame embeddings
  ``patches``  (B, Nv, D)     — paligemma: precomputed SigLIP patch embeddings
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers, moe, ssm
from repro.models.params import ParamSpec, is_spec
from repro.models.unroll import maybe_scan

PyTree = Any

# decoder positional table sized from the assigned shape grid (DESIGN.md §4.1)
MAX_LEARNED_POS = 32_768


def stack_specs(spec_tree: PyTree, n: int) -> PyTree:
    """Prepend a ``layers`` axis of size ``n`` to every leaf spec."""
    return jax.tree.map(
        lambda s: ParamSpec(
            (n,) + s.shape, s.dtype, ("layers",) + tuple(s.axes), s.init, s.scale
        ),
        spec_tree,
        is_leaf=is_spec,
    )


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    raise ValueError(f"unknown remat policy {policy!r}")


# ===========================================================================
# Transformer block (dense / MoE / VLM families)
# ===========================================================================


def tblock_specs(cfg: ModelConfig, mlp_kind: str, dense_ff: int = 0) -> dict:
    d = cfg.d_model
    spec: dict = {
        "ln1": layers.rms_norm_spec(d),
        "attn": attn.mla_specs(cfg) if cfg.use_mla else attn.gqa_specs(cfg),
        "ln2": layers.rms_norm_spec(d),
    }
    if mlp_kind == "dense":
        spec["mlp"] = layers.gated_mlp_spec(d, dense_ff or cfg.d_ff)
    elif mlp_kind == "moe":
        spec["moe"] = moe.moe_specs(cfg)
    else:
        raise ValueError(mlp_kind)
    return spec


def tblock_fwd(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    causal: bool = True,
    prefix_len: int = 0,
    impl: str = "chunked",
) -> tuple[jax.Array, jax.Array]:
    h = layers.rms_norm(params["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        a = attn.mla_full(params["attn"], cfg, h, causal=causal, impl=impl)
    else:
        a = attn.gqa_full(
            params["attn"], cfg, h, causal=causal, prefix_len=prefix_len, impl=impl
        )
    x = x + a
    h = layers.rms_norm(params["ln2"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in params:
        y, aux = moe.moe_block(params["moe"], cfg, h)
    else:
        y = layers.gated_mlp(params["mlp"], h, cfg.act)
    x = x + y
    return sharding.constrain(x, ("batch", "seq", "embed")), aux


def tblock_cache_specs(
    cfg: ModelConfig, batch: int, max_len: int, dtype: Any = jnp.bfloat16
) -> dict:
    if cfg.use_mla:
        return attn.mla_init_cache(cfg, batch, max_len, dtype)
    return attn.gqa_init_cache(cfg, batch, max_len, dtype)


def _fill(cache: jax.Array, new: jax.Array, start: int = 0) -> jax.Array:
    """Write the prompt's projected values into the cache at ``start``.

    When the prompt covers the whole cache the update is a plain cast —
    avoiding a dynamic-update-slice the SPMD partitioner would otherwise
    service with an involuntary full rematerialization (observed on the
    MQA kv=1 prefill cells)."""
    s = new.shape[1]
    if start == 0 and s == cache.shape[1]:
        return new.astype(cache.dtype)
    return cache.at[:, start : start + s].set(new.astype(cache.dtype))


def tblock_prefill(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    cache: dict,
    *,
    prefix_len: int = 0,
    impl: str = "chunked",
    start: int = 0,
) -> tuple[jax.Array, dict, jax.Array]:
    """Forward + cache fill (inference prefill).

    ``start`` > 0 is *suffix prefill*: ``x`` holds positions
    ``start .. start+s`` of the prompt and ``cache`` already contains the
    first ``start`` positions' KV (gathered from shared prefix pages);
    only GQA attention supports it."""
    b, s, _ = x.shape
    positions = jnp.arange(start, start + s)[None, :]
    h = layers.rms_norm(params["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        if start:
            raise ValueError("suffix prefill is not supported for MLA caches")
        c_kv, k_rope = attn._mla_ckv(params["attn"], cfg, h, positions)
        cache = {
            "c_kv": _fill(cache["c_kv"], c_kv),
            "k_rope": _fill(cache["k_rope"], k_rope),
        }
        a = attn.mla_full(params["attn"], cfg, h, causal=True, impl=impl)
    else:
        rope_pos = positions if cfg.pos_emb == "rope" else None
        k, v = attn.gqa_project_kv(params["attn"], cfg, h, rope_pos)
        cache = {
            "k": _fill(cache["k"], k, start),
            "v": _fill(cache["v"], v, start),
        }
        if start:
            if prefix_len:
                raise ValueError(
                    "suffix prefill cannot combine with a prefix-LM mask"
                )
            # cache stores post-rope keys, so prefix ++ fresh-suffix concat
            # is position-consistent; the round-trip through the cache dtype
            # is exact (values originate in the compute dtype)
            k_ctx = jnp.concatenate([cache["k"][:, :start].astype(k.dtype), k], 1)
            v_ctx = jnp.concatenate([cache["v"][:, :start].astype(v.dtype), v], 1)
            a = attn.gqa_suffix(params["attn"], cfg, h, k_ctx, v_ctx, start)
        else:
            a = attn.gqa_full(
                params["attn"], cfg, h, causal=True, prefix_len=prefix_len,
                impl=impl, kv=(k, v),
            )
    x = x + a
    h = layers.rms_norm(params["ln2"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in params:
        y, aux = moe.moe_block(params["moe"], cfg, h)
    else:
        y = layers.gated_mlp(params["mlp"], h, cfg.act)
    return x + y, cache, aux


def tblock_decode(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    cache: dict,
    positions: jax.Array,
) -> tuple[jax.Array, dict]:
    h = layers.rms_norm(params["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        a, cache = attn.mla_decode(params["attn"], cfg, h, cache, positions)
    else:
        a, cache = attn.gqa_decode(params["attn"], cfg, h, cache, positions)
    x = x + a
    h = layers.rms_norm(params["ln2"], x, cfg.norm_eps)
    if "moe" in params:
        y, _ = moe.moe_block(params["moe"], cfg, h)
    else:
        y = layers.gated_mlp(params["mlp"], h, cfg.act)
    return x + y, cache


# ===========================================================================
# TransformerLM — dense, MoE and VLM families
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class TransformerLM:
    cfg: ModelConfig
    remat: str = "dots"
    attn_impl: str = "chunked"

    # -- specs ---------------------------------------------------------------

    @property
    def _n_moe_layers(self) -> int:
        return self.cfg.n_layers - self.cfg.first_k_dense if self.cfg.n_experts else 0

    @property
    def _n_dense_layers(self) -> int:
        return self.cfg.n_layers - self._n_moe_layers

    def param_specs(self) -> dict:
        cfg = self.cfg
        spec: dict = {
            "embed": layers.embedding_spec(cfg.padded_vocab, cfg.d_model),
            "final_norm": layers.rms_norm_spec(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            spec["unembed"] = layers.dense_spec(
                cfg.d_model, cfg.padded_vocab, ("embed", "vocab")
            )
        if self._n_dense_layers:
            spec["dense_layers"] = stack_specs(
                tblock_specs(cfg, "dense", cfg.dense_d_ff or cfg.d_ff),
                self._n_dense_layers,
            )
        if self._n_moe_layers:
            spec["moe_layers"] = stack_specs(
                tblock_specs(cfg, "moe"), self._n_moe_layers
            )
        return spec

    # -- embedding helpers ----------------------------------------------------

    def _embed_tokens(self, params: dict, tokens: jax.Array, dtype) -> jax.Array:
        x = layers.embed(params["embed"], tokens, dtype)
        if self.cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(self.cfg.d_model), dtype)
        return sharding.constrain(x, ("batch", "seq", "embed"))

    def _embed_inputs(self, params: dict, batch: dict, dtype) -> tuple[jax.Array, int]:
        """Token (+ vision) embeddings; returns (x, prefix_len)."""
        x = self._embed_tokens(params, batch["tokens"], dtype)
        prefix_len = 0
        if self.cfg.family == "vlm":
            patches = batch["patches"].astype(dtype)
            x = jnp.concatenate([patches, x], axis=1)
            prefix_len = patches.shape[1]
        return x, prefix_len

    def _unembed(self, params: dict, x: jax.Array, dtype) -> jax.Array:
        if self.cfg.tie_embeddings:
            return layers.unembed(params["embed"], x, dtype)
        logits = layers.dense(params["unembed"], x.astype(dtype))
        return sharding.constrain(
            logits.astype(jnp.float32), ("batch", "seq", "vocab")
        )

    # -- scan plumbing ---------------------------------------------------------

    def _scan_stack(self, stack_params, x, body):
        def scan_body(carry, p_layer):
            h, aux = carry
            h, aux_l = body(p_layer, h)
            return (h, aux + aux_l), None

        (x, aux), _ = maybe_scan(
            scan_body, (x, jnp.zeros((), jnp.float32)), stack_params
        )
        return x, aux

    # -- public API -------------------------------------------------------------

    def forward(
        self,
        params: dict,
        batch: dict,
        *,
        dtype: Any = jnp.bfloat16,
        return_hidden: bool = False,
    ) -> tuple[jax.Array, jax.Array]:
        """Full-sequence forward.  Returns (logits_f32, aux_loss)."""
        cfg = self.cfg
        x, prefix_len = self._embed_inputs(params, batch, dtype)

        body = _remat(
            lambda p, h: tblock_fwd(
                p, cfg, h, causal=True, prefix_len=prefix_len, impl=self.attn_impl
            ),
            self.remat,
        )
        aux = jnp.zeros((), jnp.float32)
        if "dense_layers" in params:
            x, a = self._scan_stack(params["dense_layers"], x, body)
            aux = aux + a
        if "moe_layers" in params:
            x, a = self._scan_stack(params["moe_layers"], x, body)
            aux = aux + a

        x = layers.rms_norm(params["final_norm"], x, cfg.norm_eps)
        if return_hidden:
            return x, aux
        return self._unembed(params, x, dtype), aux

    def cache_specs(
        self, batch: int, max_len: int, dtype: Any = jnp.bfloat16
    ) -> dict:
        per_layer = lambda n: stack_specs(
            tblock_cache_specs(self.cfg, batch, max_len, dtype), n
        )
        out: dict = {}
        if self._n_dense_layers:
            out["dense_layers"] = per_layer(self._n_dense_layers)
        if self._n_moe_layers:
            out["moe_layers"] = per_layer(self._n_moe_layers)
        return out

    def prefill(
        self,
        params: dict,
        batch: dict,
        cache: dict,
        *,
        dtype: Any = jnp.bfloat16,
        start: int = 0,
    ) -> tuple[jax.Array, dict]:
        """Run the prompt, fill the cache, return last-position logits.

        ``start`` > 0 runs *suffix prefill*: ``batch["tokens"]`` holds only
        the prompt suffix from position ``start`` on, and ``cache`` must
        already hold the first ``start`` positions' KV."""
        cfg = self.cfg
        if start and cfg.family == "vlm":
            raise ValueError("suffix prefill is not supported for VLM prompts")
        x, prefix_len = self._embed_inputs(params, batch, dtype)
        new_cache: dict = {}

        def run(stack_key: str, x):
            def scan_body(h, pc):
                p_layer, c_layer = pc
                h, c_layer, _ = tblock_prefill(
                    p_layer, cfg, h, c_layer, prefix_len=prefix_len,
                    impl=self.attn_impl, start=start,
                )
                return h, c_layer

            x, cs = maybe_scan(scan_body, x, (params[stack_key], cache[stack_key]))
            new_cache[stack_key] = cs
            return x

        if "dense_layers" in params:
            x = run("dense_layers", x)
        if "moe_layers" in params:
            x = run("moe_layers", x)

        x = layers.rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = self._unembed(params, x[:, -1:], dtype)
        return logits[:, 0], new_cache

    def decode_step(
        self,
        params: dict,
        tokens: jax.Array,  # (B, 1)
        cache: dict,
        positions: jax.Array,  # (B,) position of the new token
        *,
        dtype: Any = jnp.bfloat16,
    ) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        x = self._embed_tokens(params, tokens, dtype)
        new_cache: dict = {}

        def run(stack_key: str, x):
            def scan_body(h, pc):
                p_layer, c_layer = pc
                h, c_layer = tblock_decode(p_layer, cfg, h, c_layer, positions)
                return h, c_layer

            x, cs = maybe_scan(scan_body, x, (params[stack_key], cache[stack_key]))
            new_cache[stack_key] = cs
            return x

        if "dense_layers" in params:
            x = run("dense_layers", x)
        if "moe_layers" in params:
            x = run("moe_layers", x)

        x = layers.rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = self._unembed(params, x, dtype)
        return logits[:, 0], new_cache


# ===========================================================================
# MambaLM — pure SSM family
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class MambaLM:
    cfg: ModelConfig
    remat: str = "dots"

    def param_specs(self) -> dict:
        cfg = self.cfg
        block = {
            "ln": layers.rms_norm_spec(cfg.d_model),
            "mixer": ssm.mamba2_specs(cfg),
        }
        return {
            "embed": layers.embedding_spec(cfg.padded_vocab, cfg.d_model),
            "layers": stack_specs(block, cfg.n_layers),
            "final_norm": layers.rms_norm_spec(cfg.d_model),
        }

    def forward(
        self,
        params: dict,
        batch: dict,
        *,
        dtype: Any = jnp.bfloat16,
        return_hidden: bool = False,
    ) -> tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        x = layers.embed(params["embed"], batch["tokens"], dtype)
        x = sharding.constrain(x, ("batch", "seq", "embed"))

        def block(p, h):
            y = ssm.mamba2_full(
                p["mixer"], cfg, layers.rms_norm(p["ln"], h, cfg.norm_eps)
            )
            return h + y

        body = _remat(block, self.remat)

        def scan_body(h, p_layer):
            return body(p_layer, h), None

        x, _ = maybe_scan(scan_body, x, params["layers"])
        x = layers.rms_norm(params["final_norm"], x, cfg.norm_eps)
        if return_hidden:
            return x, jnp.zeros((), jnp.float32)
        logits = layers.unembed(params["embed"], x, dtype)
        return logits, jnp.zeros((), jnp.float32)

    def cache_specs(
        self, batch: int, max_len: int = 0, dtype: Any = jnp.float32
    ) -> dict:
        del max_len  # O(1) state: SSM caches carry no sequence axis
        return {
            "layers": stack_specs(
                ssm.mamba2_init_cache(self.cfg, batch, dtype), self.cfg.n_layers
            )
        }

    def prefill(
        self, params: dict, batch: dict, cache: dict, *, dtype: Any = jnp.bfloat16
    ) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        tokens = batch["tokens"]
        x = layers.embed(params["embed"], tokens, dtype)

        def scan_body(h, pc):
            p, c = pc
            normed = layers.rms_norm(p["ln"], h, cfg.norm_eps)
            y, new_c = ssm.mamba2_prefill(p["mixer"], cfg, normed, c)
            return h + y, new_c

        x, cs = maybe_scan(scan_body, x, (params["layers"], cache["layers"]))
        x = layers.rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = layers.unembed(params["embed"], x[:, -1:], dtype)
        return logits[:, 0], {"layers": cs}

    def decode_step(
        self,
        params: dict,
        tokens: jax.Array,
        cache: dict,
        positions: jax.Array,
        *,
        dtype: Any = jnp.bfloat16,
    ) -> tuple[jax.Array, dict]:
        del positions  # SSM decode is position-free
        cfg = self.cfg
        x = layers.embed(params["embed"], tokens, dtype)

        def scan_body(h, pc):
            p, c = pc
            normed = layers.rms_norm(p["ln"], h, cfg.norm_eps)
            y, new_c = ssm.mamba2_decode(p["mixer"], cfg, normed, c)
            return h + y, new_c

        x, cs = maybe_scan(scan_body, x, (params["layers"], cache["layers"]))
        x = layers.rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = layers.unembed(params["embed"], x, dtype)
        return logits[:, 0], {"layers": cs}


# ===========================================================================
# HybridLM — zamba2: Mamba2 backbone + shared attention block
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class HybridLM:
    """``n_layers`` Mamba2 blocks; one *shared* transformer block applied
    after every ``shared_attn_every``-th layer with per-application norm
    gains (DESIGN.md §4.1)."""

    cfg: ModelConfig
    remat: str = "dots"
    attn_impl: str = "chunked"

    @property
    def n_groups(self) -> int:
        return self.cfg.n_layers // self.cfg.shared_attn_every

    @property
    def n_tail(self) -> int:
        return self.cfg.n_layers - self.n_groups * self.cfg.shared_attn_every

    def _mamba_block_spec(self) -> dict:
        return {
            "ln": layers.rms_norm_spec(self.cfg.d_model),
            "mixer": ssm.mamba2_specs(self.cfg),
        }

    def param_specs(self) -> dict:
        cfg = self.cfg
        g, k = self.n_groups, cfg.shared_attn_every
        spec: dict = {
            "embed": layers.embedding_spec(cfg.padded_vocab, cfg.d_model),
            # (G, K, ...) grouped mamba stacks
            "groups": stack_specs(stack_specs(self._mamba_block_spec(), k), g),
            "shared_attn": tblock_specs(cfg, "dense"),
            # per-application input norm for the shared block
            "app_norms": stack_specs(layers.rms_norm_spec(cfg.d_model), g),
            "final_norm": layers.rms_norm_spec(cfg.d_model),
        }
        if self.n_tail:
            spec["tail"] = stack_specs(self._mamba_block_spec(), self.n_tail)
        return spec

    def _mamba_fwd(self, p, h):
        y = ssm.mamba2_full(
            p["mixer"], self.cfg, layers.rms_norm(p["ln"], h, self.cfg.norm_eps)
        )
        return h + y

    def forward(
        self,
        params: dict,
        batch: dict,
        *,
        dtype: Any = jnp.bfloat16,
        return_hidden: bool = False,
    ) -> tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        x = layers.embed(params["embed"], batch["tokens"], dtype)
        x = sharding.constrain(x, ("batch", "seq", "embed"))
        mamba_body = _remat(self._mamba_fwd, self.remat)

        shared = params["shared_attn"]

        def attn_app(app_norm, h):
            normed = layers.rms_norm(app_norm, h, cfg.norm_eps)
            out, _ = tblock_fwd(shared, cfg, normed, causal=True, impl=self.attn_impl)
            return h + (out - normed)  # residual around the shared block

        attn_body = _remat(attn_app, self.remat)

        def group_body(h, group):
            p_stack, app_norm = group

            def inner(h2, p_layer):
                return mamba_body(p_layer, h2), None

            h, _ = maybe_scan(inner, h, p_stack)
            h = attn_body(app_norm, h)
            return h, None

        x, _ = maybe_scan(group_body, x, (params["groups"], params["app_norms"]))
        if self.n_tail:
            def inner(h2, p_layer):
                return mamba_body(p_layer, h2), None

            x, _ = maybe_scan(inner, x, params["tail"])

        x = layers.rms_norm(params["final_norm"], x, cfg.norm_eps)
        if return_hidden:
            return x, jnp.zeros((), jnp.float32)
        logits = layers.unembed(params["embed"], x, dtype)
        return logits, jnp.zeros((), jnp.float32)

    def cache_specs(
        self, batch: int, max_len: int, dtype: Any = jnp.bfloat16
    ) -> dict:
        cfg = self.cfg
        g, k = self.n_groups, cfg.shared_attn_every
        mamba_cache = ssm.mamba2_init_cache(cfg, batch, jnp.float32)
        out: dict = {
            "groups": stack_specs(stack_specs(mamba_cache, k), g),
            # one KV cache per shared-attn application
            "attn": stack_specs(
                attn.gqa_init_cache(cfg, batch, max_len, dtype), g
            ),
        }
        if self.n_tail:
            out["tail"] = stack_specs(mamba_cache, self.n_tail)
        return out

    def prefill(
        self, params: dict, batch: dict, cache: dict, *, dtype: Any = jnp.bfloat16
    ) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        x = layers.embed(params["embed"], batch["tokens"], dtype)
        shared = params["shared_attn"]

        def group_body(h, xs):
            p_stack, app_norm, m_cache, a_cache = xs

            def inner(h2, pc):
                p, c = pc
                normed = layers.rms_norm(p["ln"], h2, cfg.norm_eps)
                y, new_c = ssm.mamba2_prefill(p["mixer"], cfg, normed, c)
                return h2 + y, new_c

            h, new_m = maybe_scan(inner, h, (p_stack, m_cache))
            normed = layers.rms_norm(app_norm, h, cfg.norm_eps)
            out, new_a, _ = tblock_prefill(
                shared, cfg, normed, a_cache, impl=self.attn_impl
            )
            h = h + (out - normed)
            return h, (new_m, new_a)

        x, (new_groups, new_attn) = maybe_scan(
            group_body,
            x,
            (params["groups"], params["app_norms"], cache["groups"], cache["attn"]),
        )
        new_cache: dict = {"groups": new_groups, "attn": new_attn}
        if self.n_tail:
            def inner(h2, pc):
                p, c = pc
                normed = layers.rms_norm(p["ln"], h2, cfg.norm_eps)
                y, new_c = ssm.mamba2_prefill(p["mixer"], cfg, normed, c)
                return h2 + y, new_c

            x, new_tail = maybe_scan(inner, x, (params["tail"], cache["tail"]))
            new_cache["tail"] = new_tail

        x = layers.rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = layers.unembed(params["embed"], x[:, -1:], dtype)
        return logits[:, 0], new_cache

    def decode_step(
        self,
        params: dict,
        tokens: jax.Array,
        cache: dict,
        positions: jax.Array,
        *,
        dtype: Any = jnp.bfloat16,
    ) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        x = layers.embed(params["embed"], tokens, dtype)
        shared = params["shared_attn"]

        def group_body(h, xs):
            p_stack, app_norm, m_cache, a_cache = xs

            def inner(h2, pc):
                p, c = pc
                normed = layers.rms_norm(p["ln"], h2, cfg.norm_eps)
                y, new_c = ssm.mamba2_decode(p["mixer"], cfg, normed, c)
                return h2 + y, new_c

            h, new_m = maybe_scan(inner, h, (p_stack, m_cache))
            normed = layers.rms_norm(app_norm, h, cfg.norm_eps)
            out, new_a = tblock_decode(shared, cfg, normed, a_cache, positions)
            h = h + (out - normed)
            return h, (new_m, new_a)

        x, (new_groups, new_attn) = maybe_scan(
            group_body,
            x,
            (params["groups"], params["app_norms"], cache["groups"], cache["attn"]),
        )
        new_cache: dict = {"groups": new_groups, "attn": new_attn}
        if self.n_tail:
            def inner(h2, pc):
                p, c = pc
                normed = layers.rms_norm(p["ln"], h2, cfg.norm_eps)
                y, new_c = ssm.mamba2_decode(p["mixer"], cfg, normed, c)
                return h2 + y, new_c

            x, new_tail = maybe_scan(inner, x, (params["tail"], cache["tail"]))
            new_cache["tail"] = new_tail

        x = layers.rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = layers.unembed(params["embed"], x, dtype)
        return logits[:, 0], new_cache


# ===========================================================================
# EncDecLM — whisper: encoder over frame embeddings + causal decoder w/ cross
# ===========================================================================


def _eblock_specs(cfg: ModelConfig, cross: bool) -> dict:
    d = cfg.d_model
    spec = {
        "ln1": layers.layer_norm_spec(d),
        "attn": attn.gqa_specs(cfg),
        "ln2": layers.layer_norm_spec(d),
        "mlp": layers.mlp_spec(d, cfg.d_ff, bias=True),
    }
    if cross:
        spec["ln_cross"] = layers.layer_norm_spec(d)
        spec["cross"] = attn.gqa_specs(cfg, cross=True)
    return spec


@dataclasses.dataclass(frozen=True)
class EncDecLM:
    cfg: ModelConfig
    remat: str = "dots"
    attn_impl: str = "chunked"

    def param_specs(self) -> dict:
        cfg = self.cfg
        return {
            "embed": layers.embedding_spec(cfg.padded_vocab, cfg.d_model),
            "enc_pos": layers.learned_pos_spec(cfg.encoder_seq, cfg.d_model),
            "dec_pos": layers.learned_pos_spec(MAX_LEARNED_POS, cfg.d_model),
            "encoder": stack_specs(_eblock_specs(cfg, False), cfg.n_encoder_layers),
            "enc_norm": layers.layer_norm_spec(cfg.d_model),
            "decoder": stack_specs(_eblock_specs(cfg, True), cfg.n_layers),
            "final_norm": layers.layer_norm_spec(cfg.d_model),
        }

    # -- encoder ---------------------------------------------------------------

    def encode(self, params: dict, frames: jax.Array, dtype) -> jax.Array:
        cfg = self.cfg
        x = frames.astype(dtype) + params["enc_pos"]["table"][
            None, : frames.shape[1]
        ].astype(dtype)
        x = sharding.constrain(x, ("batch", "seq", "embed"))

        def block(p, h):
            a = attn.gqa_full(
                p["attn"], cfg, layers.layer_norm(p["ln1"], h, cfg.norm_eps),
                causal=False, impl=self.attn_impl,
            )
            h = h + a
            y = layers.mlp(
                p["mlp"], layers.layer_norm(p["ln2"], h, cfg.norm_eps), cfg.act
            )
            return h + y

        body = _remat(block, self.remat)

        def scan_body(h, p):
            return body(p, h), None

        x, _ = maybe_scan(scan_body, x, params["encoder"])
        return layers.layer_norm(params["enc_norm"], x, cfg.norm_eps)

    # -- decoder ---------------------------------------------------------------

    def _dec_embed(self, params, tokens, dtype, pos_offset=None):
        x = layers.embed(params["embed"], tokens, dtype)
        if pos_offset is None:
            pos = params["dec_pos"]["table"][None, : tokens.shape[1]]
        else:
            pos = jnp.take(params["dec_pos"]["table"], pos_offset, axis=0)[:, None]
        return x + pos.astype(dtype)

    def _dec_block(self, p, h, enc_out):
        cfg = self.cfg
        a = attn.gqa_full(
            p["attn"], cfg, layers.layer_norm(p["ln1"], h, cfg.norm_eps),
            causal=True, impl=self.attn_impl,
        )
        h = h + a
        normed = layers.layer_norm(p["ln_cross"], h, cfg.norm_eps)
        kv = attn.gqa_project_kv(p["cross"], cfg, enc_out, None)
        c = attn.gqa_full(
            p["cross"], cfg, normed, causal=False, impl=self.attn_impl, kv=kv
        )
        h = h + c
        y = layers.mlp(p["mlp"], layers.layer_norm(p["ln2"], h, cfg.norm_eps), cfg.act)
        return h + y

    def forward(
        self,
        params: dict,
        batch: dict,
        *,
        dtype: Any = jnp.bfloat16,
        return_hidden: bool = False,
    ) -> tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"], dtype)
        x = self._dec_embed(params, batch["tokens"], dtype)
        body = _remat(lambda p, h: self._dec_block(p, h, enc_out), self.remat)

        def scan_body(h, p):
            return body(p, h), None

        x, _ = maybe_scan(scan_body, x, params["decoder"])
        x = layers.layer_norm(params["final_norm"], x, cfg.norm_eps)
        if return_hidden:
            return x, jnp.zeros((), jnp.float32)
        logits = layers.unembed(params["embed"], x, dtype)
        return logits, jnp.zeros((), jnp.float32)

    # -- caches ------------------------------------------------------------------

    def cache_specs(
        self, batch: int, max_len: int, dtype: Any = jnp.bfloat16
    ) -> dict:
        cfg = self.cfg
        self_kv = attn.gqa_init_cache(cfg, batch, max_len, dtype)
        cross_kv = attn.gqa_init_cache(cfg, batch, cfg.encoder_seq, dtype)
        return {
            "self": stack_specs(self_kv, cfg.n_layers),
            "cross": stack_specs(cross_kv, cfg.n_layers),
        }

    def prefill(
        self, params: dict, batch: dict, cache: dict, *, dtype: Any = jnp.bfloat16
    ) -> tuple[jax.Array, dict]:
        """Encode frames, build cross caches, run prompt through the decoder."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"], dtype)
        tokens = batch["tokens"]
        x = self._dec_embed(params, tokens, dtype)

        def scan_body(h, pc):
            p, (self_c, cross_c) = pc
            normed = layers.layer_norm(p["ln1"], h, cfg.norm_eps)
            k, v = attn.gqa_project_kv(p["attn"], cfg, normed, None)
            self_c = {
                "k": _fill(self_c["k"], k),
                "v": _fill(self_c["v"], v),
            }
            a = attn.gqa_full(
                p["attn"], cfg, normed, causal=True, impl=self.attn_impl, kv=(k, v)
            )
            h = h + a
            ck, cv = attn.gqa_project_kv(p["cross"], cfg, enc_out, None)
            cross_c = {
                "k": ck.astype(cross_c["k"].dtype),
                "v": cv.astype(cross_c["v"].dtype),
            }
            normed = layers.layer_norm(p["ln_cross"], h, cfg.norm_eps)
            c = attn.gqa_full(
                p["cross"], cfg, normed, causal=False, impl=self.attn_impl,
                kv=(ck, cv),
            )
            h = h + c
            y = layers.mlp(
                p["mlp"], layers.layer_norm(p["ln2"], h, cfg.norm_eps), cfg.act
            )
            return h + y, (self_c, cross_c)

        x, (new_self, new_cross) = maybe_scan(
            scan_body, x, (params["decoder"], (cache["self"], cache["cross"]))
        )
        x = layers.layer_norm(params["final_norm"], x, cfg.norm_eps)
        logits = layers.unembed(params["embed"], x[:, -1:], dtype)
        return logits[:, 0], {"self": new_self, "cross": new_cross}

    def decode_step(
        self,
        params: dict,
        tokens: jax.Array,
        cache: dict,
        positions: jax.Array,
        *,
        dtype: Any = jnp.bfloat16,
    ) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        x = self._dec_embed(params, tokens, dtype, pos_offset=positions)

        def scan_body(h, pc):
            p, (self_c, cross_c) = pc
            normed = layers.layer_norm(p["ln1"], h, cfg.norm_eps)
            a, self_c = attn.gqa_decode(p["attn"], cfg, normed, self_c, positions)
            h = h + a
            normed = layers.layer_norm(p["ln_cross"], h, cfg.norm_eps)
            q = attn.gqa_project_q(p["cross"], cfg, normed, None)
            c = attn.naive_attention(
                q, cross_c["k"], cross_c["v"], None, cfg.head_dim**-0.5
            )
            c = layers.dense(p["cross"]["wo"], c.reshape(c.shape[0], 1, -1))
            h = h + c
            y = layers.mlp(
                p["mlp"], layers.layer_norm(p["ln2"], h, cfg.norm_eps), cfg.act
            )
            return h + y, (self_c, cross_c)

        x, (new_self, new_cross) = maybe_scan(
            scan_body, x, (params["decoder"], (cache["self"], cache["cross"]))
        )
        x = layers.layer_norm(params["final_norm"], x, cfg.norm_eps)
        logits = layers.unembed(params["embed"], x, dtype)
        return logits[:, 0], {"self": new_self, "cross": new_cross}


# ===========================================================================
# Factory + utilities
# ===========================================================================


def build_model(cfg: ModelConfig, **kw: Any):
    if cfg.family in ("dense", "moe", "vlm"):
        return TransformerLM(cfg, **kw)
    if cfg.family == "ssm":
        return MambaLM(cfg, **{k: v for k, v in kw.items() if k != "attn_impl"})
    if cfg.family == "hybrid":
        return HybridLM(cfg, **kw)
    if cfg.family == "encdec":
        return EncDecLM(cfg, **kw)
    raise ValueError(f"unknown family {cfg.family!r}")


def active_param_count(cfg: ModelConfig, specs: PyTree) -> int:
    """Parameters touched per token (MoE experts scaled by k/E)."""
    from repro.models.params import map_with_path

    total = 0

    def visit(path: tuple[str, ...], s: ParamSpec) -> ParamSpec:
        nonlocal total
        n = s.size
        if cfg.n_experts and "moe" in path and path[-2] == "moe" and path[-1] in (
            "wi", "wg", "wo"
        ):
            n = int(n * cfg.n_experts_per_token / cfg.n_experts)
        total += n
        return s

    map_with_path(visit, specs)
    return total
