"""Logical-axis sharding rules (MaxText-style).

Every parameter / activation axis in the model code carries a *logical* name
("embed", "heads", "mlp", "vocab", "batch", ...).  A rule table maps logical
names to physical mesh axes; :func:`spec_for_axes` resolves a tuple of logical
names into a ``PartitionSpec``, skipping any mapping that does not divide
evenly (e.g. whisper's 20 heads on a 16-way model axis fall back to
replication rather than failing).

Two rule sets ship by default:

* ``TRAIN_RULES``  — TP over ``model``, batch over ``(pod, data)``, FSDP
  (weight sharding) over ``data``.
* ``SERVE_RULES``  — TP over ``model``, batch over ``(pod, data)``, decode KV
  cache *sequence*-sharded over ``data`` (flash-decode style) so that a
  batch-1, 500k-token cache still uses the whole pod.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import params as pm

# ---------------------------------------------------------------------------
# Rule tables: logical axis -> mesh axis (or tuple of mesh axes).
# Order matters: first rule whose mesh axes are all present in the mesh and
# divide the dimension evenly wins.
# ---------------------------------------------------------------------------

Rules = tuple[tuple[str, Any], ...]

TRAIN_RULES: Rules = (
    ("batch", ("pod", "data")),   # examples across the "executor pool"
    ("batch", "data"),
    ("expert", "model"),          # expert parallelism
    ("heads", "model"),           # TP: attention heads
    ("kv_heads", "model"),
    ("mlp", "model"),             # TP: FFN hidden
    ("vocab", "model"),           # TP: embedding/unembedding
    ("ssm_heads", "model"),
    ("ssm_inner", "model"),
    ("kv_lora", None),
    ("embed", ("pod", "data")),   # FSDP: shard the d_model axis of weights
    ("embed", "data"),
    ("expert_data", ("pod", "data")),  # FSDP axis for expert weights
    ("expert_data", "data"),
    ("seq", None),
    ("cache_seq", None),
    ("layers", None),
    ("conv", None),
    ("state", None),
)

SERVE_RULES: Rules = (
    ("batch", ("pod", "data")),
    ("batch", "data"),
    ("expert", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("mlp", "model"),
    ("vocab", "model"),
    ("ssm_heads", "model"),
    ("ssm_inner", "model"),
    ("kv_lora", None),
    ("embed", None),              # serving: weights replicated along data
    ("expert_data", None),
    ("seq", None),
    # decode KV cache: sequence sharding over "model" (flash-decode style);
    # works for every arch/shape (32k and 512k divide 16) including batch=1
    # long-context, and keeps per-device KV bytes at 1/(data*model).
    ("cache_seq", "model"),
    ("layers", None),
    ("conv", None),
    ("state", None),
)


#: context-parallel prefill (§Perf hillclimb): activations shard over
#: (batch x SEQUENCE) instead of TP — weights are fully sharded for storage
#: and gathered per layer (XLA-inserted), so per-step wire is one weight
#: gather (~param_bytes) instead of 2 full-activation all-reduces per layer.
PREFILL_CP_RULES: Rules = (
    ("batch", ("pod", "data")),
    ("batch", "data"),
    ("seq", "model"),             # context parallelism
    ("cache_seq", "model"),
    # weight storage sharding (gathered on use)
    ("embed", "data"),
    ("expert_data", "data"),
    ("expert", "model"),
    ("heads", None),
    ("kv_heads", None),
    ("mlp", None),
    ("vocab", None),
    ("ssm_heads", None),
    ("ssm_inner", None),
    ("kv_lora", None),
    ("layers", None),
    ("conv", None),
    ("state", None),
)

#: serve with 2D expert sharding (§Perf): routed-expert weights shard over
#: (model x data) so a 236B MoE fits per-device HBM at serve time; the
#: dispatch einsum's d_model contraction turns into a cheap partial-sum
#: all-reduce of the (tiny) per-expert token blocks.
SERVE_EP2D_RULES: Rules = tuple(
    (name, "data") if name == "expert_data" else (name, target)
    for name, target in SERVE_RULES
)

RULE_TABLES = {
    "train": TRAIN_RULES,
    "serve": SERVE_RULES,
    "serve_ep2d": SERVE_EP2D_RULES,
    "prefill_cp": PREFILL_CP_RULES,
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: Rules
    mesh: Mesh
    #: "token" (default): dispatched tokens stay batch-sharded, expert
    #: weights are gathered (right for training: weights << activations).
    #: "weight_stationary": dispatched tokens reshard to d_model-sharded so
    #: 2D-sharded expert weights never move (right for decode: capacity is
    #: tiny, weights are huge).
    moe_dispatch: str = "token"

    def mesh_axes_for(self, logical: str | None) -> tuple[str, ...] | None:
        """First rule for ``logical`` whose mesh axes all exist wins; rules
        whose axes are absent (e.g. ``pod`` on a single-pod mesh) fall through
        to the next rule for the same name."""
        if logical is None:
            return None
        for name, target in self.rules:
            if name != logical:
                continue
            if target is None:
                return None
            axes = (target,) if isinstance(target, str) else tuple(target)
            if all(a in self.mesh.axis_names for a in axes):
                return axes
        return None

    def candidates_for(self, logical: str | None) -> list[tuple[str, ...]]:
        """All viable mesh-axis tuples for ``logical``, in rule order."""
        if logical is None:
            return []
        out: list[tuple[str, ...]] = []
        for name, target in self.rules:
            if name != logical:
                continue
            if target is None:
                break
            axes = (target,) if isinstance(target, str) else tuple(target)
            if all(a in self.mesh.axis_names for a in axes):
                out.append(axes)
        return out

    def spec_for_axes(
        self, axes: Sequence[str | None], shape: Sequence[int] | None = None
    ) -> P:
        """Resolve logical axes into a PartitionSpec.

        If ``shape`` is given, a mapping that does not divide the dimension
        evenly falls through to the next rule for the same logical name, and
        finally to replication — never a lowering error.  A mesh axis may be
        consumed at most once per spec.
        """
        used: set[str] = set()
        out: list[Any] = []
        for i, logical in enumerate(axes):
            chosen: tuple[str, ...] | None = None
            for mesh_axes in self.candidates_for(logical):
                if any(a in used for a in mesh_axes):
                    continue
                size = 1
                for a in mesh_axes:
                    size *= self.mesh.shape[a]
                if shape is not None and shape[i] % size != 0:
                    continue
                chosen = mesh_axes
                break
            if chosen is None:
                out.append(None)
                continue
            used.update(chosen)
            out.append(chosen[0] if len(chosen) == 1 else tuple(chosen))
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    # -- parameters ---------------------------------------------------------

    def param_pspecs(self, specs: Any) -> Any:
        """PartitionSpec tree for a ParamSpec tree."""
        return jax.tree.map(
            lambda s: self.spec_for_axes(s.axes, s.shape),
            specs,
            is_leaf=pm.is_spec,
        )

    def param_shardings(self, specs: Any) -> Any:
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, self.spec_for_axes(s.axes, s.shape)),
            specs,
            is_leaf=pm.is_spec,
        )

    # -- activations --------------------------------------------------------

    def constrain(self, x: jax.Array, axes: Sequence[str | None]) -> jax.Array:
        """with_sharding_constraint by logical axis names (no-op off-mesh)."""
        spec = self.spec_for_axes(axes, x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))


# A process-global "current rules" so model code can annotate activations
# without threading the rules object through every function signature.
_CURRENT: list[ShardingRules | None] = [None]


class use_rules:
    """Context manager installing the active sharding rules."""

    def __init__(self, rules: ShardingRules | None):
        self.rules = rules
        self._prev: ShardingRules | None = None

    def __enter__(self) -> ShardingRules | None:
        self._prev = _CURRENT[0]
        _CURRENT[0] = self.rules
        return self.rules

    def __exit__(self, *exc: Any) -> None:
        _CURRENT[0] = self._prev


def constrain(x: jax.Array, axes: Sequence[str | None]) -> jax.Array:
    """Annotate activation sharding if rules are active, else pass through."""
    rules = _CURRENT[0]
    if rules is None:
        return x
    return rules.constrain(x, axes)


def current_rules() -> ShardingRules | None:
    return _CURRENT[0]
