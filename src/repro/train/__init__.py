from repro.train.loss import cross_entropy
from repro.train.optimizer import (
    AdamWState,
    OptimizerConfig,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    init_opt_state,
    lr_at,
)
from repro.train.step import TrainConfig, make_loss_fn, make_train_step

__all__ = [
    "AdamWState",
    "OptimizerConfig",
    "TrainConfig",
    "adamw_update",
    "clip_by_global_norm",
    "cross_entropy",
    "global_norm",
    "init_opt_state",
    "lr_at",
    "make_loss_fn",
    "make_train_step",
]
