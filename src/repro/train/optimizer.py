"""AdamW + LR schedules + gradient clipping, from scratch.

Optimizer state is a pytree congruent with the parameter tree, so the same
sharding rules apply (FSDP shards optimizer moments exactly like weights —
this is what makes 100B-parameter training fit).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    schedule: str = "cosine"  # cosine | linear | constant


class AdamWState(NamedTuple):
    step: jax.Array  # () int32
    mu: PyTree
    nu: PyTree


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Warmup + decay schedule, evaluated in-graph."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(cfg.warmup_steps, 1))
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac)
        )
    elif cfg.schedule == "linear":
        decay = 1.0 - (1.0 - cfg.min_lr_ratio) * frac
    elif cfg.schedule == "constant":
        decay = jnp.ones_like(frac)
    else:
        raise ValueError(f"unknown schedule {cfg.schedule!r}")
    return cfg.learning_rate * warm * decay


def init_opt_state(params: PyTree) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=zeros)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(
    cfg: OptimizerConfig,
    params: PyTree,
    grads: PyTree,
    state: AdamWState,
) -> tuple[PyTree, AdamWState, dict]:
    """One AdamW step (decoupled weight decay, bias-corrected moments)."""
    grads, grad_norm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    step = state.step + 1
    lr = lr_at(cfg, state.step)
    b1, b2 = cfg.b1, cfg.b2

    mu = jax.tree.map(
        lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
    )
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.nu,
        grads,
    )
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    metrics = {"lr": lr, "grad_norm": grad_norm}
    return new_params, AdamWState(step=step, mu=mu, nu=nu), metrics
