"""Next-token cross-entropy with vocab-padding masking and z-loss."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(
    logits: jax.Array,  # (B, S, Vp) f32, possibly vocab-padded
    labels: jax.Array,  # (B, S) int32, -1 = ignore
    vocab_size: int,
    z_loss_weight: float = 1e-4,
) -> tuple[jax.Array, dict]:
    """Mean masked token NLL (+ z-loss).  Padded vocab ids get -inf logits.

    Uses ``take_along_axis`` for the label logit (XLA partitions the gather
    with a masked local gather + all-reduce when vocab is TP-sharded — this
    avoids materializing a (B, S, V) one-hot; see DESIGN.md §5).
    """
    vp = logits.shape[-1]
    if vp > vocab_size:
        pad_mask = jnp.arange(vp) < vocab_size
        logits = jnp.where(pad_mask, logits, -1e9)

    lse = jax.nn.logsumexp(logits, axis=-1)  # (B, S)
    label_ids = jnp.maximum(labels, 0)
    label_logit = jnp.take_along_axis(logits, label_ids[..., None], axis=-1)[..., 0]
    nll = lse - label_logit

    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom

    z = jnp.sum(jnp.square(lse) * mask) / denom
    total = loss + z_loss_weight * z

    metrics = {
        "nll": loss,
        "z_loss": z,
        "tokens": jnp.sum(mask),
        "accuracy": jnp.sum(
            (jnp.argmax(logits, axis=-1) == label_ids).astype(jnp.float32) * mask
        )
        / denom,
    }
    return total, metrics
