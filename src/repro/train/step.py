"""``train_step`` factory: loss → grad → (optional microbatch accumulation) →
AdamW update.  This is the function the dry-run lowers for ``train_4k``.

Gradient accumulation scans over microbatches (sequential, f32 accumulator),
trading step latency for activation memory — the standard large-batch recipe.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.train import loss as loss_lib
from repro.train import optimizer as opt_lib
from repro.models.unroll import maybe_scan

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: opt_lib.OptimizerConfig = opt_lib.OptimizerConfig()
    microbatches: int = 1
    z_loss_weight: float = 1e-4
    compute_dtype: Any = jnp.bfloat16


def make_loss_fn(
    model: Any, cfg: ModelConfig, tcfg: TrainConfig
) -> Callable[[PyTree, dict], tuple[jax.Array, dict]]:
    def loss_fn(params: PyTree, batch: dict) -> tuple[jax.Array, dict]:
        logits, aux = model.forward(params, batch, dtype=tcfg.compute_dtype)
        labels = batch["labels"]
        if cfg.family == "vlm":
            # logits cover [vision prefix | text]; align to text labels
            logits = logits[:, cfg.n_vision_tokens :]
        total, metrics = loss_lib.cross_entropy(
            logits, labels, cfg.padded_vocab, tcfg.z_loss_weight
        )
        if cfg.n_experts:
            total = total + cfg.router_aux_weight * aux
            metrics["moe_aux"] = aux
        metrics["loss"] = total
        return total, metrics

    return loss_fn


def make_train_step(
    model: Any, cfg: ModelConfig, tcfg: TrainConfig
) -> Callable[[PyTree, opt_lib.AdamWState, dict], tuple[PyTree, Any, dict]]:
    """Returns ``train_step(params, opt_state, batch) -> (params, opt, metrics)``.

    ``batch`` arrays have a leading global-batch axis; with
    ``tcfg.microbatches > 1`` they are reshaped to (M, B/M, ...) and
    accumulated with a sequential scan.
    """
    loss_fn = make_loss_fn(model, cfg, tcfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (_, metrics), grads = grad_fn(params, batch)
        return grads, metrics

    def accumulated(params, batch):
        m = tcfg.microbatches

        def reshape(x):
            return x.reshape((m, x.shape[0] // m) + x.shape[1:])

        micro = jax.tree.map(reshape, batch)

        def body(carry, mb):
            acc, met_acc = carry
            grads, metrics = single(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / m, acc, grads
            )
            met_acc = jax.tree.map(lambda a, x: a + x / m, met_acc, metrics)
            return (acc, met_acc), None

        zero_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        g0, met0 = single(params, jax.tree.map(lambda x: x[0], micro))
        init = (
            jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / m, zero_g, g0),
            jax.tree.map(lambda x: x / m, met0),
        )
        (grads, metrics), _ = maybe_scan(
            body, init, jax.tree.map(lambda x: x[1:], micro)
        )
        return grads, metrics

    def train_step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            grads, metrics = accumulated(params, batch)
        else:
            grads, metrics = single(params, batch)
        params, opt_state, opt_metrics = opt_lib.adamw_update(
            tcfg.optimizer, params, grads, opt_state
        )
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step
