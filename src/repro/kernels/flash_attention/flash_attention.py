"""Flash-attention Pallas TPU kernel (prefill / train path).

TPU-native tiling (DESIGN.md §6): the grid is (B, H, nq, nk) with the
k-block axis innermost — TPU grids execute sequentially over the trailing
dimension, so the online-softmax running state (m, l, acc) lives in VMEM
scratch and carries across k-blocks.  GQA is expressed *in the BlockSpec
index maps*: k/v blocks are fetched from head ``h // group`` so a KV head's
tiles are read once per query-head group, never duplicated in HBM.

Block shapes default to (block_q x d) and (block_k x d) tiles sized for
~1-2 MB of VMEM with d=128 MXU-aligned lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(
    q_ref,  # (1, 1, bq, d)
    k_ref,  # (1, 1, bk, d)
    v_ref,  # (1, 1, bk, d)
    o_ref,  # (1, 1, bq, d)
    m_ref,  # VMEM scratch (bq, 1) f32
    l_ref,  # VMEM scratch (bq, 1) f32
    acc_ref,  # VMEM scratch (bq, d) f32
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    n_k: int,
    q_offset: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)

    if causal:
        q_pos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0
        ) + q_offset
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1
        )
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[:, 0]  # (bq,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])  # (bq, bk)
    correction = jnp.exp(m_prev - m_new)
    l_new = l_ref[:, 0] * correction + jnp.sum(p, axis=1)
    acc = acc_ref[...] * correction[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    m_ref[:, 0] = m_new
    l_ref[:, 0] = l_new
    acc_ref[...] = acc

    @pl.when(ik == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:, 0], 1e-37)[:, None]
        o_ref[0, 0, :, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "scale", "block_q", "block_k", "q_offset", "interpret"
    ),
)
def flash_attention(
    q: jax.Array,  # (B, H, Sq, d)
    k: jax.Array,  # (B, K, Sk, d)
    v: jax.Array,  # (B, K, Sk, d)
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 256,
    block_k: int = 512,
    q_offset: int = 0,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, d = q.shape
    kheads, sk = k.shape[1], k.shape[2]
    g = h // kheads
    if scale is None:
        scale = d**-0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    n_q, n_k = sq // block_q, sk // block_k

    kernel = functools.partial(
        _kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        n_k=n_k,
        q_offset=q_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_k),
        in_specs=[
            pl.BlockSpec(
                (1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda b_, h_, iq, ik, g=g: (b_, h_ // g, ik, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda b_, h_, iq, ik, g=g: (b_, h_ // g, ik, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m
            pltpu.VMEM((block_q, 1), jnp.float32),   # l
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q, k, v)
