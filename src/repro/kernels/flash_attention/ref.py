"""Pure-jnp oracle for the flash-attention kernel.

Layout: q (B, H, Sq, d), k/v (B, K, Sk, d) with H = K * G (GQA).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def flash_attention_ref(
    q: jax.Array,  # (B, H, Sq, d)
    k: jax.Array,  # (B, K, Sk, d)
    v: jax.Array,  # (B, K, Sk, d)
    *,
    causal: bool = True,
    scale: float | None = None,
    q_offset: int = 0,
) -> jax.Array:
    b, h, sq, d = q.shape
    kheads, sk = k.shape[1], k.shape[2]
    g = h // kheads
    if scale is None:
        scale = d**-0.5
    qg = q.reshape(b, kheads, g, sq, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgqd,bksd->bkgqs", qg, kf) * scale
    if causal:
        q_pos = jnp.arange(sq) + q_offset
        k_pos = jnp.arange(sk)
        mask = q_pos[:, None] >= k_pos[None, :]
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", probs, vf)
    return out.reshape(b, h, sq, d).astype(q.dtype)
