"""Model-facing wrapper for the flash-attention kernel.

Accepts the model layout (B, S, H, d) and handles transposition, GQA head
mapping and block-size selection.  ``interpret=True`` runs the kernel body
in Python on CPU (how the test suite validates against ``ref.py``); on a
real TPU the same call lowers through Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention


def _pick_block(s: int, target: int) -> int:
    target = min(target, s)
    for b in range(target, 0, -1):
        if s % b == 0:
            return b
    return s


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "q_offset", "interpret")
)
def flash_attention_bshd(
    q: jax.Array,  # (B, Sq, H, d)
    k: jax.Array,  # (B, Sk, K, d)
    v: jax.Array,  # (B, Sk, K, d)
    *,
    causal: bool = True,
    scale: float | None = None,
    q_offset: int = 0,
    interpret: bool = False,
) -> jax.Array:
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    out = flash_attention(
        qt,
        kt,
        vt,
        causal=causal,
        scale=scale,
        block_q=_pick_block(q.shape[1], 256),
        block_k=_pick_block(k.shape[1], 512),
        q_offset=q_offset,
        interpret=interpret,
    )
    return jnp.transpose(out, (0, 2, 1, 3))
