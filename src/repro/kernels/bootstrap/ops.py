"""Bootstrap CI wrapper: kernel (large n) or jnp ref (host scale), plus
percentile extraction."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bootstrap.bootstrap import bootstrap_means
from repro.kernels.bootstrap.ref import bootstrap_means_ref


@functools.partial(
    jax.jit, static_argnames=("n_boot", "confidence", "use_pallas", "interpret")
)
def bootstrap_ci(
    data: jax.Array,
    seed: int = 0,
    *,
    n_boot: int = 1000,
    confidence: float = 0.95,
    use_pallas: bool = False,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(mean, lo, hi) percentile CI from Poisson-bootstrap means."""
    if use_pallas:
        means = bootstrap_means(
            data, jnp.uint32(seed), n_boot=n_boot, interpret=interpret
        )
    else:
        means = bootstrap_means_ref(data, n_boot, seed)
    alpha = (1.0 - confidence) / 2.0
    lo = jnp.quantile(means, alpha)
    hi = jnp.quantile(means, 1.0 - alpha)
    return jnp.mean(data), lo, hi
