"""Bootstrap CI wrapper: kernel (large n) or jnp ref (host scale), plus
percentile extraction and the chunked-partials dispatcher used by the
device-resident statistics backend."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bootstrap.bootstrap import bootstrap_means
from repro.kernels.bootstrap.bootstrap import (
    bootstrap_partials as bootstrap_partials_kernel,
)
from repro.kernels.bootstrap.ref import bootstrap_means_ref, bootstrap_partials_ref


@functools.partial(
    jax.jit, static_argnames=("n_boot", "confidence", "use_pallas", "interpret")
)
def bootstrap_ci(
    data: jax.Array,
    seed: int = 0,
    *,
    n_boot: int = 1000,
    confidence: float = 0.95,
    use_pallas: bool = False,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(mean, lo, hi) percentile CI from Poisson-bootstrap means."""
    if use_pallas:
        means = bootstrap_means(
            data, jnp.uint32(seed), n_boot=n_boot, interpret=interpret
        )
    else:
        means = bootstrap_means_ref(data, n_boot, seed)
    alpha = (1.0 - confidence) / 2.0
    lo = jnp.quantile(means, alpha)
    hi = jnp.quantile(means, 1.0 - alpha)
    return jnp.mean(data), lo, hi


def resolve_partials_mode(mode: str) -> str:
    """Resolve ``"auto"`` to the execution path this process will use.

    The three concrete modes share the identical weight stream but differ
    in float accumulation order, so partials from different modes are not
    bit-mergeable: callers that persist partials (the pallas statistics
    engine's spill state) record the resolved mode and refuse to merge
    across modes — e.g. a run spilled on a TPU host must not be resumed
    float-inexactly on a CPU host.
    """
    if mode == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "ref"
    if mode not in ("kernel", "interpret", "ref"):
        raise ValueError(f"unknown bootstrap partials mode {mode!r}")
    return mode


def bootstrap_partials(
    scores: np.ndarray,  # (n, m) float — NaN marks unscorable examples
    seed: int,
    start: int,
    *,
    n_boot: int = 1000,
    mode: str = "auto",
) -> tuple[np.ndarray, np.ndarray]:
    """Chunked-partials entry point for the ``backend="pallas"`` statistics
    engine: ``(sum w*x, sum w)`` float32 replicate pairs of shape
    ``(n_boot, m)`` for one chunk whose row 0 sits at absolute offset
    ``start``.

    ``mode`` selects the execution path — all three share the identical
    counter-mixer weight stream (bit-for-bit), they differ only in float
    accumulation order:

    * ``"auto"``   — the Pallas TPU kernel when a TPU is attached, else the
      blocked jnp oracle (XLA-compiled; this is the production CPU path).
    * ``"kernel"`` / ``"interpret"`` — force the kernel (natively, or
      through the Pallas interpreter for CPU parity tests).
    * ``"ref"``    — force the blocked jnp oracle.
    """
    n, m = np.shape(scores)
    if n == 0:  # empty chunk: zero partials (the kernel's grid needs >=1 tile)
        zeros = np.zeros((n_boot, m), np.float32)
        return zeros, zeros.copy()
    mode = resolve_partials_mode(mode)
    x = jnp.asarray(scores, jnp.float32)
    s = jnp.uint32(seed)
    o = jnp.uint32(start)
    if mode == "ref":
        swx, sw = bootstrap_partials_ref(x, s, o, n_boot=n_boot)
    else:
        swx, sw = bootstrap_partials_kernel(
            x, s, o, n_boot=n_boot, interpret=(mode == "interpret")
        )
    return np.asarray(swx), np.asarray(sw)
