"""Poisson-bootstrap resample-reduce Pallas TPU kernel.

The statistics stage at paper scale is B x n ~ 10^3 x 10^6 resample-reduce —
too big to materialize resample indices in HBM (that would be 4 TB of
int32).  TPU-native design (DESIGN.md §6):

* **PRNG-on-the-fly**: resample weights are generated *inside* the kernel
  from a counter-based mixer (murmur3-finalizer over (boot_row, position,
  seed)) — zero HBM traffic for randomness, fully deterministic given the
  seed, and identical across shards.
* **Poisson bootstrap**: weights w ~ Poisson(1) i.i.d. instead of an exact
  multinomial resample.  This is the standard streaming/distributed
  bootstrap (resample mean = sum(w*x)/sum(w)); no gather is needed, tiles
  stream through VMEM.  Statistical equivalence is validated empirically by
  the coverage benchmark (paper Table 5); the exact multinomial path exists
  in ``repro/stats/bootstrap.py`` for host-scale n.
* grid = (n_boot/bb, n/bn), data-tile axis innermost; (bb,) running sums in
  VMEM scratch; means emitted on the last data tile.

Truncation: the inverse-CDF lookup caps w at 7 (tail mass ~8e-5) — bias is
< 1e-4 relative and far below bootstrap Monte-Carlo noise at B = 1000.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.bootstrap.ref import POISSON1_CDF


def _kernel(
    data_ref,   # (1, bn)
    seed_ref,   # (1, 1) uint32
    out_ref,    # (bb, 1) f32 — means for this bootstrap-row block
    swx_ref,    # VMEM (bb, 1) f32
    sw_ref,     # VMEM (bb, 1) f32
    *,
    bb: int,
    bn: int,
    n: int,
    n_tiles: int,
):
    ib = pl.program_id(0)
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        swx_ref[...] = jnp.zeros_like(swx_ref)
        sw_ref[...] = jnp.zeros_like(sw_ref)

    x = data_ref[0, :].astype(jnp.float32)  # (bn,)

    u32 = jnp.uint32
    boot = (
        ib * bb + jax.lax.broadcasted_iota(jnp.int32, (bb, bn), 0)
    ).astype(u32)
    pos = (
        it * bn + jax.lax.broadcasted_iota(jnp.int32, (bb, bn), 1)
    ).astype(u32)
    seed = seed_ref[0, 0]

    h = boot * u32(0x9E3779B1) ^ pos * u32(0x85EBCA77) ^ seed
    h = h ^ (h >> u32(16))
    h = h * u32(0x85EBCA6B)
    h = h ^ (h >> u32(13))
    h = h * u32(0xC2B2AE35)
    h = h ^ (h >> u32(16))

    u = (h >> u32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))
    w = jnp.zeros((bb, bn), jnp.float32)
    for c in POISSON1_CDF:
        w = w + (u >= jnp.float32(c)).astype(jnp.float32)

    # mask the ragged tail (n may not divide the tile size)
    valid = (it * bn + jax.lax.broadcasted_iota(jnp.int32, (bb, bn), 1)) < n
    w = jnp.where(valid, w, 0.0)

    swx_ref[:, 0] += w @ x
    sw_ref[:, 0] += jnp.sum(w, axis=1)

    @pl.when(it == n_tiles - 1)
    def _final():
        out_ref[:, 0] = swx_ref[:, 0] / jnp.maximum(sw_ref[:, 0], 1.0)


@functools.partial(
    jax.jit, static_argnames=("n_boot", "block_boot", "block_n", "interpret")
)
def bootstrap_means(
    data: jax.Array,  # (n,) f32
    seed: jax.Array,  # () uint32
    *,
    n_boot: int = 1000,
    block_boot: int = 128,
    block_n: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """(n_boot,) Poisson-bootstrap means of ``data``."""
    n = data.shape[0]
    bb = min(block_boot, n_boot)
    assert n_boot % bb == 0, (n_boot, bb)
    bn = min(block_n, n)
    n_tiles = (n + bn - 1) // bn
    n_pad = n_tiles * bn
    if n_pad != n:
        data = jnp.pad(data, (0, n_pad - n))

    kernel = functools.partial(
        _kernel, bb=bb, bn=bn, n=n, n_tiles=n_tiles
    )
    out = pl.pallas_call(
        kernel,
        grid=(n_boot // bb, n_tiles),
        in_specs=[
            pl.BlockSpec((1, bn), lambda ib, it: (0, it)),
            pl.BlockSpec((1, 1), lambda ib, it: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, 1), lambda ib, it: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((n_boot, 1), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bb, 1), jnp.float32),
            pltpu.VMEM((bb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        data.reshape(1, n_pad).astype(jnp.float32),
        jnp.asarray(seed, jnp.uint32).reshape(1, 1),
    )
    return out[:, 0]
