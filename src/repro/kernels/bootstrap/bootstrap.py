"""Poisson-bootstrap resample-reduce Pallas TPU kernel.

The statistics stage at paper scale is B x n ~ 10^3 x 10^6 resample-reduce —
too big to materialize resample indices in HBM (that would be 4 TB of
int32).  TPU-native design (DESIGN.md §6):

* **PRNG-on-the-fly**: resample weights are generated *inside* the kernel
  from a counter-based mixer (murmur3-finalizer over (boot_row, position,
  seed)) — zero HBM traffic for randomness, fully deterministic given the
  seed, and identical across shards.
* **Poisson bootstrap**: weights w ~ Poisson(1) i.i.d. instead of an exact
  multinomial resample.  This is the standard streaming/distributed
  bootstrap (resample mean = sum(w*x)/sum(w)); no gather is needed, tiles
  stream through VMEM.  Statistical equivalence is validated empirically by
  the coverage benchmark (paper Table 5); the exact multinomial path exists
  in ``repro/stats/bootstrap.py`` for host-scale n.
* grid = (n_boot/bb, n/bn), data-tile axis innermost; (bb,) running sums in
  VMEM scratch; means emitted on the last data tile.

Truncation: the inverse-CDF lookup caps w at 7 (tail mass ~8e-5) — bias is
< 1e-4 relative and far below bootstrap Monte-Carlo noise at B = 1000.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.bootstrap.ref import mix_bits, poisson1_weight


def _kernel(
    data_ref,   # (1, bn)
    seed_ref,   # (1, 1) uint32
    out_ref,    # (bb, 1) f32 — means for this bootstrap-row block
    swx_ref,    # VMEM (bb, 1) f32
    sw_ref,     # VMEM (bb, 1) f32
    *,
    bb: int,
    bn: int,
    n: int,
    n_tiles: int,
):
    ib = pl.program_id(0)
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        swx_ref[...] = jnp.zeros_like(swx_ref)
        sw_ref[...] = jnp.zeros_like(sw_ref)

    x = data_ref[0, :].astype(jnp.float32)  # (bn,)

    u32 = jnp.uint32
    boot = (
        ib * bb + jax.lax.broadcasted_iota(jnp.int32, (bb, bn), 0)
    ).astype(u32)
    pos = (
        it * bn + jax.lax.broadcasted_iota(jnp.int32, (bb, bn), 1)
    ).astype(u32)

    # mix_bits/poisson1_weight are pure jnp and trace inside the kernel:
    # one definition of the PRNG shared by kernel and oracle, bit-for-bit
    w = poisson1_weight(mix_bits(boot, pos, seed_ref[0, 0]))

    # mask the ragged tail (n may not divide the tile size)
    valid = (it * bn + jax.lax.broadcasted_iota(jnp.int32, (bb, bn), 1)) < n
    w = jnp.where(valid, w, 0.0)

    swx_ref[:, 0] += w @ x
    sw_ref[:, 0] += jnp.sum(w, axis=1)

    @pl.when(it == n_tiles - 1)
    def _final():
        out_ref[:, 0] = swx_ref[:, 0] / jnp.maximum(sw_ref[:, 0], 1.0)


@functools.partial(
    jax.jit, static_argnames=("n_boot", "block_boot", "block_n", "interpret")
)
def bootstrap_means(
    data: jax.Array,  # (n,) f32
    seed: jax.Array,  # () uint32
    *,
    n_boot: int = 1000,
    block_boot: int = 128,
    block_n: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """(n_boot,) Poisson-bootstrap means of ``data``."""
    n = data.shape[0]
    bb = min(block_boot, n_boot)
    assert n_boot % bb == 0, (n_boot, bb)
    bn = min(block_n, n)
    n_tiles = (n + bn - 1) // bn
    n_pad = n_tiles * bn
    if n_pad != n:
        data = jnp.pad(data, (0, n_pad - n))

    kernel = functools.partial(
        _kernel, bb=bb, bn=bn, n=n, n_tiles=n_tiles
    )
    out = pl.pallas_call(
        kernel,
        grid=(n_boot // bb, n_tiles),
        in_specs=[
            pl.BlockSpec((1, bn), lambda ib, it: (0, it)),
            pl.BlockSpec((1, 1), lambda ib, it: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, 1), lambda ib, it: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((n_boot, 1), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bb, 1), jnp.float32),
            pltpu.VMEM((bb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        data.reshape(1, n_pad).astype(jnp.float32),
        jnp.asarray(seed, jnp.uint32).reshape(1, 1),
    )
    return out[:, 0]


# -- chunked-partials variant ---------------------------------------------------
#
# The evaluation pipeline streams chunks; a chunk carries *all* lexical
# metrics of its examples as a (chunk, n_metrics) score matrix.  Instead of
# one means-kernel launch per metric per chunk, this variant emits the
# mergeable ``(sum w*x, sum w)`` replicate pairs for every metric in one
# launch: weights are generated once per (replicate, example) and hit the
# MXU twice — against the scores and against the per-metric validity mask
# (NaN = unscorable, weight zero for that metric only).  Weights are keyed
# by the *absolute* example position ``chunk_start + i`` through the same
# murmur3-finalizer counter mixer, so chunk partials are deterministic,
# order-independent, and merge bit-identically across crash/resume as long
# as the chunk layout is unchanged.


def _partials_kernel(
    data_ref,   # (bn, bm) f32 — NaN marks unscorable / padding
    sp_ref,     # (1, 2) uint32 — [seed, chunk_start]
    swx_ref,    # out (bb, bm) f32
    sw_ref,     # out (bb, bm) f32
    swx_acc,    # VMEM (bb, bm) f32
    sw_acc,     # VMEM (bb, bm) f32
    *,
    bb: int,
    bn: int,
    n_tiles: int,
):
    ib = pl.program_id(0)
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        swx_acc[...] = jnp.zeros_like(swx_acc)
        sw_acc[...] = jnp.zeros_like(sw_acc)

    x = data_ref[...].astype(jnp.float32)  # (bn, bm)

    u32 = jnp.uint32
    boot = (
        ib * bb + jax.lax.broadcasted_iota(jnp.int32, (bb, bn), 0)
    ).astype(u32)
    pos = sp_ref[0, 1] + (
        it * bn + jax.lax.broadcasted_iota(jnp.int32, (bb, bn), 1)
    ).astype(u32)

    # shared PRNG definition (see _kernel): weights keyed by the absolute
    # example position, identical to the blocked oracle bit-for-bit
    w = poisson1_weight(mix_bits(boot, pos, sp_ref[0, 0]))

    # per-metric validity: NaN scores (and NaN row/column padding) carry
    # weight zero in both sums, so each metric's replicate pair only ever
    # sees that metric's scorable examples
    valid = x == x  # (bn, bm)
    xv = jnp.where(valid, x, 0.0)
    swx_acc[...] += jax.lax.dot(
        w, xv, preferred_element_type=jnp.float32
    )
    sw_acc[...] += jax.lax.dot(
        w, valid.astype(jnp.float32), preferred_element_type=jnp.float32
    )

    @pl.when(it == n_tiles - 1)
    def _final():
        swx_ref[...] = swx_acc[...]
        sw_ref[...] = sw_acc[...]


@functools.partial(
    jax.jit,
    static_argnames=("n_boot", "block_boot", "block_n", "interpret"),
)
def bootstrap_partials(
    scores: jax.Array,  # (n, m) — NaN marks unscorable examples
    seed: jax.Array,    # () uint32
    start: jax.Array,   # () uint32 — absolute offset of row 0
    *,
    n_boot: int = 1000,
    block_boot: int = 128,
    block_n: int = 1024,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Mergeable ``(sum w*x, sum w)`` replicate pairs, shape (n_boot, m)."""
    n, m = scores.shape
    bb = min(block_boot, n_boot)
    # round the replicate count up to a whole number of row-blocks; the
    # extra rows draw from their own counter stream and are sliced away
    nb_pad = ((n_boot + bb - 1) // bb) * bb
    bn = min(block_n, max(n, 8))
    n_tiles = (n + bn - 1) // bn
    # lanes want multiples of 128; pad metrics with NaN columns (masked out)
    bm = ((m + 127) // 128) * 128
    data = jnp.pad(
        scores.astype(jnp.float32),
        ((0, n_tiles * bn - n), (0, bm - m)),
        constant_values=jnp.nan,
    )

    kernel = functools.partial(
        _partials_kernel, bb=bb, bn=bn, n_tiles=n_tiles
    )
    swx, sw = pl.pallas_call(
        kernel,
        grid=(nb_pad // bb, n_tiles),
        in_specs=[
            pl.BlockSpec((bn, bm), lambda ib, it: (it, 0)),
            pl.BlockSpec((1, 2), lambda ib, it: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, bm), lambda ib, it: (ib, 0)),
            pl.BlockSpec((bb, bm), lambda ib, it: (ib, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb_pad, bm), jnp.float32),
            jax.ShapeDtypeStruct((nb_pad, bm), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bb, bm), jnp.float32),
            pltpu.VMEM((bb, bm), jnp.float32),
        ],
        interpret=interpret,
    )(
        data,
        jnp.stack(
            [jnp.asarray(seed, jnp.uint32), jnp.asarray(start, jnp.uint32)]
        ).reshape(1, 2),
    )
    return swx[:n_boot, :m], sw[:n_boot, :m]
