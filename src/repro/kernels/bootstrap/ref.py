"""Pure-jnp oracle for the Poisson-bootstrap kernel.

Implements the *identical* counter-based RNG (xorshift-mix) and Poisson(1)
inverse-CDF lookup as the kernel, in plain jnp — kernel vs ref must agree
bit-for-bit on the weights and to float tolerance on the means.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# cumulative Poisson(1) probabilities for k = 0..6 (k=7 tail mass ~8e-5)
POISSON1_CDF = (
    0.36787944117144233,
    0.7357588823428847,
    0.9196986029286058,
    0.9810118431238462,
    0.9963401531726563,
    0.9994058151824183,
    0.9999167588507119,
)


def mix_bits(boot: jax.Array, pos: jax.Array, seed: jax.Array) -> jax.Array:
    """Counter-based 32-bit mixer (murmur3-style finalizer over a seeded
    combination of the bootstrap-row and position counters)."""
    u32 = jnp.uint32
    h = (
        boot.astype(u32) * u32(0x9E3779B1)
        ^ pos.astype(u32) * u32(0x85EBCA77)
        ^ seed.astype(u32)
    )
    h = h ^ (h >> u32(16))
    h = h * u32(0x85EBCA6B)
    h = h ^ (h >> u32(13))
    h = h * u32(0xC2B2AE35)
    h = h ^ (h >> u32(16))
    return h


def poisson1_weight(bits: jax.Array) -> jax.Array:
    """Map uniform u32 bits -> Poisson(1) draw via inverse CDF (k <= 7)."""
    u = (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))
    w = jnp.zeros_like(u)
    for c in POISSON1_CDF:
        w = w + (u >= jnp.float32(c)).astype(jnp.float32)
    return w


def bootstrap_means_ref(
    data: jax.Array,  # (n,) f32
    n_boot: int,
    seed: int,
) -> jax.Array:
    """(n_boot,) Poisson-bootstrap resample means."""
    n = data.shape[0]
    boot = jnp.arange(n_boot, dtype=jnp.uint32)[:, None]
    pos = jnp.arange(n, dtype=jnp.uint32)[None, :]
    bits = mix_bits(boot, pos, jnp.uint32(seed))
    w = poisson1_weight(bits)  # (n_boot, n)
    sum_wx = w @ data.astype(jnp.float32)
    sum_w = jnp.sum(w, axis=1)
    return sum_wx / jnp.maximum(sum_w, 1.0)


#: row-block size of the blocked reference; fixed so the float accumulation
#: order (and therefore the partials, bit-for-bit) is reproducible across runs
DEFAULT_BLOCK_N = 1024


@functools.partial(jax.jit, static_argnames=("n_boot", "block_n"))
def bootstrap_partials_ref(
    scores: jax.Array,  # (n, m) — NaN marks unscorable examples
    seed: jax.Array,    # () uint32
    start: jax.Array,   # () uint32 — absolute offset of row 0
    *,
    n_boot: int,
    block_n: int = DEFAULT_BLOCK_N,
) -> tuple[jax.Array, jax.Array]:
    """Blocked oracle for the chunked-partials kernel: ``(sum w*x, sum w)``
    replicate pairs of shape ``(n_boot, m)``.

    The weight for (replicate b, example p) depends only on
    ``(seed, start + p, b)`` through :func:`mix_bits`, so partials computed
    over *any* chunking of a dataset merge into the same replicates —
    order-independent and resume-safe.  NaN scores get weight zero
    per-metric (they stay out of both ``sum w*x`` and ``sum w``), matching
    the host path's NaN filtering.  Streams ``block_n`` rows at a time:
    peak memory is O(n_boot x block_n), never the (B, n) weight matrix.
    """
    n, m = scores.shape
    n_blocks = (n + block_n - 1) // block_n
    pad = n_blocks * block_n - n
    x = jnp.pad(
        scores.astype(jnp.float32), ((0, pad), (0, 0)),
        constant_values=jnp.nan,  # padded rows are masked like NaN scores
    ).reshape(n_blocks, block_n, m)
    boot = jnp.arange(n_boot, dtype=jnp.uint32)[:, None]
    offs = jnp.arange(block_n, dtype=jnp.uint32)[None, :]

    def body(carry, blk):
        swx, sw = carry
        xb, ib = blk
        pos = jnp.uint32(start) + ib * jnp.uint32(block_n) + offs
        w = poisson1_weight(mix_bits(boot, pos, jnp.uint32(seed)))
        valid = ~jnp.isnan(xb)               # (block_n, m), per-metric mask
        swx = swx + w @ jnp.where(valid, xb, 0.0)
        sw = sw + w @ valid.astype(jnp.float32)
        return (swx, sw), None

    init = (
        jnp.zeros((n_boot, m), jnp.float32),
        jnp.zeros((n_boot, m), jnp.float32),
    )
    (swx, sw), _ = jax.lax.scan(
        body, init, (x, jnp.arange(n_blocks, dtype=jnp.uint32))
    )
    return swx, sw
