from repro.kernels.bootstrap.bootstrap import bootstrap_means
from repro.kernels.bootstrap.ops import bootstrap_ci, bootstrap_partials
from repro.kernels.bootstrap.ref import (
    bootstrap_means_ref,
    bootstrap_partials_ref,
    mix_bits,
    poisson1_weight,
)

__all__ = [
    "bootstrap_ci",
    "bootstrap_means",
    "bootstrap_means_ref",
    "bootstrap_partials",
    "bootstrap_partials_ref",
    "mix_bits",
    "poisson1_weight",
]
