from repro.kernels.bootstrap.bootstrap import bootstrap_means
from repro.kernels.bootstrap.ops import bootstrap_ci
from repro.kernels.bootstrap.ref import bootstrap_means_ref, mix_bits, poisson1_weight

__all__ = [
    "bootstrap_ci",
    "bootstrap_means",
    "bootstrap_means_ref",
    "mix_bits",
    "poisson1_weight",
]
