"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper / path selection), ref.py (pure-jnp oracle for allclose tests).
"""
