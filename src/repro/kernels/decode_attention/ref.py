"""Pure-jnp oracles for the GQA decode-attention kernels (contiguous,
paged, and int8-quantized paged), including blocked oracles that mirror
the kernels' page-at-a-time online-softmax recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.quant import dequantize_pages

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def decode_attention_ref(
    q: jax.Array,        # (B, K, G, d) — query heads grouped per KV head
    k_cache: jax.Array,  # (B, K, S, d)
    v_cache: jax.Array,  # (B, K, S, d)
    lengths: jax.Array,  # (B,) int32 — valid cache positions per sequence
    *,
    scale: float | None = None,
) -> jax.Array:
    b, kh, g, d = q.shape
    s = k_cache.shape[2]
    if scale is None:
        scale = d**-0.5
    scores = jnp.einsum(
        "bkgd,bksd->bkgs", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    valid = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", probs, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def gather_pages_ref(
    pages: jax.Array,        # (P, K, ps, d) — global page pool
    page_tables: jax.Array,  # (B, nP) int32
) -> jax.Array:
    """Materialize the contiguous (B, K, nP*ps, d) view of a paged cache."""
    _, kh, ps, d = pages.shape
    b, n_p = page_tables.shape
    g = pages[page_tables]                 # (B, nP, K, ps, d)
    g = jnp.moveaxis(g, 1, 2)              # (B, K, nP, ps, d)
    return g.reshape(b, kh, n_p * ps, d)


def paged_decode_attention_ref(
    q: jax.Array,            # (B, K, G, d)
    k_pages: jax.Array,      # (P, K, ps, d)
    v_pages: jax.Array,      # (P, K, ps, d)
    page_tables: jax.Array,  # (B, nP) int32
    lengths: jax.Array,      # (B,) int32
    *,
    scale: float | None = None,
) -> jax.Array:
    """Dense oracle: gather pages into a contiguous cache, then run the
    contiguous reference."""
    k = gather_pages_ref(k_pages, page_tables)
    v = gather_pages_ref(v_pages, page_tables)
    return decode_attention_ref(q, k, v, lengths, scale=scale)


def paged_decode_attention_blocked_ref(
    q: jax.Array,            # (B, K, G, d)
    k_pages: jax.Array,      # (P, K, ps, d)
    v_pages: jax.Array,      # (P, K, ps, d)
    page_tables: jax.Array,  # (B, nP) int32
    lengths: jax.Array,      # (B,) int32
    *,
    scale: float | None = None,
) -> jax.Array:
    """Blocked oracle: replays the kernel's page-at-a-time online-softmax
    recurrence in jnp (same m/l/acc update order), so a kernel bug in the
    recurrence itself cannot hide behind softmax re-normalization."""
    b, kh, g, d = q.shape
    ps = k_pages.shape[2]
    n_p = page_tables.shape[1]
    if scale is None:
        scale = d**-0.5
    qf = q.astype(jnp.float32)
    m = jnp.full((b, kh, g), NEG_INF, jnp.float32)
    l = jnp.zeros((b, kh, g), jnp.float32)
    acc = jnp.zeros((b, kh, g, d), jnp.float32)
    for i_p in range(n_p):
        k = k_pages[page_tables[:, i_p]].astype(jnp.float32)  # (B, K, ps, d)
        v = v_pages[page_tables[:, i_p]].astype(jnp.float32)
        s = jnp.einsum("bkgd,bksd->bkgs", qf, k) * scale
        pos = i_p * ps + jnp.arange(ps)[None, None, None, :]
        s = jnp.where(pos < lengths[:, None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgs,bksd->bkgd", p, v)
        m = m_new
    out = acc / jnp.maximum(l, 1e-37)[..., None]
    return out.astype(q.dtype)


def quant_paged_decode_attention_ref(
    q: jax.Array,         # (B, K, G, d)
    k_pages: jax.Array,   # (P, K, ps, d) int8
    v_pages: jax.Array,   # (P, K, ps, d) int8
    k_scales: jax.Array,  # (P, K) f32
    v_scales: jax.Array,  # (P, K) f32
    page_tables: jax.Array,  # (B, nP) int32
    lengths: jax.Array,   # (B,) int32
    *,
    scale: float | None = None,
) -> jax.Array:
    """Dense oracle: dequantize the whole pool, then run the paged
    reference — exactly what the kernel must match, since in-kernel
    dequant uses the same per-(page, head) scales elementwise."""
    return paged_decode_attention_ref(
        q,
        dequantize_pages(k_pages, k_scales),
        dequantize_pages(v_pages, v_scales),
        page_tables,
        lengths,
        scale=scale,
    )


def quant_paged_decode_attention_blocked_ref(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    k_scales: jax.Array,
    v_scales: jax.Array,
    page_tables: jax.Array,
    lengths: jax.Array,
    *,
    scale: float | None = None,
) -> jax.Array:
    """Blocked oracle: the kernel's page-at-a-time recurrence with the
    dequant applied per gathered tile (same order of operations as the
    kernel body: gather int8, scale, then the m/l/acc update)."""
    b, kh, g, d = q.shape
    ps = k_pages.shape[2]
    n_p = page_tables.shape[1]
    if scale is None:
        scale = d**-0.5
    qf = q.astype(jnp.float32)
    m = jnp.full((b, kh, g), NEG_INF, jnp.float32)
    l = jnp.zeros((b, kh, g), jnp.float32)
    acc = jnp.zeros((b, kh, g, d), jnp.float32)
    for i_p in range(n_p):
        tab = page_tables[:, i_p]
        ks = k_scales[tab][:, :, None, None]  # (B, K, 1, 1)
        vs = v_scales[tab][:, :, None, None]
        k = k_pages[tab].astype(jnp.float32) * ks  # (B, K, ps, d)
        v = v_pages[tab].astype(jnp.float32) * vs
        s = jnp.einsum("bkgd,bksd->bkgs", qf, k) * scale
        pos = i_p * ps + jnp.arange(ps)[None, None, None, :]
        s = jnp.where(pos < lengths[:, None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgs,bksd->bkgd", p, v)
        m = m_new
    out = acc / jnp.maximum(l, 1e-37)[..., None]
    return out.astype(q.dtype)
