"""Pure-jnp oracle for the GQA decode-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def decode_attention_ref(
    q: jax.Array,        # (B, K, G, d) — query heads grouped per KV head
    k_cache: jax.Array,  # (B, K, S, d)
    v_cache: jax.Array,  # (B, K, S, d)
    lengths: jax.Array,  # (B,) int32 — valid cache positions per sequence
    *,
    scale: float | None = None,
) -> jax.Array:
    b, kh, g, d = q.shape
    s = k_cache.shape[2]
    if scale is None:
        scale = d**-0.5
    scores = jnp.einsum(
        "bkgd,bksd->bkgs", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    valid = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", probs, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)
