from repro.kernels.decode_attention.decode_attention import decode_attention
from repro.kernels.decode_attention.ops import (
    decode_attention_bshd,
    paged_decode_attention_bshd,
)
from repro.kernels.decode_attention.paged import paged_decode_attention
from repro.kernels.decode_attention.ref import (
    decode_attention_ref,
    gather_pages_ref,
    paged_decode_attention_blocked_ref,
    paged_decode_attention_ref,
)

__all__ = [
    "decode_attention",
    "decode_attention_bshd",
    "decode_attention_ref",
    "gather_pages_ref",
    "paged_decode_attention",
    "paged_decode_attention_bshd",
    "paged_decode_attention_blocked_ref",
    "paged_decode_attention_ref",
]
