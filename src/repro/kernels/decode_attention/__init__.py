from repro.kernels.decode_attention.decode_attention import decode_attention
from repro.kernels.decode_attention.ops import (
    decode_attention_bshd,
    paged_decode_attention_bshd,
    quant_paged_decode_attention_bshd,
)
from repro.kernels.decode_attention.paged import paged_decode_attention
from repro.kernels.decode_attention.paged_quant import (
    quant_paged_decode_attention,
)
from repro.kernels.decode_attention.quant import (
    absmax_dequantize,
    absmax_quantize,
    dequantize_pages,
    kv_page_bytes,
    quantize_pages,
)
from repro.kernels.decode_attention.ref import (
    decode_attention_ref,
    gather_pages_ref,
    paged_decode_attention_blocked_ref,
    paged_decode_attention_ref,
    quant_paged_decode_attention_blocked_ref,
    quant_paged_decode_attention_ref,
)

__all__ = [
    "absmax_dequantize",
    "absmax_quantize",
    "decode_attention",
    "decode_attention_bshd",
    "decode_attention_ref",
    "dequantize_pages",
    "gather_pages_ref",
    "kv_page_bytes",
    "paged_decode_attention",
    "paged_decode_attention_bshd",
    "paged_decode_attention_blocked_ref",
    "paged_decode_attention_ref",
    "quant_paged_decode_attention",
    "quant_paged_decode_attention_blocked_ref",
    "quant_paged_decode_attention_bshd",
    "quant_paged_decode_attention_ref",
    "quantize_pages",
]
