"""Model-facing wrapper for the decode-attention kernel.

Model layout: q (B, 1, H, d), cache (B, S, K, d), positions (B,) — the
position of the *current* token; valid length = position + 1.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import decode_attention
from repro.kernels.decode_attention.paged import paged_decode_attention
from repro.kernels.decode_attention.paged_quant import (
    quant_paged_decode_attention,
)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def decode_attention_bshd(
    q: jax.Array,        # (B, 1, H, d)
    k_cache: jax.Array,  # (B, S, K, d)
    v_cache: jax.Array,  # (B, S, K, d)
    positions: jax.Array,  # (B,)
    *,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    b, _, h, d = q.shape
    kh = k_cache.shape[2]
    g = h // kh
    qg = q[:, 0].reshape(b, kh, g, d)
    kt = jnp.transpose(k_cache, (0, 2, 1, 3))
    vt = jnp.transpose(v_cache, (0, 2, 1, 3))
    out = decode_attention(
        qg, kt, vt, (positions + 1).astype(jnp.int32),
        scale=scale, interpret=interpret,
    )
    return out.reshape(b, 1, h, d)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode_attention_bshd(
    q: jax.Array,            # (B, 1, H, d)
    k_pages: jax.Array,      # (P, ps, K, d) — pool pages in model layout
    v_pages: jax.Array,      # (P, ps, K, d)
    page_tables: jax.Array,  # (B, nP) int32
    positions: jax.Array,    # (B,) — position of the *current* token
    *,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    b, _, h, d = q.shape
    kh = k_pages.shape[2]
    g = h // kh
    qg = q[:, 0].reshape(b, kh, g, d)
    kt = jnp.transpose(k_pages, (0, 2, 1, 3))
    vt = jnp.transpose(v_pages, (0, 2, 1, 3))
    out = paged_decode_attention(
        qg, kt, vt, page_tables, (positions + 1).astype(jnp.int32),
        scale=scale, interpret=interpret,
    )
    return out.reshape(b, 1, h, d)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def quant_paged_decode_attention_bshd(
    q: jax.Array,            # (B, 1, H, d)
    k_pages: jax.Array,      # (P, ps, K, d) int8 — pool pages, model layout
    v_pages: jax.Array,      # (P, ps, K, d) int8
    k_scales: jax.Array,     # (P, K) f32 per-(page, head) absmax scales
    v_scales: jax.Array,     # (P, K) f32
    page_tables: jax.Array,  # (B, nP) int32
    positions: jax.Array,    # (B,) — position of the *current* token
    *,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    b, _, h, d = q.shape
    kh = k_pages.shape[2]
    g = h // kh
    qg = q[:, 0].reshape(b, kh, g, d)
    kt = jnp.transpose(k_pages, (0, 2, 1, 3))
    vt = jnp.transpose(v_pages, (0, 2, 1, 3))
    out = quant_paged_decode_attention(
        qg, kt, vt, k_scales, v_scales, page_tables,
        (positions + 1).astype(jnp.int32),
        scale=scale, interpret=interpret,
    )
    return out.reshape(b, 1, h, d)
