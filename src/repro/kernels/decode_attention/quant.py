"""Symmetric absmax int8 block quantization for paged KV storage.

One quantization group per **(page, kv head)**: the scale is the absmax
over that head's ``(page_size, head_dim)`` tile divided by 127, stored
as f32 alongside the int8 page.  Chosen over finer granularities because
the scale buffer must stay negligible next to the page payload — at
``(P, K)`` f32 scales the overhead is ``4 / (page_size * head_dim)`` of
the bf16 payload (~0.4% at 16x64) — and over coarser ones because a
single outlier head must not crush every other head's resolution.

Properties the serving stack depends on:

* **deterministic** — round-half-to-even on ``x / scale``; quantized
  bytes are a pure function of the page's float content, so shared pages
  are shared quantized bytes and replica count / routing / crash-resume
  never change a stored byte at fixed dtype;
* **zero-safe** — an all-zero group gets scale 1.0 (not 0), so
  dequantization never divides by or multiplies NaNs out of empty pages;
* **bounded** — round-trip error per element is at most ``scale / 2 =
  absmax / 254`` of its group (tested by hypothesis in
  ``tests/test_paged_cache.py``).

The generic ``absmax_quantize`` / ``absmax_dequantize`` pair works on any
layout given the group axes; ``quantize_pages`` fixes the kernel-suite
pool layout ``(P, K, page_size, d)`` -> scales ``(P, K)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

#: int8 symmetric range: [-127, 127] (avoid -128 so negation is closed)
QMAX = 127.0


def kv_page_bytes(page_size, kv_heads, head_dim, n_layers, kv_cache_dtype="bf16"):
    """Bytes per KV page — canonical formula lives with the host-side pool
    accounting in :func:`repro.serve.paged_cache.kv_page_bytes`; deferred
    import because the scheduler (pulled in by ``repro.serve``) imports
    this module at load time."""
    from repro.serve.paged_cache import kv_page_bytes as _impl

    return _impl(page_size, kv_heads, head_dim, n_layers, kv_cache_dtype)


def _norm_axes(ndim: int, axes: tuple[int, ...]) -> tuple[int, ...]:
    return tuple(sorted(a % ndim for a in axes))


def absmax_quantize(
    x: jax.Array,
    group_axes: tuple[int, ...],
    *,
    mask: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Quantize ``x`` to int8 with one f32 scale per quantization group.

    ``group_axes`` are reduced away in the scale (one scale per remaining
    index).  ``mask`` (broadcastable to ``x``) zeroes elements *before*
    the absmax and the store — used to keep stale rows of a partially
    filled page out of both the scale and the stored bytes, so quantized
    content is a pure function of the valid token history.
    """
    axes = _norm_axes(x.ndim, group_axes)
    xf = x.astype(jnp.float32)
    if mask is not None:
        xf = jnp.where(mask, xf, 0.0)
    absmax = jnp.max(jnp.abs(xf), axis=axes)
    scale = jnp.where(absmax > 0.0, absmax / QMAX, 1.0)
    q = jnp.round(xf / jnp.expand_dims(scale, axes))
    q = jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)
    return q, scale


def absmax_dequantize(
    q: jax.Array,
    scale: jax.Array,
    group_axes: tuple[int, ...],
    *,
    dtype: jnp.dtype = jnp.float32,
) -> jax.Array:
    """Inverse of :func:`absmax_quantize` (up to rounding)."""
    axes = _norm_axes(q.ndim, group_axes)
    return (
        q.astype(jnp.float32) * jnp.expand_dims(scale, axes)
    ).astype(dtype)


def quantize_pages(pages: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Kernel-suite pool layout: ``(P, K, ps, d)`` -> int8 pages plus
    ``(P, K)`` f32 scales (one group per page per KV head)."""
    return absmax_quantize(pages, (2, 3))


def dequantize_pages(
    q_pages: jax.Array, scales: jax.Array, *, dtype: jnp.dtype = jnp.float32
) -> jax.Array:
    """Inverse of :func:`quantize_pages`."""
    return absmax_dequantize(q_pages, scales, (2, 3), dtype=dtype)
