"""Quantized paged GQA decode-attention Pallas TPU kernel.

Identical gather/online-softmax structure to ``paged.py``, but the pool
pages arrive as **int8** with per-(page, head) f32 scales
(``quant.quantize_pages``) and are dequantized *inside the kernel body*:
the page table gather moves int8 bytes HBM -> VMEM, the scale rides in a
``(1, 1)`` block selected by the same prefetched table entry, and the
``q * s`` dequant happens on the VPU right before the MXU contractions.
Full-precision K/V therefore never materialize in HBM — the bandwidth
(and the pool residency) of the paged decode path halves.

* grid = (B, K, nP), page axis innermost (sequential on TPU) so the
  online-softmax scratch survives across one sequence's pages;
* scales use the same padding convention as the tables: padding entries
  address pool page 0, whose scale is live data — the length mask zeroes
  the padded positions' contribution exactly, so the fetched-but-masked
  scale value is irrelevant;
* the dequantized tile is (page_size, d) f32 in VMEM/registers only —
  the int8 -> f32 widening is per-tile, never per-pool.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _quant_paged_kernel(
    tables_ref,    # SMEM (B, nP) int32 — scalar prefetch
    lengths_ref,   # SMEM (B,) int32 — scalar prefetch
    q_ref,         # (1, 1, G, d)
    k_ref,         # (1, 1, ps, d) int8 — pool page selected by index map
    v_ref,         # (1, 1, ps, d) int8
    k_scale_ref,   # (1, 1) f32 — per-(page, head) absmax scale
    v_scale_ref,   # (1, 1) f32
    o_ref,         # (1, 1, G, d)
    m_ref,         # VMEM (G, 1) f32
    l_ref,         # VMEM (G, 1) f32
    acc_ref,       # VMEM (G, d) f32
    *,
    scale: float,
    page_size: int,
    n_pages: int,
):
    b = pl.program_id(0)
    i_p = pl.program_id(2)

    @pl.when(i_p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, d)
    # in-kernel dequant: int8 page tile * its (page, head) scale
    k = k_ref[0, 0].astype(jnp.float32) * k_scale_ref[0, 0]  # (ps, d)
    v = v_ref[0, 0].astype(jnp.float32) * v_scale_ref[0, 0]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (G, ps)

    length = lengths_ref[b]
    pos = i_p * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < length, s, NEG_INF)

    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[:, 0] = l_ref[:, 0] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[:, 0] = m_new

    @pl.when(i_p == n_pages - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:, 0], 1e-37)[:, None]
        o_ref[0, 0, :, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def quant_paged_decode_attention(
    q: jax.Array,         # (B, K, G, d)
    k_pages: jax.Array,   # (P, K, ps, d) int8 — quantized page pool
    v_pages: jax.Array,   # (P, K, ps, d) int8
    k_scales: jax.Array,  # (P, K) f32 — per-(page, head) absmax scales
    v_scales: jax.Array,  # (P, K) f32
    page_tables: jax.Array,  # (B, nP) int32 — pool index per sequence page
    lengths: jax.Array,   # (B,) int32 — valid token count per sequence
    *,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    b, kh, g, d = q.shape
    p_pool, kh2, page_size, d2 = k_pages.shape
    assert (kh2, d2) == (kh, d), (k_pages.shape, q.shape)
    assert k_scales.shape == (p_pool, kh), (k_scales.shape, k_pages.shape)
    assert page_tables.shape[0] == b, (page_tables.shape, b)
    n_pages = page_tables.shape[1]
    if scale is None:
        scale = d**-0.5

    kernel = functools.partial(
        _quant_paged_kernel, scale=scale, page_size=page_size, n_pages=n_pages
    )
    page_spec = pl.BlockSpec(
        (1, 1, page_size, d),
        lambda b_, k_, ip_, tabs, lens: (tabs[b_, ip_], k_, 0, 0),
    )
    scale_spec = pl.BlockSpec(
        (1, 1), lambda b_, k_, ip_, tabs, lens: (tabs[b_, ip_], k_)
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kh, n_pages),
        in_specs=[
            pl.BlockSpec(
                (1, 1, g, d), lambda b_, k_, ip_, tabs, lens: (b_, k_, 0, 0)
            ),
            page_spec,
            page_spec,
            scale_spec,
            scale_spec,
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g, d), lambda b_, k_, ip_, tabs, lens: (b_, k_, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, g, d), q.dtype),
        interpret=interpret,
    )(
        page_tables.astype(jnp.int32),
        lengths.astype(jnp.int32),
        q,
        k_pages,
        v_pages,
        k_scales.astype(jnp.float32),
        v_scales.astype(jnp.float32),
    )
