"""GQA decode-attention Pallas TPU kernel.

Decode is HBM-bandwidth-bound: one query token per sequence against a long
KV cache.  TPU-native design (DESIGN.md §6):

* grid = (B, K, nS) with the cache-sequence axis innermost (sequential on
  TPU), so the online-softmax state for the whole **query-head group** lives
  in VMEM scratch across cache tiles;
* each KV tile is read from HBM exactly once and shared by all G query
  heads of its group (GQA grouping in-kernel, not via head replication);
* per-sequence valid lengths arrive as a scalar-prefetch operand so ragged
  continuous-batching batches mask correctly;
* f32 accumulators, bf16/f32 inputs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(
    lengths_ref,  # SMEM (B,) int32 — scalar prefetch
    q_ref,        # (1, 1, G, d)
    k_ref,        # (1, 1, bs, d)
    v_ref,        # (1, 1, bs, d)
    o_ref,        # (1, 1, G, d)
    m_ref,        # VMEM (G, 1) f32
    l_ref,        # VMEM (G, 1) f32
    acc_ref,      # VMEM (G, d) f32
    *,
    scale: float,
    block_s: int,
    n_s: int,
):
    b = pl.program_id(0)
    i_s = pl.program_id(2)

    @pl.when(i_s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, d)
    k = k_ref[0, 0].astype(jnp.float32)  # (bs, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (G, bs)

    length = lengths_ref[b]
    pos = i_s * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < length, s, NEG_INF)

    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[:, 0] = l_ref[:, 0] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[:, 0] = m_new

    @pl.when(i_s == n_s - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:, 0], 1e-37)[:, None]
        o_ref[0, 0, :, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_s", "interpret")
)
def decode_attention(
    q: jax.Array,        # (B, K, G, d)
    k_cache: jax.Array,  # (B, K, S, d)
    v_cache: jax.Array,  # (B, K, S, d)
    lengths: jax.Array,  # (B,) int32
    *,
    scale: float | None = None,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, kh, g, d = q.shape
    s = k_cache.shape[2]
    if scale is None:
        scale = d**-0.5
    block_s = min(block_s, s)
    assert s % block_s == 0, (s, block_s)
    n_s = s // block_s

    kernel = functools.partial(
        _kernel, scale=scale, block_s=block_s, n_s=n_s
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kh, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, k_, is_, lens: (b_, k_, 0, 0)),
            pl.BlockSpec(
                (1, 1, block_s, d), lambda b_, k_, is_, lens: (b_, k_, is_, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_s, d), lambda b_, k_, is_, lens: (b_, k_, is_, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g, d), lambda b_, k_, is_, lens: (b_, k_, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, g, d), q.dtype),
        interpret=interpret,
    )(lengths, q, k_cache, v_cache)
