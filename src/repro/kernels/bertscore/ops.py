"""BERTScore wrapper with the F1 epilogue; selects kernel or jnp path."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bertscore.bertscore import bertscore_pr
from repro.kernels.bertscore.ref import bertscore_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def bertscore(
    cand: jax.Array,
    ref: jax.Array,
    cand_mask: jax.Array,
    ref_mask: jax.Array,
    *,
    use_pallas: bool = False,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(P, R, F1) per example."""
    if use_pallas:
        p, r = bertscore_pr(
            cand, ref, cand_mask, ref_mask, interpret=interpret
        )
        f1 = 2 * p * r / jnp.maximum(p + r, 1e-9)
        return p, r, f1
    return bertscore_ref(cand, ref, cand_mask, ref_mask)
