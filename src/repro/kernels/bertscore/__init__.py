from repro.kernels.bertscore.bertscore import bertscore_pr
from repro.kernels.bertscore.ops import bertscore
from repro.kernels.bertscore.ref import bertscore_ref

__all__ = ["bertscore", "bertscore_pr", "bertscore_ref"]
