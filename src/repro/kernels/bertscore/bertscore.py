"""BERTScore greedy-matching Pallas TPU kernel.

Token-pair similarity is an (Lc x D) . (D x Lr) matmul — MXU work — followed
by masked row/column maxima and mean reductions.  TPU-native design
(DESIGN.md §6): grid = (B, nLr) with the ref-length axis innermost; one
program holds the candidate tile (Lc x D) and one ref tile (bLr x D) in
VMEM, accumulates the running row-max (over ref tiles) in VMEM scratch and
the column-max means incrementally; P/R emit on the last tile.  The F1
epilogue lives in ops.py.

Embeddings are normalized in-kernel (rsqrt of row norms) so the matmul
computes cosine similarity directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _normalize(x: jax.Array) -> jax.Array:
    norm2 = jnp.sum(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(jnp.maximum(norm2, 1e-18))


def _kernel(
    cand_ref,   # (1, Lc, D)
    cmask_ref,  # (1, Lc)
    refs_ref,   # (1, bLr, D)
    rmask_ref,  # (1, bLr)
    p_ref,      # (1, 1) out — precision
    r_ref,      # (1, 1) out — recall
    rowmax_ref,  # VMEM (Lc, 1) f32 — running max over ref tiles
    colsum_ref,  # VMEM (1, 1) f32 — sum of col maxima (ref tokens)
    colcnt_ref,  # VMEM (1, 1) f32 — count of valid ref tokens
    *,
    n_tiles: int,
):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        rowmax_ref[...] = jnp.full_like(rowmax_ref, NEG_INF)
        colsum_ref[...] = jnp.zeros_like(colsum_ref)
        colcnt_ref[...] = jnp.zeros_like(colcnt_ref)

    c = _normalize(cand_ref[0].astype(jnp.float32))   # (Lc, D)
    r = _normalize(refs_ref[0].astype(jnp.float32))   # (bLr, D)
    cm = cmask_ref[0].astype(jnp.float32) > 0.5       # (Lc,)
    rm = rmask_ref[0].astype(jnp.float32) > 0.5       # (bLr,)

    sim = jax.lax.dot_general(
        c, r, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Lc, bLr)
    sim = jnp.where(cm[:, None] & rm[None, :], sim, NEG_INF)

    rowmax_ref[:, 0] = jnp.maximum(rowmax_ref[:, 0], jnp.max(sim, axis=1))
    col_max = jnp.max(sim, axis=0)  # (bLr,)
    colsum_ref[0, 0] += jnp.sum(jnp.where(rm, col_max, 0.0))
    colcnt_ref[0, 0] += jnp.sum(rm.astype(jnp.float32))

    @pl.when(it == n_tiles - 1)
    def _final():
        cmf = cm.astype(jnp.float32)
        denom_c = jnp.maximum(jnp.sum(cmf), 1.0)
        p_ref[0, 0] = jnp.sum(
            jnp.where(cm, rowmax_ref[:, 0], 0.0)
        ) / denom_c
        r_ref[0, 0] = colsum_ref[0, 0] / jnp.maximum(colcnt_ref[0, 0], 1.0)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def bertscore_pr(
    cand: jax.Array,       # (B, Lc, D)
    ref: jax.Array,        # (B, Lr, D)
    cand_mask: jax.Array,  # (B, Lc)
    ref_mask: jax.Array,   # (B, Lr)
    *,
    block_r: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    b, lc, d = cand.shape
    lr = ref.shape[1]
    br = min(block_r, lr)
    n_tiles = (lr + br - 1) // br
    pad = n_tiles * br - lr
    if pad:
        ref = jnp.pad(ref, ((0, 0), (0, pad), (0, 0)))
        ref_mask = jnp.pad(ref_mask, ((0, 0), (0, pad)))

    kernel = functools.partial(_kernel, n_tiles=n_tiles)
    p, r = pl.pallas_call(
        kernel,
        grid=(b, n_tiles),
        in_specs=[
            pl.BlockSpec((1, lc, d), lambda ib, it: (ib, 0, 0)),
            pl.BlockSpec((1, lc), lambda ib, it: (ib, 0)),
            pl.BlockSpec((1, br, d), lambda ib, it: (ib, it, 0)),
            pl.BlockSpec((1, br), lambda ib, it: (ib, it)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda ib, it: (ib, 0)),
            pl.BlockSpec((1, 1), lambda ib, it: (ib, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((lc, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        cand,
        cand_mask.astype(jnp.float32),
        ref,
        ref_mask.astype(jnp.float32),
    )
    return p[:, 0], r[:, 0]
