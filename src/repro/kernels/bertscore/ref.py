"""Pure-jnp oracle for the BERTScore greedy-matching kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def bertscore_ref(
    cand: jax.Array,       # (B, Lc, D) token embeddings (need not be normalized)
    ref: jax.Array,        # (B, Lr, D)
    cand_mask: jax.Array,  # (B, Lc) bool/0-1
    ref_mask: jax.Array,   # (B, Lr)
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(P, R, F1) per example — greedy max-cosine matching."""
    f32 = jnp.float32
    c = cand.astype(f32)
    r = ref.astype(f32)
    c = c / jnp.maximum(jnp.linalg.norm(c, axis=-1, keepdims=True), 1e-9)
    r = r / jnp.maximum(jnp.linalg.norm(r, axis=-1, keepdims=True), 1e-9)
    sim = jnp.einsum("bcd,brd->bcr", c, r)  # (B, Lc, Lr)
    cm = cand_mask.astype(bool)
    rm = ref_mask.astype(bool)
    sim = jnp.where(cm[:, :, None] & rm[:, None, :], sim, NEG_INF)

    row_max = jnp.max(sim, axis=2)  # best ref per cand token
    col_max = jnp.max(sim, axis=1)  # best cand per ref token
    p = jnp.sum(jnp.where(cm, row_max, 0.0), axis=1) / jnp.maximum(
        jnp.sum(cm, axis=1), 1
    )
    r_ = jnp.sum(jnp.where(rm, col_max, 0.0), axis=1) / jnp.maximum(
        jnp.sum(rm, axis=1), 1
    )
    f1 = 2 * p * r_ / jnp.maximum(p + r_, 1e-9)
    return p, r_, f1
