"""Wrapper selecting the SSD execution path.

``use_pallas=False`` (default on CPU) routes to the chunked jnp
implementation in ``models/ssm.py``; ``use_pallas=True`` calls the Mosaic
kernel (``interpret=True`` for CPU validation).
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.ssd.ssd import ssd as ssd_pallas
from repro.models.ssm import ssd_chunked


@functools.partial(
    jax.jit, static_argnames=("chunk", "use_pallas", "interpret")
)
def ssd_apply(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b_mat: jax.Array,
    c_mat: jax.Array,
    *,
    chunk: int = 256,
    use_pallas: bool = False,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    if use_pallas:
        return ssd_pallas(x, dt, a, b_mat, c_mat, chunk=chunk, interpret=interpret)
    return ssd_chunked(x, dt, a, b_mat, c_mat, chunk)
