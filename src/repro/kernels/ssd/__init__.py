from repro.kernels.ssd.ops import ssd_apply
from repro.kernels.ssd.ref import ssd_ref
from repro.kernels.ssd.ssd import ssd

__all__ = ["ssd", "ssd_apply", "ssd_ref"]
