"""Mamba2 SSD Pallas TPU kernel (chunked state-space-dual form).

TPU-native design (DESIGN.md §6): grid = (B, H, nc) with the chunk axis
innermost/sequential.  Each program holds one (chunk x P) x-tile and one
(chunk x N) B/C-tile in VMEM, computes the intra-chunk quadratic form on
the MXU (segsum-decayed "attention" matrix), and carries the running
(P x N) state in VMEM scratch across chunks — the inter-chunk linear
recurrence never touches HBM.

Inputs are pre-projected per head; dt is post-softplus.  Outputs both the
sequence y and the final state (for prefill -> decode handoff).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    x_ref,    # (1, bc, 1, P)
    dt_ref,   # (1, bc, 1)
    a_ref,    # (1, 1) — per-head decay rate (SMEM-ish tiny block)
    b_ref,    # (1, bc, 1, N)
    c_ref,    # (1, bc, 1, N)
    y_ref,    # (1, bc, 1, P) out
    fs_ref,   # (1, 1, P, N) out — final state
    state_ref,  # VMEM scratch (P, N) f32
    *,
    n_chunks: int,
    chunk: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # (bc, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # (bc,)
    a = a_ref[0, 0]                                 # ()
    bm = b_ref[0, :, 0, :].astype(jnp.float32)      # (bc, N)
    cm = c_ref[0, :, 0, :].astype(jnp.float32)      # (bc, N)

    da = dt * a                                     # (bc,)
    cum = jnp.cumsum(da)                            # (bc,)
    xdt = x * dt[:, None]                           # (bc, P)

    # intra-chunk quadratic form: L[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(tri, jnp.exp(diff), 0.0)       # (bc, bc)
    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (bc, bc)
    y_diag = jax.lax.dot_general(
        scores * lmat, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                               # (bc, P)

    # cross-chunk: contribution of the entering state
    state = state_ref[...]                          # (P, N)
    y_off = jax.lax.dot_general(
        cm, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(cum)[:, None]                       # (bc, P)

    y_ref[0, :, 0, :] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: decay whole chunk + inject chunk contributions
    decay_states = jnp.exp(cum[-1] - cum)           # (bc,)
    contrib = jax.lax.dot_general(
        xdt * decay_states[:, None], bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                               # (P, N)
    state_ref[...] = state * jnp.exp(cum[-1]) + contrib

    @pl.when(ic == n_chunks - 1)
    def _final():
        fs_ref[0, 0, :, :] = state_ref[...].astype(fs_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(
    x: jax.Array,   # (B, L, H, P)
    dt: jax.Array,  # (B, L, H)
    a: jax.Array,   # (H,)
    b_mat: jax.Array,  # (B, L, H, N)
    c_mat: jax.Array,  # (B, L, H, N)
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    bsz, slen, h, p = x.shape
    n = b_mat.shape[-1]
    chunk = min(chunk, slen)
    assert slen % chunk == 0, (slen, chunk)
    nc = slen // chunk

    kernel = functools.partial(_kernel, n_chunks=nc, chunk=chunk)
    a2d = a.reshape(h, 1).astype(jnp.float32)

    y, final_state = pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b_, h_, c_: (b_, c_, h_)),
            pl.BlockSpec((1, 1), lambda b_, h_, c_: (h_, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, c_: (b_, c_, h_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, slen, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a2d, b_mat, c_mat)
    return y, final_state
