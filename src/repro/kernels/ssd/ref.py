"""Pure-jnp oracle for the SSD (Mamba2) kernel: sequential recurrence.

y_t = C_t^T h_t,   h_t = exp(dt_t * a) * h_{t-1} + dt_t * x_t B_t^T

This is the O(L) literal recurrence — slow but unambiguous; both the
chunked jnp path (models/ssm.py) and the Pallas kernel must match it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(
    x: jax.Array,   # (B, L, H, P)
    dt: jax.Array,  # (B, L, H) — post-softplus
    a: jax.Array,   # (H,) negative
    b_mat: jax.Array,  # (B, L, H, N)
    c_mat: jax.Array,  # (B, L, H, N)
    initial_state: jax.Array | None = None,  # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    bsz, slen, h, p = x.shape
    n = b_mat.shape[-1]
    f32 = jnp.float32

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(dt_t * a)  # (B,H)
        update = jnp.einsum("bhp,bhn->bhpn", dt_t[..., None] * x_t, b_t)
        state = state * decay[..., None, None] + update
        y_t = jnp.einsum("bhpn,bhn->bhp", state, c_t)
        return state, y_t

    init = (
        initial_state.astype(f32)
        if initial_state is not None
        else jnp.zeros((bsz, h, p, n), f32)
    )
    xs = (
        jnp.moveaxis(x.astype(f32), 1, 0),
        jnp.moveaxis(dt.astype(f32), 1, 0),
        jnp.moveaxis(b_mat.astype(f32), 1, 0),
        jnp.moveaxis(c_mat.astype(f32), 1, 0),
    )
    final, ys = jax.lax.scan(step, init, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B, L, H, P)
    return y.astype(x.dtype), final
