"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) expert_ff=768
vocab=151936, 128 experts top-8, qk_norm [hf:Qwen/Qwen3-30B-A3B]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,               # per-expert hidden (as assigned)
    moe_d_ff=768,
    vocab_size=151_936,
    n_experts=128,
    n_experts_per_token=8,
    qk_norm=True,
    rope_theta=1_000_000.0,
)
