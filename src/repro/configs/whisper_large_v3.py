"""whisper-large-v3 [audio] — enc-dec, conv frontend stubbed.

32L d_model=1280 20H (kv=20, full MHA) d_ff=5120 vocab=51866
[arXiv:2212.04356]. The mel/conv frontend is a stub: ``input_specs()``
provides pre-computed frame embeddings (B, 1500, 1280).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,             # decoder layers
    n_encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51_866,
    act="gelu",
    qkv_bias=True,           # whisper projections carry biases
    pos_emb="learned",
    norm_eps=1e-5,
    encoder_seq=1500,        # 30s audio -> 3000 mel frames -> conv stride 2
    tie_embeddings=True,
)
