"""Architecture configuration schema.

One frozen dataclass covers every assigned family (dense / MoE / SSM / hybrid /
enc-dec / VLM).  Each ``src/repro/configs/<arch>.py`` instantiates it with the
exact published numbers; ``reduced()`` derives the CPU smoke-test variant of
the same family (same block wiring, tiny dims).
"""

from __future__ import annotations

import dataclasses
from typing import Any


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0  # 0 -> d_model // n_heads
    act: str = "silu"  # silu | gelu
    qkv_bias: bool = False
    qk_norm: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    rope_theta: float = 1_000_000.0
    pos_emb: str = "rope"  # rope | learned | none

    # --- MLA (multi-head latent attention) ---------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0  # 0 -> no q compression
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    n_experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0           # per-expert hidden
    first_k_dense: int = 0      # leading dense layers (deepseek-v2)
    dense_d_ff: int = 0         # hidden of those dense layers
    router_aux_weight: float = 0.001
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 SSD) ----------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 256

    # --- hybrid (zamba2) ------------------------------------------------------
    shared_attn_every: int = 0  # apply the shared attention block every N layers

    # --- enc-dec (whisper) -----------------------------------------------------
    n_encoder_layers: int = 0
    encoder_seq: int = 0        # frontend-stub frame count

    # --- VLM (paligemma) --------------------------------------------------------
    n_vision_tokens: int = 0
    embed_scale: bool = False   # gemma: scale embeddings by sqrt(d_model)

    # ------------------------------------------------------------------------

    def __post_init__(self) -> None:
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so TP-16 shards evenly."""
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid families)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw: dict[str, Any] = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads else 0,
            head_dim=16,
            d_ff=128,
            vocab_size=512,
        )
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
        if self.family == "hybrid":
            kw.update(n_layers=4, shared_attn_every=2)
        if self.n_experts:
            kw.update(n_experts=8, n_experts_per_token=2, moe_d_ff=64,
                      n_shared_experts=min(self.n_shared_experts, 1),
                      first_k_dense=min(self.first_k_dense, 1), dense_d_ff=128)
        if self.use_mla:
            kw.update(q_lora_rank=32 if self.q_lora_rank else 0,
                      kv_lora_rank=32, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16)
        if self.family == "encdec":
            kw.update(n_encoder_layers=2, encoder_seq=32)
        if self.family == "vlm":
            kw.update(n_vision_tokens=8)
        return self.replace(name=self.name + "-reduced", **kw)


# ---------------------------------------------------------------------------
# Input-shape grid assigned to this paper (LM-family shapes).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """The shape cells this architecture runs (long_500k is sub-quadratic-only)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.is_subquadratic:
        out.append("long_500k")
    return out
