"""Architecture registry: ``--arch <id>`` resolves here."""

from __future__ import annotations

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, applicable_shapes
from repro.configs.deepseek_v2_236b import CONFIG as DEEPSEEK_V2_236B
from repro.configs.mamba2_27b import CONFIG as MAMBA2_27B
from repro.configs.minicpm3_4b import CONFIG as MINICPM3_4B
from repro.configs.paligemma_3b import CONFIG as PALIGEMMA_3B
from repro.configs.qwen15_110b import CONFIG as QWEN15_110B
from repro.configs.qwen25_32b import CONFIG as QWEN25_32B
from repro.configs.qwen3_4b import CONFIG as QWEN3_4B
from repro.configs.qwen3_moe_30b_a3b import CONFIG as QWEN3_MOE_30B_A3B
from repro.configs.whisper_large_v3 import CONFIG as WHISPER_LARGE_V3
from repro.configs.zamba2_7b import CONFIG as ZAMBA2_7B

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        WHISPER_LARGE_V3,
        QWEN15_110B,
        QWEN3_4B,
        MINICPM3_4B,
        QWEN25_32B,
        ZAMBA2_7B,
        PALIGEMMA_3B,
        MAMBA2_27B,
        QWEN3_MOE_30B_A3B,
        DEEPSEEK_V2_236B,
    )
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch]


__all__ = [
    "ARCHS",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "applicable_shapes",
    "get_config",
]
