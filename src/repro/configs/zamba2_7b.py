"""zamba2-7b [hybrid] — 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000,
ssm_state=64; Mamba2 backbone + shared full-attention block
[arXiv:2411.15242].

See DESIGN.md §4.1: the shared attn+MLP block (one set of weights) is applied
after every 6th Mamba2 layer with per-application norm gains.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14_336,
    vocab_size=32_000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    shared_attn_every=6,
    rope_theta=10_000.0,
)
