"""deepseek-v2-236b [moe] — 60L d_model=5120 128H expert_ff=1536
vocab=102400; MLA kv_lora=512, 2 shared + 160 routed experts top-6,
first layer dense [arXiv:2405.04434]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,               # per-expert hidden (as assigned)
    moe_d_ff=1536,
    vocab_size=102_400,
    n_experts=160,
    n_experts_per_token=6,
    n_shared_experts=2,
    first_k_dense=1,
    dense_d_ff=12_288,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    head_dim=192,            # qk_nope + qk_rope
    rope_theta=10_000.0,
)
