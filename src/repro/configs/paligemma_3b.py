"""paligemma-3b [vlm] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=257216; SigLIP frontend stubbed (patch embeddings provided by
``input_specs``), Gemma-style decoder [arXiv:2407.07726].
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16_384,
    vocab_size=257_216,
    act="gelu",
    n_vision_tokens=256,     # 224px / 14px patches = 16x16
    embed_scale=True,        # gemma scales embeddings by sqrt(d_model)
    tie_embeddings=True,
    rope_theta=10_000.0,
)
