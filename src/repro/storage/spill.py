"""Resumable chunk spill: per-chunk partial results on a DeltaLite table.

A streaming run commits one manifest row per completed chunk — the chunk's
mergeable accumulator states (:mod:`repro.stats.streaming`), failure
sample, and stage stats — as a single DeltaLite commit.  The ACID log
gives the two properties resume needs for free:

* **atomicity** — a chunk is either fully committed (segment + log entry)
  or invisible; a driver dying mid-chunk leaves at most an orphaned,
  unreferenced segment file (crash safety inherited from DeltaLite);
* **concurrency** — two drivers racing on the same table retry through
  optimistic concurrency; duplicate rows for a chunk are resolved
  latest-wins on the ``chunk_id`` key column.

A restarted run reads the manifest, merges the committed partial states,
and skips those chunks entirely — no re-inference, no re-scoring.  Each
run is isolated under ``<root>/<run_key>`` (the task fingerprint), so a
changed task config never resumes from stale chunks.
"""

from __future__ import annotations

import os

from repro.storage.deltalite import DeltaLite


class ChunkManifest:
    #: reserved (negative) chunk_id keys for run-level adaptive metadata:
    #: the certification-regime row (stopping-rule fingerprint, written
    #: before the first chunk of an adaptive run) and the stop-decision
    #: row (written exactly once, when the rule fires).  Kept in the same
    #: ACID table as the chunk rows so a stop commit is atomic with the
    #: chunk commits it summarizes.
    REGIME_KEY = -2
    STOP_KEY = -1

    def __init__(self, root: str, run_key: str):
        self.run_key = run_key
        self.path = os.path.join(root, run_key)
        self.table = DeltaLite(self.path, key_column="chunk_id")

    def completed(self) -> dict[int, dict]:
        """chunk_id -> committed state row (latest wins on duplicates).
        Reserved metadata rows (negative ids) are excluded — read them
        through :meth:`stop_row` / :meth:`regime_row`."""
        out: dict[int, dict] = {}
        for row in self.table.read():
            if row.get("run_key") == self.run_key and int(row["chunk_id"]) >= 0:
                out[int(row["chunk_id"])] = row
        return out

    def record(self, chunk_id: int, state: dict) -> int:
        """Commit one completed chunk; returns the manifest version."""
        return self.table.append(
            [{"chunk_id": chunk_id, "run_key": self.run_key, **state}]
        )

    def try_record(self, chunk_id: int, state: dict) -> bool:
        """First-committer-wins commit for concurrent chunk workers.

        A speculatively re-issued chunk races its original attempt here:
        exactly one attempt commits a manifest row (``True``); the loser's
        row is discarded atomically by DeltaLite's conditional append
        (``False``) and its partial state must not be merged — the
        committed row is the canonical result for the chunk.
        """
        return (
            self.table.append_if_absent(
                [{"chunk_id": chunk_id, "run_key": self.run_key, **state}]
            )
            is not None
        )

    def get(self, chunk_id: int) -> dict | None:
        """Committed row for one chunk (CAS point lookup), or None."""
        row = self.table.lookup(str(chunk_id))
        if row is not None and row.get("run_key") != self.run_key:
            return None
        return row

    # -- adaptive-run metadata rows -------------------------------------------

    def regime_row(self) -> dict | None:
        """The committed certification-regime row, or None."""
        return self.get(self.REGIME_KEY)

    def try_record_regime(self, state: dict) -> bool:
        """First-committer-wins commit of the certification regime (the
        stopping-rule fingerprint).  Exactly one regime row ever exists;
        racing adaptive drivers resolve through the conditional append and
        losers re-read and validate."""
        return self.try_record(self.REGIME_KEY, state)

    def stop_row(self) -> dict | None:
        """The committed stop decision, or None (run never stopped)."""
        return self.get(self.STOP_KEY)

    def try_record_stop(self, state: dict) -> bool:
        """First-committer-wins commit of the stop decision.  The stop
        point is part of the resume contract: once committed, every resume
        terminates at exactly this chunk and never re-opens sampling."""
        return self.try_record(self.STOP_KEY, state)
