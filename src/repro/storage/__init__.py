from repro.storage.deltalite import CommitConflict, DeltaLite
from repro.storage.spill import ChunkManifest

__all__ = ["ChunkManifest", "CommitConflict", "DeltaLite"]
