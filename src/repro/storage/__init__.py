from repro.storage.deltalite import CommitConflict, DeltaLite

__all__ = ["CommitConflict", "DeltaLite"]
