"""DeltaLite: a log-structured ACID table with time travel and a CAS index.

The paper caches responses in Delta Lake for (a) ACID appends from many
executors, (b) time-travel reads for reproducing past evaluations, and
(c) efficient exact-key lookup.  No JVM exists on pod hosts, so we keep the
three *semantics* in ~300 lines (DESIGN.md §2):

* **segments**: immutable gzip'd JSON-lines files (columnar enough for our
  row sizes; zstd/Parquet is a drop-in swap on a real deployment),
* **transaction log**: ``_log/NNNNNNNN.json`` entries, one per commit,
  listing segment adds/removes.  Commits are atomic via ``O_CREAT|O_EXCL``
  on the next version file — optimistic concurrency: losers retry with the
  next version number (exactly Delta's protocol),
* **time travel**: a read at version V replays log entries <= V,
* **CAS index**: each commit records the set of ``key_column`` values in
  its segments, so point lookups prune segments without scanning them.

Crash safety: a writer dying after writing a segment but before its log
commit leaves an unreferenced file (invisible, garbage-collectable) — the
table never observes partial state.
"""

from __future__ import annotations

import gzip
import json
import os
import random
import time
import uuid
from typing import Iterable


class CommitConflict(Exception):
    """Another writer committed this version first; retry."""


#: optimistic-concurrency retry budget.  Every lost race means another
#: writer committed (global progress), but a single writer can starve
#: under heavy contention — the budget plus jittered backoff below keeps
#: many concurrent chunk committers from spinning against each other.
COMMIT_RETRIES = 50


def _conflict_backoff(attempt: int) -> None:
    """Tiny jittered sleep after a lost version race: de-synchronizes
    writers that keep colliding on the same next-version number."""
    time.sleep(random.uniform(0.0, 0.002) * min(attempt + 1, 8))


class DeltaLite:
    def __init__(self, path: str, key_column: str | None = None):
        self.path = path
        self.key_column = key_column
        # monotone scan hint: versions are append-only, so latest_version
        # can resume from the last one seen instead of walking from 0 —
        # O(new versions) instead of O(all versions) per call, which keeps
        # concurrent committers from bunching up on long logs.  Benign
        # under races: the hint only ever lags the truth.
        self._version_hint = -1
        os.makedirs(os.path.join(path, "_log"), exist_ok=True)
        os.makedirs(os.path.join(path, "data"), exist_ok=True)

    # -- log plumbing ---------------------------------------------------------

    def _log_dir(self) -> str:
        return os.path.join(self.path, "_log")

    def _version_path(self, v: int) -> str:
        return os.path.join(self._log_dir(), f"{v:08d}.json")

    def latest_version(self) -> int:
        """Highest contiguous committed version (-1 = empty table)."""
        v = self._version_hint
        while os.path.exists(self._version_path(v + 1)):
            v += 1
        self._version_hint = v
        return v

    def _read_log(self, version: int | None = None) -> list[dict]:
        last = self.latest_version() if version is None else version
        entries = []
        for v in range(last + 1):
            with open(self._version_path(v)) as f:
                entries.append(json.load(f))
        return entries

    def _live_segments(self, version: int | None = None) -> list[dict]:
        live: dict[str, dict] = {}
        for entry in self._read_log(version):
            for add in entry.get("add", []):
                live[add["file"]] = add
            for rm in entry.get("remove", []):
                live.pop(rm, None)
        return list(live.values())

    # -- writes -----------------------------------------------------------------

    def _write_segment(self, rows: list[dict]) -> dict:
        name = f"part-{uuid.uuid4().hex}.jsonl.gz"
        fpath = os.path.join(self.path, "data", name)
        with gzip.open(fpath, "wt") as f:
            for row in rows:
                f.write(json.dumps(row) + "\n")
        seg = {"file": name, "rows": len(rows)}
        if self.key_column:
            seg["keys"] = sorted({str(r[self.key_column]) for r in rows})
        return seg

    def _commit(
        self, entry: dict, retries: int = COMMIT_RETRIES, precheck=None
    ) -> int | None:
        """Atomic commit: the fully-written entry is published with a hard
        link, so a concurrent reader can never observe a partial log file;
        losers of the version race get FileExistsError and retry.

        ``precheck(v)`` (optional) runs before each attempt against the
        table state at version ``v - 1`` — the state the successful link
        at ``v`` linearizes after; returning False abandons the commit
        (returns None).  Conditional appends build on this single copy of
        the publish protocol.
        """
        for attempt in range(retries):
            v = self.latest_version() + 1
            if precheck is not None and not precheck(v):
                return None
            entry["version"] = v
            entry["timestamp"] = time.time()
            tmp = self._version_path(v) + f".{uuid.uuid4().hex}.tmp"
            with open(tmp, "w") as f:
                json.dump(entry, f)
            try:
                os.link(tmp, self._version_path(v))
                return v
            except FileExistsError:
                _conflict_backoff(attempt)
                continue  # lost the race; re-read latest and retry
            finally:
                os.unlink(tmp)
        raise CommitConflict(f"could not commit after {retries} attempts")

    def append(self, rows: Iterable[dict]) -> int:
        """Append rows as one new segment; returns the committed version."""
        rows = list(rows)
        if not rows:
            return self.latest_version()
        seg = self._write_segment(rows)
        return self._commit({"add": [seg], "remove": []})

    def append_if_absent(
        self, rows: Iterable[dict], retries: int = COMMIT_RETRIES
    ) -> int | None:
        """First-committer-wins conditional append: commit the rows only if
        none of their ``key_column`` values are already live in the table.

        The absence check runs against the table state immediately preceding
        the version we try to claim, and the ``O_CREAT|O_EXCL``-style link is
        the linearization point: if another writer claims that version first
        we lose the race, re-read, and re-check — so two writers racing on
        the same key can never both commit it.  Returns the committed
        version, or ``None`` if a key was already taken (the written segment
        is unlinked; losers leave no garbage, even when the retry budget is
        exhausted).
        """
        assert self.key_column, "append_if_absent requires a key_column"
        rows = list(rows)
        if not rows:
            return self.latest_version()
        keys = {str(r[self.key_column]) for r in rows}
        if keys & self.keys():  # cheap fast path: skip the segment write
            return None
        seg = self._write_segment(rows)

        def absent(v: int) -> bool:
            return not (keys & self.keys(version=v - 1))

        version: int | None = None
        try:
            version = self._commit(
                {"add": [seg], "remove": []}, retries=retries, precheck=absent
            )
        finally:
            if version is None:  # lost the key race or exhausted retries
                os.unlink(os.path.join(self.path, "data", seg["file"]))
        return version

    def overwrite(self, rows: Iterable[dict]) -> int:
        """Replace the table contents (old versions stay readable)."""
        seg = self._write_segment(list(rows))
        current = [s["file"] for s in self._live_segments()]
        return self._commit({"add": [seg], "remove": current})

    def compact(self) -> int:
        """Merge all live segments into one (latest-wins on the key column)."""
        rows = self.read()
        if self.key_column:
            dedup: dict[str, dict] = {}
            for r in rows:
                dedup[str(r[self.key_column])] = r
            rows = list(dedup.values())
        seg = self._write_segment(rows)
        current = [s["file"] for s in self._live_segments()]
        return self._commit({"add": [seg], "remove": current})

    # -- reads --------------------------------------------------------------------

    def _read_segment(self, name: str) -> list[dict]:
        fpath = os.path.join(self.path, "data", name)
        with gzip.open(fpath, "rt") as f:
            return [json.loads(line) for line in f if line.strip()]

    def read(self, version: int | None = None) -> list[dict]:
        """Full scan at a version (time travel when ``version`` is given)."""
        rows: list[dict] = []
        for seg in self._live_segments(version):
            rows.extend(self._read_segment(seg["file"]))
        return rows

    def lookup(self, key: str, version: int | None = None) -> dict | None:
        """CAS point lookup: latest row whose key_column equals ``key``."""
        assert self.key_column, "lookup requires a key_column"
        hit: dict | None = None
        for seg in self._live_segments(version):
            keys = seg.get("keys")
            if keys is not None and str(key) not in keys:
                continue  # pruned without reading the segment
            for row in self._read_segment(seg["file"]):
                if str(row[self.key_column]) == str(key):
                    hit = row  # later segments win
        return hit

    def keys(self, version: int | None = None) -> set[str]:
        out: set[str] = set()
        for seg in self._live_segments(version):
            if seg.get("keys") is not None:
                out.update(seg["keys"])
            else:
                out.update(
                    str(r[self.key_column]) for r in self._read_segment(seg["file"])
                )
        return out

    def history(self) -> list[dict]:
        """Commit log (version, timestamp, files added/removed)."""
        return [
            {
                "version": e["version"],
                "timestamp": e["timestamp"],
                "added": [a["file"] for a in e.get("add", [])],
                "removed": e.get("remove", []),
            }
            for e in self._read_log()
        ]
