"""Fault-tolerant shard execution: the "executor pool" with straggler
mitigation and failure recovery.

Spark recovers skew with dynamic work stealing; a gang-scheduled SPMD step
cannot (DESIGN.md §2), so the unit of recovery here is the *shard*: the
evaluation runner splits examples into shards and this pool

* runs shards on a thread pool ("executors"),
* retries failed shards (recoverable errors) up to ``max_retries``,
* **speculatively re-issues** shards that run longer than
  ``straggler_factor`` x the median completed-shard time (first finisher
  wins, the loser's result is discarded) — Spark/MapReduce speculative
  execution,
* tracks per-worker heartbeats so a simulated dead worker's shards are
  reassigned.

Deterministic failure injection hooks make all of this testable on CPU.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Any, Callable, Sequence


@dataclasses.dataclass
class ShardResult:
    index: int
    value: Any
    attempts: int
    worker: int
    duration_s: float
    speculative: bool = False


@dataclasses.dataclass
class PoolStats:
    shards: int = 0
    retries: int = 0
    speculative_launches: int = 0
    speculative_wins: int = 0
    failures: int = 0


class WorkerPool:
    def __init__(
        self,
        n_workers: int = 4,
        *,
        max_retries: int = 3,
        straggler_factor: float = 0.0,  # 0 = speculative execution off
        straggler_min_s: float = 0.05,
        poll_s: float = 0.01,
    ):
        self.n_workers = n_workers
        self.max_retries = max_retries
        self.straggler_factor = straggler_factor
        self.straggler_min_s = straggler_min_s
        self.poll_s = poll_s
        self.stats = PoolStats()
        self.heartbeats: dict[int, float] = {}
        self._worker_ids = threading.local()
        self._next_worker = iter(range(10**9))
        self._lock = threading.Lock()

    def _worker_id(self) -> int:
        wid = getattr(self._worker_ids, "id", None)
        if wid is None:
            with self._lock:
                wid = next(self._next_worker)
            self._worker_ids.id = wid
        return wid

    def _run_shard(self, fn: Callable, index: int, shard: Any, attempt: int,
                   speculative: bool) -> ShardResult:
        wid = self._worker_id()
        t0 = time.monotonic()
        self.heartbeats[wid] = t0
        value = fn(index, shard, wid)
        dt = time.monotonic() - t0
        self.heartbeats[wid] = time.monotonic()
        return ShardResult(
            index=index, value=value, attempts=attempt, worker=wid,
            duration_s=dt, speculative=speculative,
        )

    def map_shards(
        self, fn: Callable[[int, Any, int], Any], shards: Sequence[Any]
    ) -> list[ShardResult]:
        """Run ``fn(shard_index, shard, worker_id)`` over all shards."""
        results: dict[int, ShardResult] = {}
        completed_durations: list[float] = []
        self.stats.shards += len(shards)

        with ThreadPoolExecutor(max_workers=self.n_workers) as pool:
            running: dict[Future, tuple[int, int, bool, float]] = {}
            pending = list(enumerate(shards))
            attempts = {i: 0 for i in range(len(shards))}
            speculated: set[int] = set()

            def launch(i: int, speculative: bool = False) -> None:
                attempts[i] += 1
                fut = pool.submit(
                    self._run_shard, fn, i, shards[i], attempts[i], speculative
                )
                running[fut] = (i, attempts[i], speculative, time.monotonic())

            while pending and len(running) < self.n_workers:
                i, _ = pending.pop(0)
                launch(i)

            while running:
                done, _ = wait(
                    list(running), timeout=self.poll_s,
                    return_when=FIRST_COMPLETED,
                )
                for fut in done:
                    i, attempt, speculative, _t0 = running.pop(fut)
                    try:
                        res = fut.result()
                    except Exception:
                        self.stats.failures += 1
                        if attempt <= self.max_retries and i not in results:
                            self.stats.retries += 1
                            launch(i, speculative)
                        elif i not in results and not any(
                            ri == i for ri, *_ in running.values()
                        ):
                            raise
                        continue
                    if i not in results:
                        results[i] = res
                        completed_durations.append(res.duration_s)
                        if res.speculative:
                            self.stats.speculative_wins += 1

                # refill free workers
                while pending and len(running) < self.n_workers:
                    i, _ = pending.pop(0)
                    launch(i)

                # straggler detection: re-issue slow in-flight shards
                if (
                    self.straggler_factor
                    and completed_durations
                    and not pending
                    and len(running) < self.n_workers
                ):
                    median = sorted(completed_durations)[
                        len(completed_durations) // 2
                    ]
                    threshold = max(
                        self.straggler_min_s, self.straggler_factor * median
                    )
                    now = time.monotonic()
                    for fut, (i, attempt, spec, t0) in list(running.items()):
                        if (
                            not spec
                            and i not in speculated
                            and i not in results
                            and now - t0 > threshold
                            and len(running) < self.n_workers
                        ):
                            speculated.add(i)
                            self.stats.speculative_launches += 1
                            launch(i, speculative=True)

        missing = [i for i in range(len(shards)) if i not in results]
        if missing:
            raise RuntimeError(f"shards never completed: {missing}")
        return [results[i] for i in range(len(shards))]
