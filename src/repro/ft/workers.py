"""Fault-tolerant shard execution: the "executor pool" with straggler
mitigation and failure recovery.

Spark recovers skew with dynamic work stealing; a gang-scheduled SPMD step
cannot (DESIGN.md §2), so the unit of recovery here is the *shard*: the
evaluation runner splits examples into shards and this pool

* runs shards on a thread pool ("executors"),
* retries failed shards (recoverable errors) up to ``max_retries``,
* **speculatively re-issues** shards that run longer than
  ``straggler_factor`` x the median completed-shard time (first finisher
  wins, the loser's result is discarded) — Spark/MapReduce speculative
  execution,
* tracks per-worker heartbeats so a simulated dead worker's shards are
  reassigned.

Two scheduling surfaces share those semantics:

* :meth:`WorkerPool.map_shards` — a fixed shard list, results returned in
  shard order (the intra-chunk inference path);
* :meth:`WorkerPool.imap_windowed` — an unbounded item *iterator* with a
  bounded in-flight window, results yielded in completion order (the
  chunk-level surface of the concurrent streaming executor: items are
  whole chunks, so peak materialized work is window x chunk).

Deterministic failure injection hooks make all of this testable on CPU.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Any, Callable, Iterable, Iterator, Sequence


@dataclasses.dataclass
class ShardResult:
    index: int
    value: Any
    attempts: int
    worker: int
    duration_s: float
    speculative: bool = False


@dataclasses.dataclass
class PoolStats:
    shards: int = 0
    retries: int = 0
    speculative_launches: int = 0
    speculative_wins: int = 0
    failures: int = 0

    def merge(self, other: "PoolStats") -> "PoolStats":
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))
        return self


class WorkerPool:
    def __init__(
        self,
        n_workers: int = 4,
        *,
        max_retries: int = 3,
        straggler_factor: float = 0.0,  # 0 = speculative execution off
        straggler_min_s: float = 0.05,
        poll_s: float = 0.01,
    ):
        self.n_workers = n_workers
        self.max_retries = max_retries
        self.straggler_factor = straggler_factor
        self.straggler_min_s = straggler_min_s
        self.poll_s = poll_s
        self.stats = PoolStats()
        self.heartbeats: dict[int, float] = {}
        self._worker_ids = threading.local()
        self._next_worker = iter(range(10**9))
        self._lock = threading.Lock()

    def _worker_id(self) -> int:
        wid = getattr(self._worker_ids, "id", None)
        if wid is None:
            with self._lock:
                wid = next(self._next_worker)
            self._worker_ids.id = wid
        return wid

    def _run_shard(self, fn: Callable, index: int, shard: Any, attempt: int,
                   speculative: bool) -> ShardResult:
        wid = self._worker_id()
        t0 = time.monotonic()
        self.heartbeats[wid] = t0
        value = fn(index, shard, wid)
        dt = time.monotonic() - t0
        self.heartbeats[wid] = time.monotonic()
        return ShardResult(
            index=index, value=value, attempts=attempt, worker=wid,
            duration_s=dt, speculative=speculative,
        )

    def _fold_stats(self, local: PoolStats, stats_out: PoolStats | None) -> None:
        """Publish one scheduling loop's stats.  Each ``map_shards`` /
        ``imap_windowed`` call accumulates into a *local* :class:`PoolStats`
        and folds it into the shared ``self.stats`` under the pool lock, so
        concurrent calls sharing one pool (the concurrent streaming
        executor's chunk workers) neither lose increments nor misattribute
        another call's traffic to their own delta."""
        with self._lock:
            self.stats.merge(local)
        if stats_out is not None:
            stats_out.merge(local)

    def map_shards(
        self,
        fn: Callable[[int, Any, int], Any],
        shards: Sequence[Any],
        *,
        stats_out: PoolStats | None = None,
    ) -> list[ShardResult]:
        """Run ``fn(shard_index, shard, worker_id)`` over all shards.

        ``stats_out`` (optional) receives this call's own retry/speculation
        counts — exact even when other threads run ``map_shards`` on the
        same pool concurrently, unlike a before/after snapshot of
        ``self.stats``.
        """
        results: dict[int, ShardResult] = {}
        completed_durations: list[float] = []
        local = PoolStats(shards=len(shards))

        try:
            with ThreadPoolExecutor(max_workers=self.n_workers) as pool:
                running: dict[Future, tuple[int, int, bool, float]] = {}
                pending = list(enumerate(shards))
                attempts = {i: 0 for i in range(len(shards))}
                speculated: set[int] = set()

                def launch(i: int, speculative: bool = False) -> None:
                    attempts[i] += 1
                    fut = pool.submit(
                        self._run_shard, fn, i, shards[i], attempts[i], speculative
                    )
                    running[fut] = (i, attempts[i], speculative, time.monotonic())

                while pending and len(running) < self.n_workers:
                    i, _ = pending.pop(0)
                    launch(i)

                while running:
                    done, _ = wait(
                        list(running), timeout=self.poll_s,
                        return_when=FIRST_COMPLETED,
                    )
                    for fut in done:
                        i, attempt, speculative, _t0 = running.pop(fut)
                        try:
                            res = fut.result()
                        except Exception:
                            local.failures += 1
                            if attempt <= self.max_retries and i not in results:
                                local.retries += 1
                                launch(i, speculative)
                            elif i not in results and not any(
                                ri == i for ri, *_ in running.values()
                            ):
                                raise
                            continue
                        if i not in results:
                            results[i] = res
                            completed_durations.append(res.duration_s)
                            if res.speculative:
                                local.speculative_wins += 1

                    # refill free workers
                    while pending and len(running) < self.n_workers:
                        i, _ = pending.pop(0)
                        launch(i)

                    # straggler detection: re-issue slow in-flight shards
                    if (
                        self.straggler_factor
                        and completed_durations
                        and not pending
                        and len(running) < self.n_workers
                    ):
                        median = sorted(completed_durations)[
                            len(completed_durations) // 2
                        ]
                        threshold = max(
                            self.straggler_min_s, self.straggler_factor * median
                        )
                        now = time.monotonic()
                        for fut, (i, attempt, spec, t0) in list(running.items()):
                            if (
                                not spec
                                and i not in speculated
                                and i not in results
                                and now - t0 > threshold
                                and len(running) < self.n_workers
                            ):
                                speculated.add(i)
                                local.speculative_launches += 1
                                launch(i, speculative=True)
        finally:
            self._fold_stats(local, stats_out)

        missing = [i for i in range(len(shards)) if i not in results]
        if missing:
            raise RuntimeError(f"shards never completed: {missing}")
        return [results[i] for i in range(len(shards))]

    def imap_windowed(
        self,
        fn: Callable[[int, Any, int], Any],
        items: Iterable[Any],
        *,
        window: int,
        ordered: bool = False,
        stats_out: PoolStats | None = None,
    ) -> Iterator[ShardResult]:
        """Run ``fn(index, item, worker_id)`` over an item *iterator* with a
        bounded in-flight window, yielding one :class:`ShardResult` per item
        — in **completion order** by default, in **item order** with
        ``ordered=True``.

        This is :meth:`map_shards` lifted to streaming input: at most
        ``window`` distinct items are materialized and in flight at once
        (the next item is pulled from the iterator only when a window slot
        frees), failed attempts are retried up to ``max_retries``, and
        in-flight items slower than ``straggler_factor`` x the median
        completed duration are speculatively re-issued when a thread is
        idle — first finisher wins, the duplicate's result is discarded.

        In ordered mode a slot is freed only when its result is *yielded*:
        an item completing ahead of its turn stays resident (and its
        result buffered) until every earlier item has been yielded, so the
        window bounds in-flight + buffered together.  With chunks as items
        this is the chunk-level executor of the concurrent streaming
        pipeline: peak resident examples are strictly window x chunk, and
        a straggler chunk throttles admission instead of ballooning a
        reorder buffer — while its speculative twin runs on the idled
        threads.
        """
        if window < 1:
            raise ValueError(f"window must be positive, got {window}")
        it = iter(items)
        local = PoolStats()
        try:
            with ThreadPoolExecutor(max_workers=window) as pool:
                running: dict[Future, tuple[int, int, bool, float]] = {}
                payloads: dict[int, Any] = {}
                attempts: dict[int, int] = {}
                done_idx: set[int] = set()
                speculated: set[int] = set()
                ready: dict[int, ShardResult] = {}  # ordered-mode buffer
                next_yield = 0
                completed_durations: list[float] = []
                exhausted = False
                next_index = 0

                def launch(i: int, speculative: bool = False) -> None:
                    attempts[i] = attempts.get(i, 0) + 1
                    fut = pool.submit(
                        self._run_shard, fn, i, payloads[i], attempts[i],
                        speculative,
                    )
                    running[fut] = (i, attempts[i], speculative, time.monotonic())

                while True:
                    # admit new items while distinct in-flight < window
                    while not exhausted and len(payloads) < window:
                        try:
                            item = next(it)
                        except StopIteration:
                            exhausted = True
                            break
                        payloads[next_index] = item
                        local.shards += 1
                        launch(next_index)
                        next_index += 1
                    if not running:
                        if exhausted:
                            break
                        continue

                    done, _ = wait(
                        list(running), timeout=self.poll_s,
                        return_when=FIRST_COMPLETED,
                    )
                    for fut in done:
                        i, attempt, speculative, _t0 = running.pop(fut)
                        try:
                            res = fut.result()
                        except Exception:
                            local.failures += 1
                            if attempt <= self.max_retries and i not in done_idx:
                                local.retries += 1
                                launch(i, speculative)
                            elif i not in done_idx and not any(
                                ri == i for ri, *_ in running.values()
                            ):
                                raise
                            continue
                        if i in done_idx:
                            continue  # speculative loser: discard duplicate
                        done_idx.add(i)
                        completed_durations.append(res.duration_s)
                        if res.speculative:
                            local.speculative_wins += 1
                        if not ordered:
                            payloads.pop(i, None)  # frees a window slot
                            yield res
                            continue
                        ready[i] = res
                        while next_yield in ready:
                            out = ready.pop(next_yield)
                            payloads.pop(next_yield, None)  # frees a slot
                            next_yield += 1
                            yield out

                    # straggler detection at the item level: re-issue slow
                    # in-flight items onto idle threads
                    if (
                        self.straggler_factor
                        and completed_durations
                        and len(running) < window
                    ):
                        median = sorted(completed_durations)[
                            len(completed_durations) // 2
                        ]
                        threshold = max(
                            self.straggler_min_s, self.straggler_factor * median
                        )
                        now = time.monotonic()
                        for fut, (i, attempt, spec, t0) in list(running.items()):
                            if (
                                not spec
                                and i not in speculated
                                and i not in done_idx
                                and now - t0 > threshold
                                and len(running) < window
                            ):
                                speculated.add(i)
                                local.speculative_launches += 1
                                launch(i, speculative=True)
        finally:
            self._fold_stats(local, stats_out)
