from repro.ft.failure_sim import (
    ChunkCrashMiddleware,
    Fault,
    FlakyFn,
    SimulatedCrash,
    simulate_training,
)
from repro.ft.workers import PoolStats, ShardResult, WorkerPool

__all__ = [
    "ChunkCrashMiddleware",
    "Fault",
    "FlakyFn",
    "PoolStats",
    "ShardResult",
    "SimulatedCrash",
    "WorkerPool",
    "simulate_training",
]
