from repro.ft.failure_sim import (
    SERVING_FAULT_KINDS,
    ChunkCrashMiddleware,
    Fault,
    FlakyFn,
    ServingFault,
    ServingFaultSchedule,
    SimulatedCrash,
    simulate_training,
)
from repro.ft.workers import PoolStats, ShardResult, WorkerPool

__all__ = [
    "SERVING_FAULT_KINDS",
    "ChunkCrashMiddleware",
    "Fault",
    "FlakyFn",
    "PoolStats",
    "ServingFault",
    "ServingFaultSchedule",
    "ShardResult",
    "SimulatedCrash",
    "WorkerPool",
    "simulate_training",
]
