from repro.ft.failure_sim import Fault, FlakyFn, simulate_training
from repro.ft.workers import PoolStats, ShardResult, WorkerPool

__all__ = ["Fault", "FlakyFn", "PoolStats", "ShardResult", "WorkerPool", "simulate_training"]
