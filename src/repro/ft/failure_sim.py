"""Failure-injection harness: deterministic chaos for the worker pool and
the training loop (node death, stragglers, transient API errors).

``FlakyFn`` wraps a shard function with scheduled failures/delays keyed by
(shard_index, attempt) so tests reproduce exactly.  ``simulate_training``
drives a train loop with injected crashes and proves checkpoint/restart
equivalence: the crashed-and-restarted run must produce bitwise-identical
parameters to an uninterrupted run (the invariant the test suite asserts).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Sequence


@dataclasses.dataclass
class Fault:
    shard: int
    attempt: int            # 1-based: fail the Nth attempt of this shard
    kind: str = "raise"     # raise | delay
    delay_s: float = 0.0


class FlakyFn:
    """Wrap fn(idx, shard, worker) with deterministic fault injection."""

    def __init__(self, fn: Callable, faults: list[Fault]):
        self.fn = fn
        self.faults = {(f.shard, f.attempt): f for f in faults}
        self.attempt_counts: dict[int, int] = {}
        self.injected: list[tuple[int, int, str]] = []

    def __call__(self, idx: int, shard: Any, worker: int):
        attempt = self.attempt_counts.get(idx, 0) + 1
        self.attempt_counts[idx] = attempt
        fault = self.faults.get((idx, attempt))
        if fault is not None:
            self.injected.append((idx, attempt, fault.kind))
            if fault.kind == "raise":
                raise RuntimeError(f"injected failure shard={idx} attempt={attempt}")
            if fault.kind == "delay":
                time.sleep(fault.delay_s)
        return self.fn(idx, shard, worker)


class SimulatedCrash(RuntimeError):
    """Injected driver death: the process 'dies' mid-run, leaving any
    on-disk state (checkpoints, spill manifests) exactly as committed."""


class ChunkCrashMiddleware:
    """Deterministic crash injection for streaming evaluation runs.

    Reuses the :class:`Fault` schedule with ``shard`` = chunk index: a
    ``raise`` fault at ``(chunk, attempt)`` kills the run *after* that
    chunk committed to the spill manifest — the streaming analogue of
    node death between Spark task commits.  Attempts are counted per
    chunk across restarts of the same middleware instance, so a resumed
    run (which skips committed chunks and never re-fires their hooks)
    proceeds past the crash point.

    Duck-types :class:`repro.core.stages.Middleware` (only the
    ``on_chunk_end`` hook does anything).
    """

    def __init__(self, faults: list[Fault]):
        self.faults = {(f.shard, f.attempt): f for f in faults}
        self.attempt_counts: dict[int, int] = {}
        self.injected: list[tuple[int, int, str]] = []

    def on_task_start(self, task, rows, session) -> None:
        pass

    def on_stage_start(self, stage, art, session) -> None:
        pass

    def on_stage_end(self, stage, art, session) -> None:
        pass

    def on_task_end(self, task, result, session) -> None:
        pass

    def on_chunk_end(self, chunk_index: int, state: dict, session) -> None:
        attempt = self.attempt_counts.get(chunk_index, 0) + 1
        self.attempt_counts[chunk_index] = attempt
        fault = self.faults.get((chunk_index, attempt))
        if fault is not None:
            self.injected.append((chunk_index, attempt, fault.kind))
            if fault.kind == "raise":
                raise SimulatedCrash(
                    f"injected driver death after chunk={chunk_index} "
                    f"attempt={attempt}"
                )
            if fault.kind == "delay":
                time.sleep(fault.delay_s)


SERVING_FAULT_KINDS = ("replica_crash", "hang", "page_pressure", "slow_step")


@dataclasses.dataclass
class ServingFault:
    """One serving-layer fault, scheduled by (replica, engine step).

    Kinds (DESIGN.md §9):

    - ``replica_crash`` — the engine raises :class:`SimulatedCrash` out of
      its pump; the service's restart path must recover the replica.
    - ``hang`` — the engine makes no progress (no admissions, no decode
      steps, no completions) for ``duration`` pumps; only the service's
      health probe can see this.
    - ``page_pressure`` — force-preempt ``duration`` victim slots
      (fewest decoded tokens, index tie-break), simulating decode-time
      pool exhaustion.
    - ``slow_step`` — a straggler step: ``delay_s`` of extra latency
      (wall-clock engines only; a no-op under the virtual clock).
    """

    replica: int
    step: int               # engine pump/step index the fault fires at
    kind: str = "replica_crash"
    duration: int = 1       # pumps hung / slots preempted
    delay_s: float = 0.0    # extra latency for slow_step

    def __post_init__(self) -> None:
        if self.kind not in SERVING_FAULT_KINDS:
            raise ValueError(
                f"unknown serving fault kind {self.kind!r}; "
                f"expected one of {SERVING_FAULT_KINDS}"
            )


class ServingFaultSchedule:
    """Deterministic serving-layer fault plan keyed by (replica, step).

    Engines claim replica indices via :meth:`attach` in creation order —
    ``EvalSession`` builds replica engines 0..n-1 in order, so a schedule
    passed through ``engine_kwargs={"fault_plan": plan}`` maps faults to
    replicas deterministically.  Each fault fires exactly once, at the
    first poll whose step is >= its scheduled step (engines poll every
    pump, so this is the scheduled step in practice; the >= keeps a
    fault from being lost if an engine skips step numbers).

    Thread-safe: replicas poll concurrently from their batcher loops.
    """

    def __init__(self, faults: Sequence[ServingFault]):
        self.faults = sorted(faults, key=lambda f: (f.replica, f.step))
        self._by_replica: dict[int, list[ServingFault]] = {}
        for f in self.faults:
            self._by_replica.setdefault(f.replica, []).append(f)
        #: (replica, step fired at, kind) in firing order
        self.injected: list[tuple[int, int, str]] = []
        self._next_index = 0
        self._lock = threading.Lock()

    def attach(self) -> int:
        """Claim the next replica index (engine creation order)."""
        with self._lock:
            i = self._next_index
            self._next_index += 1
            return i

    def poll(self, replica: int, step: int) -> ServingFault | None:
        """Return the due fault for (replica, step), at most one per call."""
        with self._lock:
            due = self._by_replica.get(replica)
            if due and step >= due[0].step:
                fault = due.pop(0)
                self.injected.append((replica, step, fault.kind))
                return fault
        return None

    def as_hook(self, replica: int) -> Callable[[int], str | None]:
        """Adapt the schedule to ``ContinuousBatcher.fault_hook``: a
        callable(step) that raises for ``replica_crash``, sleeps for
        ``slow_step``, and returns the kind string for the batcher to act
        on (``page_pressure`` → forced preemption, ``hang`` → skip the
        decode step)."""

        def hook(step: int) -> str | None:
            fault = self.poll(replica, step)
            if fault is None:
                return None
            if fault.kind == "replica_crash":
                raise SimulatedCrash(
                    f"injected replica_crash replica={replica} step={step}"
                )
            if fault.kind == "slow_step" and fault.delay_s:
                time.sleep(fault.delay_s)
            return fault.kind

        return hook


def simulate_training(
    train_step: Callable,
    init_state: Any,
    batches: list[Any],
    *,
    ckpt_dir: str,
    crash_at_step: int | None = None,
    ckpt_every: int = 2,
) -> Any:
    """Run a training loop with checkpointing; optionally 'crash' (return
    early) at ``crash_at_step``.  Call again with crash_at_step=None to
    resume from the latest checkpoint and finish."""
    from repro.ckpt.checkpoint import (
        latest_step,
        restore_checkpoint,
        save_checkpoint,
    )

    start = 0
    state = init_state
    last = latest_step(ckpt_dir)
    if last is not None:
        state, _ = restore_checkpoint(ckpt_dir, last, template=init_state)
        start = last
    for step in range(start, len(batches)):
        state = train_step(state, batches[step])
        done = step + 1
        if done % ckpt_every == 0:
            if latest_step(ckpt_dir) != done:
                save_checkpoint(ckpt_dir, done, state)
        if crash_at_step is not None and done >= crash_at_step:
            return None  # simulated node death
    return state
