"""Failure-injection harness: deterministic chaos for the worker pool and
the training loop (node death, stragglers, transient API errors).

``FlakyFn`` wraps a shard function with scheduled failures/delays keyed by
(shard_index, attempt) so tests reproduce exactly.  ``simulate_training``
drives a train loop with injected crashes and proves checkpoint/restart
equivalence: the crashed-and-restarted run must produce bitwise-identical
parameters to an uninterrupted run (the invariant the test suite asserts).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable


@dataclasses.dataclass
class Fault:
    shard: int
    attempt: int            # 1-based: fail the Nth attempt of this shard
    kind: str = "raise"     # raise | delay
    delay_s: float = 0.0


class FlakyFn:
    """Wrap fn(idx, shard, worker) with deterministic fault injection."""

    def __init__(self, fn: Callable, faults: list[Fault]):
        self.fn = fn
        self.faults = {(f.shard, f.attempt): f for f in faults}
        self.attempt_counts: dict[int, int] = {}
        self.injected: list[tuple[int, int, str]] = []

    def __call__(self, idx: int, shard: Any, worker: int):
        attempt = self.attempt_counts.get(idx, 0) + 1
        self.attempt_counts[idx] = attempt
        fault = self.faults.get((idx, attempt))
        if fault is not None:
            self.injected.append((idx, attempt, fault.kind))
            if fault.kind == "raise":
                raise RuntimeError(f"injected failure shard={idx} attempt={attempt}")
            if fault.kind == "delay":
                time.sleep(fault.delay_s)
        return self.fn(idx, shard, worker)


class SimulatedCrash(RuntimeError):
    """Injected driver death: the process 'dies' mid-run, leaving any
    on-disk state (checkpoints, spill manifests) exactly as committed."""


class ChunkCrashMiddleware:
    """Deterministic crash injection for streaming evaluation runs.

    Reuses the :class:`Fault` schedule with ``shard`` = chunk index: a
    ``raise`` fault at ``(chunk, attempt)`` kills the run *after* that
    chunk committed to the spill manifest — the streaming analogue of
    node death between Spark task commits.  Attempts are counted per
    chunk across restarts of the same middleware instance, so a resumed
    run (which skips committed chunks and never re-fires their hooks)
    proceeds past the crash point.

    Duck-types :class:`repro.core.stages.Middleware` (only the
    ``on_chunk_end`` hook does anything).
    """

    def __init__(self, faults: list[Fault]):
        self.faults = {(f.shard, f.attempt): f for f in faults}
        self.attempt_counts: dict[int, int] = {}
        self.injected: list[tuple[int, int, str]] = []

    def on_task_start(self, task, rows, session) -> None:
        pass

    def on_stage_start(self, stage, art, session) -> None:
        pass

    def on_stage_end(self, stage, art, session) -> None:
        pass

    def on_task_end(self, task, result, session) -> None:
        pass

    def on_chunk_end(self, chunk_index: int, state: dict, session) -> None:
        attempt = self.attempt_counts.get(chunk_index, 0) + 1
        self.attempt_counts[chunk_index] = attempt
        fault = self.faults.get((chunk_index, attempt))
        if fault is not None:
            self.injected.append((chunk_index, attempt, fault.kind))
            if fault.kind == "raise":
                raise SimulatedCrash(
                    f"injected driver death after chunk={chunk_index} "
                    f"attempt={attempt}"
                )
            if fault.kind == "delay":
                time.sleep(fault.delay_s)


def simulate_training(
    train_step: Callable,
    init_state: Any,
    batches: list[Any],
    *,
    ckpt_dir: str,
    crash_at_step: int | None = None,
    ckpt_every: int = 2,
) -> Any:
    """Run a training loop with checkpointing; optionally 'crash' (return
    early) at ``crash_at_step``.  Call again with crash_at_step=None to
    resume from the latest checkpoint and finish."""
    from repro.ckpt.checkpoint import (
        latest_step,
        restore_checkpoint,
        save_checkpoint,
    )

    start = 0
    state = init_state
    last = latest_step(ckpt_dir)
    if last is not None:
        state, _ = restore_checkpoint(ckpt_dir, last, template=init_state)
        start = last
    for step in range(start, len(batches)):
        state = train_step(state, batches[step])
        done = step + 1
        if done % ckpt_every == 0:
            if latest_step(ckpt_dir) != done:
                save_checkpoint(ckpt_dir, done, state)
        if crash_at_step is not None and done >= crash_at_step:
            return None  # simulated node death
    return state
