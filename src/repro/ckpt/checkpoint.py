"""Checkpointing: sha256-manifested tensor store with elastic resharding.

Layout (one directory per step):

  step-000100/
    manifest.json     — tree structure, per-leaf shape/dtype/file/sha256,
                        step metadata; written LAST and atomically (rename),
                        so a crashed save is invisible
    <leaf-path>.npy   — one array per leaf (row-major, np.save format)

Restore is **elastic**: arrays are placed onto whatever mesh/sharding the
restoring job provides (``jax.device_put`` reshards transparently), so a
checkpoint written on a 2x16x16 pod restores onto 16x16 — or onto a CPU
test host.  Integrity is verified against the manifest hashes.

A production deployment writes per-shard files through a distributed
filesystem; the single-writer form here keeps the exact same manifest
protocol (the unit tests cover corrupt / partial saves).
"""

from __future__ import annotations

import hashlib
import json
import os
from typing import Any

import jax
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((name, leaf))
    return out


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save_checkpoint(
    directory: str, step: int, tree: PyTree, *, extra: dict | None = None
) -> str:
    """Write ``tree`` at ``directory/step-NNNNNN``; returns the path."""
    cdir = os.path.join(directory, f"step-{step:06d}")
    tmp = cdir + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest: dict[str, Any] = {"step": step, "leaves": {}, "extra": extra or {}}
    for name, leaf in _flatten_with_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        fname = name.replace("/", "__") + ".npy"
        fpath = os.path.join(tmp, fname)
        np.save(fpath, arr)
        manifest["leaves"][name] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "sha256": _sha256_file(fpath),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(cdir):
        raise FileExistsError(cdir)
    os.rename(tmp, cdir)  # atomic publish
    return cdir


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("-")[1])
        for d in os.listdir(directory)
        if d.startswith("step-") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    step: int | None = None,
    *,
    template: PyTree | None = None,
    shardings: PyTree | None = None,
    verify: bool = True,
) -> tuple[PyTree, dict]:
    """Load a checkpoint; reshard onto ``shardings`` if given (elastic).

    ``template`` provides the tree structure; without it a nested dict
    keyed by leaf path is returned.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    cdir = os.path.join(directory, f"step-{step:06d}")
    with open(os.path.join(cdir, "manifest.json")) as f:
        manifest = json.load(f)

    arrays: dict[str, np.ndarray] = {}
    for name, meta in manifest["leaves"].items():
        fpath = os.path.join(cdir, meta["file"])
        if verify and _sha256_file(fpath) != meta["sha256"]:
            raise IOError(f"checksum mismatch for {name} in {cdir}")
        arrays[name] = np.load(fpath)

    if template is not None:
        named = _flatten_with_paths(template)
        leaves = []
        shard_list = (
            [s for _, s in _flatten_with_paths(shardings)]
            if shardings is not None
            else [None] * len(named)
        )
        for (name, tmpl_leaf), sh in zip(named, shard_list):
            if name not in arrays:
                raise KeyError(f"checkpoint missing leaf {name}")
            arr = arrays[name]
            want = tuple(getattr(tmpl_leaf, "shape", arr.shape))
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"shape mismatch for {name}: ckpt {arr.shape} vs {want}"
                )
            dtype = getattr(tmpl_leaf, "dtype", arr.dtype)
            arr = arr.astype(dtype)
            leaves.append(
                jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr)
            )
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), leaves
        )
        return tree, manifest
    # no template: nested-by-path dict
    return arrays, manifest


class CheckpointManager:
    """Keep-last-N rotation + save-every-K policy around save/restore."""

    def __init__(self, directory: str, *, keep: int = 3, every: int = 100):
        self.directory = directory
        self.keep = keep
        self.every = every

    def maybe_save(self, step: int, tree: PyTree, **extra: Any) -> str | None:
        if step % self.every != 0:
            return None
        path = save_checkpoint(self.directory, step, tree, extra=extra)
        self._rotate()
        return path

    def _rotate(self) -> None:
        steps = sorted(
            int(d.split("-")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step-") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            cdir = os.path.join(self.directory, f"step-{s:06d}")
            for f in os.listdir(cdir):
                os.remove(os.path.join(cdir, f))
            os.rmdir(cdir)

    def restore_latest(self, **kw: Any):
        return restore_checkpoint(self.directory, **kw)
