"""Optional-hypothesis shim: property-based tests become clean skips when
hypothesis is not installed, so the suite collects on a clean interpreter."""

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: every strategy factory
        returns None (never drawn from — the test body is skipped)."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None

            return _strategy

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def deco(fn):
            # zero-arg replacement so pytest doesn't look for fixtures
            # matching the hypothesis-drawn parameters
            def _skipped():
                pytest.skip("hypothesis not installed")

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco
