# NOTE: no XLA_FLAGS here on purpose — smoke tests and benchmarks must see
# the real single CPU device; only launch/dryrun.py forces 512 placeholders.
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)
