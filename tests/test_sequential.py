"""Anytime-valid sequential statistics (ISSUE 6 tentpole part 1).

Unit tests for the confidence-sequence boundary, verdict certification and
the stopping rule, plus the seeded empirical guarantees the whole adaptive
subsystem rests on: *optional stopping does not inflate miscoverage or
false certification beyond alpha* (the property a fixed-n interval peeked
at repeatedly provably lacks)."""

import dataclasses
import math

import numpy as np
import pytest

from repro.stats import MetricAccumulator
from repro.stats.sequential import (
    StoppingRule,
    certify_verdict,
    mixture_half_width,
    rho_opt,
    sequential_ci,
)

ALPHA = 0.05
#: binomial noise allowance on the empirical rates (n_sims=400:
#: sd(rate) ~ 0.011 at the nominal level; 3 sd on top of alpha)
SLACK = 0.035


def _acc(values) -> MetricAccumulator:
    a = MetricAccumulator()
    a.update(list(values))
    return a


# -- boundary shape ------------------------------------------------------------


def test_rho_opt_validates_inputs():
    with pytest.raises(ValueError):
        rho_opt(0)
    with pytest.raises(ValueError):
        rho_opt(100, alpha=1.5)
    assert rho_opt(100) > rho_opt(10_000)  # tightest-point moves out


def test_half_width_infinite_below_one_sample():
    assert mixture_half_width(0.25, 0) == float("inf")
    assert math.isfinite(mixture_half_width(0.25, 1))


def test_half_width_shrinks_with_n_and_variance():
    rho = rho_opt(1000)
    widths = [mixture_half_width(0.25, n, rho=rho) for n in (10, 100, 1000, 10_000)]
    assert widths == sorted(widths, reverse=True)
    assert mixture_half_width(0.01, 500, rho=rho) < mixture_half_width(
        0.25, 500, rho=rho
    )


def test_half_width_wider_than_fixed_n_interval():
    """The price of unlimited peeking: the sequence is wider than the
    fixed-n normal interval at its own tuning point (never free)."""
    n, var = 1000, 0.25
    fixed = 1.96 * math.sqrt(var / n)
    assert mixture_half_width(var, n, rho=rho_opt(n)) > fixed


def test_sequential_ci_edge_cases():
    nan_iv = sequential_ci(_acc([]))
    assert math.isnan(nan_iv.value) and nan_iv.half_width == float("inf")
    # acs needs two points for a variance; mixture does not
    assert sequential_ci(_acc([0.7])).half_width == float("inf")
    assert math.isfinite(sequential_ci(_acc([0.7]), method="mixture").half_width)
    with pytest.raises(ValueError):
        sequential_ci(_acc([0.1, 0.2]), method="bonferroni")


def test_sequential_ci_covers_from_moments():
    rng = np.random.default_rng(3)
    x = rng.random(4000)
    iv = sequential_ci(_acc(x))
    assert iv.lo < float(np.mean(x)) < iv.hi
    assert iv.n == 4000 and iv.method == "acs"


# -- verdicts ------------------------------------------------------------------


def test_certify_verdict_cases():
    assert certify_verdict(0.02, 0.10) == "a_better"
    assert certify_verdict(-0.10, -0.02) == "b_better"
    assert certify_verdict(-0.01, 0.01) == "undecided"            # margin 0
    assert certify_verdict(-0.01, 0.01, margin=0.05) == "equivalent"
    assert certify_verdict(0.06, 0.20, margin=0.05) == "a_better"
    assert certify_verdict(0.02, 0.20, margin=0.05) == "undecided"
    assert certify_verdict(float("-inf"), float("inf")) == "undecided"
    assert certify_verdict(float("nan"), 0.1) == "undecided"


# -- stopping rule -------------------------------------------------------------


def test_stopping_rule_fingerprint_tracks_statistical_fields():
    r = StoppingRule(enabled=True, target_half_width=0.02)
    assert r.fingerprint() == StoppingRule(
        enabled=True, target_half_width=0.02
    ).fingerprint()
    assert r.fingerprint() != dataclasses.replace(r, alpha=0.01).fingerprint()
    assert r.fingerprint() != dataclasses.replace(
        r, target_half_width=0.03
    ).fingerprint()


def test_stopping_rule_unknown_metric_refused():
    rule = StoppingRule(enabled=True, metric="bleu", target_half_width=0.1)
    with pytest.raises(KeyError, match="bleu"):
        rule.should_stop({"exact_match": _acc([1.0, 0.0])}, 2)


def test_stopping_rule_min_examples_gate():
    rule = StoppingRule(
        enabled=True, target_half_width=10.0, min_examples=100
    )
    accs = {"m": _acc([0.5] * 50)}
    assert not rule.should_stop(accs, 50).stop
    accs["m"].update([0.5] * 50)
    d = rule.should_stop(accs, 100)
    assert d.stop and d.reason == "target_half_width"


def test_stopping_rule_max_examples_is_final():
    rule = StoppingRule(enabled=True, min_examples=10, max_examples=200)
    rng = np.random.default_rng(0)
    d = rule.should_stop({"m": _acc(rng.random(200))}, 200)
    assert d.stop and d.reason == "max_examples"
    assert not rule.should_stop({"m": _acc(rng.random(199))}, 199).stop


def test_stopping_rule_disabled_never_stops():
    rule = StoppingRule()
    assert not rule.should_stop({"m": _acc([0.5, 0.5])}, 10**9).stop


def test_stopping_rule_watches_all_metrics_when_unset():
    rule = StoppingRule(
        enabled=True, target_half_width=0.2, min_examples=16
    )
    rng = np.random.default_rng(1)
    tight = _acc([0.5] * 400)            # zero variance: very tight
    loose = _acc(rng.normal(0, 5.0, 400))  # wide
    assert not rule.should_stop({"a": tight, "b": loose}, 400).stop
    d = rule.should_stop({"a": tight, "b": _acc([0.3] * 400)}, 400)
    assert d.stop


# -- empirical guarantees under optional stopping (satellite: type-1 sim) ------


def _peek_halfwidths(x: np.ndarray, peeks: np.ndarray, rho: float):
    """Half-width of the acs sequence at each peek point of one stream."""
    csum, csq = np.cumsum(x), np.cumsum(x * x)
    out = []
    for n in peeks:
        var = (csq[n - 1] - csum[n - 1] ** 2 / n) / (n - 1)
        out.append(mixture_half_width(max(var, 0.0), int(n), rho=rho))
    return csum[peeks - 1] / peeks, np.array(out)


def test_anytime_coverage_under_continuous_peeking():
    """P(any peek's interval misses the true mean) <= alpha (+MC slack):
    the defining property of a confidence sequence.  A fixed-n interval
    peeked at this schedule misses ~3-5x more often."""
    rng = np.random.default_rng(7)
    n_sims, n, mu = 400, 2000, 0.6
    peeks = np.arange(50, n + 1, 50)
    rho = rho_opt(200, ALPHA)
    misses = fixed_misses = 0
    for _ in range(n_sims):
        x = (rng.random(n) < mu).astype(float)
        means, hw = _peek_halfwidths(x, peeks, rho)
        misses += int(np.any(np.abs(means - mu) > hw))
        fixed_hw = 1.96 * np.sqrt(
            np.maximum(means * (1 - means), 1e-12) / peeks
        )
        fixed_misses += int(np.any(np.abs(means - mu) > fixed_hw))
    assert misses / n_sims <= ALPHA + SLACK, misses / n_sims
    # sanity: the naive fixed-n interval really does blow past alpha on
    # this peeking schedule — the sequence is not vacuously wide
    assert fixed_misses / n_sims > ALPHA + SLACK


def test_false_certification_rate_under_null_with_optional_stopping():
    """Two identical models, stop at the FIRST certified verdict: the
    false-certification rate stays at alpha even though the stopping time
    is chosen by peeking — the core claim of the adaptive subsystem."""
    rng = np.random.default_rng(11)
    n_sims, n = 400, 2000
    peeks = np.arange(50, n + 1, 50)
    rho = rho_opt(200, ALPHA)
    false_cert = 0
    for _ in range(n_sims):
        p = rng.uniform(0.3, 0.8)
        d = (rng.random(n) < p).astype(float) - (rng.random(n) < p).astype(float)
        means, hw = _peek_halfwidths(d, peeks, rho)
        for m, w in zip(means, hw):
            v = certify_verdict(m - w, m + w)
            if v != "undecided":
                false_cert += 1
                break
    assert false_cert / n_sims <= ALPHA + SLACK, false_cert / n_sims


def test_adaptive_certification_finds_true_direction_early():
    """Separated models: stopping at the first certified verdict yields
    the correct direction (essentially) always, and consumes far fewer
    examples than the full stream."""
    rng = np.random.default_rng(13)
    n_sims, n = 200, 4000
    peeks = np.arange(100, n + 1, 100)
    rho = rho_opt(400, ALPHA)
    wrong = undecided = 0
    stop_ns = []
    for _ in range(n_sims):
        d = (rng.random(n) < 0.65).astype(float) - (rng.random(n) < 0.50).astype(float)
        means, hw = _peek_halfwidths(d, peeks, rho)
        for nn, m, w in zip(peeks, means, hw):
            v = certify_verdict(m - w, m + w)
            if v != "undecided":
                stop_ns.append(int(nn))
                if v != "a_better":
                    wrong += 1
                break
        else:
            undecided += 1
    assert wrong == 0
    assert undecided / n_sims < 0.05
    assert np.mean(stop_ns) < 0.5 * n  # certifies well before exhaustion
