"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bertscore import bertscore_pr, bertscore_ref
from repro.kernels.bootstrap import bootstrap_means, bootstrap_means_ref
from repro.kernels.decode_attention import (
    decode_attention,
    decode_attention_ref,
    dequantize_pages,
    gather_pages_ref,
    paged_decode_attention,
    paged_decode_attention_blocked_ref,
    paged_decode_attention_ref,
    quant_paged_decode_attention,
    quant_paged_decode_attention_blocked_ref,
    quant_paged_decode_attention_ref,
    quantize_pages,
)
from repro.kernels.flash_attention import (
    flash_attention,
    flash_attention_bshd,
    flash_attention_ref,
)
from repro.kernels.ssd import ssd, ssd_ref
from repro.models.ssm import ssd_chunked

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return 2e-5 if dtype == jnp.float32 else 3e-2


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize(
    "b,h,kh,sq,sk,d,causal",
    [
        (2, 4, 2, 128, 128, 32, True),
        (1, 8, 8, 256, 256, 64, True),
        (2, 4, 1, 128, 256, 32, False),
        (1, 2, 2, 64, 192, 128, True),
    ],
)
def test_flash_attention(b, h, kh, sq, sk, d, causal, dtype, rng):
    q = jnp.asarray(rng.randn(b, h, sq, d), dtype)
    k = jnp.asarray(rng.randn(b, kh, sk, d), dtype)
    v = jnp.asarray(rng.randn(b, kh, sk, d), dtype)
    off = sk - sq if causal else 0
    out = flash_attention(
        q, k, v, causal=causal, block_q=64, block_k=64, q_offset=off,
        interpret=True,
    )
    ref = flash_attention_ref(q, k, v, causal=causal, q_offset=off)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


def test_flash_attention_bshd_layout(rng):
    b, s, h, kh, d = 2, 128, 4, 2, 32
    q = jnp.asarray(rng.randn(b, s, h, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, kh, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, kh, d), jnp.float32)
    out = flash_attention_bshd(q, k, v, causal=True, interpret=True)
    ref = flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize(
    "b,kh,g,s,d", [(2, 2, 4, 256, 32), (3, 1, 8, 512, 64), (2, 4, 1, 128, 32)]
)
def test_decode_attention(b, kh, g, s, d, dtype, rng):
    q = jnp.asarray(rng.randn(b, kh, g, d), dtype)
    k = jnp.asarray(rng.randn(b, kh, s, d), dtype)
    v = jnp.asarray(rng.randn(b, kh, s, d), dtype)
    lens = jnp.asarray(rng.randint(1, s, (b,)), jnp.int32)
    out = decode_attention(q, k, v, lens, block_s=64, interpret=True)
    ref = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


def _paged_case(rng, b, kh, g, n_p, ps, d, dtype, lens):
    """Random pool + per-sequence tables drawn without replacement, so
    every sequence gathers distinct pages (sharing is tested separately)."""
    pool = b * n_p + 3  # a few never-referenced pages
    k = jnp.asarray(rng.randn(pool, kh, ps, d), dtype)
    v = jnp.asarray(rng.randn(pool, kh, ps, d), dtype)
    q = jnp.asarray(rng.randn(b, kh, g, d), dtype)
    perm = rng.permutation(pool)[: b * n_p].reshape(b, n_p)
    tables = jnp.asarray(perm, jnp.int32)
    return q, k, v, tables, jnp.asarray(lens, jnp.int32)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize(
    "b,kh,g,n_p,ps,d,lens",
    [
        # ragged lengths, mid-page offsets
        (3, 2, 4, 4, 16, 32, [5, 33, 64]),
        # page-boundary lengths (len % ps == 0) and a single-token sequence
        (3, 1, 8, 4, 16, 64, [16, 48, 1]),
        # one page per sequence
        (2, 4, 1, 1, 32, 32, [7, 32]),
    ],
)
def test_paged_decode_attention(b, kh, g, n_p, ps, d, lens, dtype, rng):
    q, k, v, tables, lengths = _paged_case(rng, b, kh, g, n_p, ps, d, dtype, lens)
    out = paged_decode_attention(q, k, v, tables, lengths, interpret=True)
    dense = paged_decode_attention_ref(q, k, v, tables, lengths)
    blocked = paged_decode_attention_blocked_ref(q, k, v, tables, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(dense, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(blocked, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


def test_paged_matches_contiguous_kernel(rng):
    """Gathering the pages into a slab and running the contiguous kernel
    gives the same answer as the paged kernel on the pool directly."""
    b, kh, g, n_p, ps, d = 2, 2, 4, 4, 16, 32
    q, k, v, tables, lengths = _paged_case(
        rng, b, kh, g, n_p, ps, d, jnp.float32, [23, 64]
    )
    out = paged_decode_attention(q, k, v, tables, lengths, interpret=True)
    slab_k = gather_pages_ref(k, tables)
    slab_v = gather_pages_ref(v, tables)
    contig = decode_attention(q, slab_k, slab_v, lengths, block_s=ps, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(contig), atol=2e-5, rtol=2e-5
    )


def test_paged_decode_shared_pages_alias(rng):
    """Prefix sharing: two sequences whose tables alias the same pool
    pages for a shared prefix read identical KV there — sequence 1 must
    score exactly like a private copy of those pages would."""
    b, kh, g, ps, d = 2, 2, 4, 16, 32
    pool = 8
    k = jnp.asarray(rng.randn(pool, kh, ps, d), jnp.float32)
    v = jnp.asarray(rng.randn(pool, kh, ps, d), jnp.float32)
    q = jnp.asarray(rng.randn(b, kh, g, d), jnp.float32)
    # pages 0-1 shared, last page private (2 vs 3); padding entries are 0
    tables = jnp.asarray([[0, 1, 2, 0], [0, 1, 3, 0]], jnp.int32)
    lengths = jnp.asarray([40, 37], jnp.int32)
    out = paged_decode_attention(q, k, v, tables, lengths, interpret=True)
    # private-copy oracle: duplicate the shared pages into fresh slots
    k2 = jnp.concatenate([k, k[:2]], axis=0)
    v2 = jnp.concatenate([v, v[:2]], axis=0)
    tables2 = jnp.asarray([[0, 1, 2, 0], [8, 9, 3, 0]], jnp.int32)
    ref = paged_decode_attention_ref(q, k2, v2, tables2, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_paged_decode_padding_pages_ignored(rng):
    """Table entries past ceil(len/ps) point at pool page 0 (arbitrary
    live data) — the length mask must zero them exactly: answers are
    invariant to what the padding entries address."""
    b, kh, g, ps, d = 1, 2, 4, 16, 32
    k = jnp.asarray(rng.randn(6, kh, ps, d), jnp.float32)
    v = jnp.asarray(rng.randn(6, kh, ps, d), jnp.float32)
    q = jnp.asarray(rng.randn(b, kh, g, d), jnp.float32)
    lengths = jnp.asarray([20], jnp.int32)  # 2 live pages of 4
    a = paged_decode_attention(
        q, k, v, jnp.asarray([[2, 3, 0, 0]], jnp.int32), lengths, interpret=True
    )
    bb = paged_decode_attention(
        q, k, v, jnp.asarray([[2, 3, 5, 1]], jnp.int32), lengths, interpret=True
    )
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb), atol=0, rtol=0)


def _quant_paged_case(rng, b, kh, g, n_p, ps, d, dtype, lens):
    """Like ``_paged_case`` but the pool is int8 block-quantized: q stays
    in ``dtype``; pages carry per-(page, head) f32 absmax scales."""
    q, k, v, tables, lengths = _paged_case(rng, b, kh, g, n_p, ps, d, dtype, lens)
    kq, ks = quantize_pages(jnp.asarray(k, jnp.float32))
    vq, vs = quantize_pages(jnp.asarray(v, jnp.float32))
    return q, kq, vq, ks, vs, tables, lengths


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize(
    "b,kh,g,n_p,ps,d,lens",
    [
        # ragged lengths, mid-page offsets
        (3, 2, 4, 4, 16, 32, [5, 33, 64]),
        # page-boundary lengths (len % ps == 0) and a single-token sequence
        (3, 1, 8, 4, 16, 64, [16, 48, 1]),
        # one page per sequence
        (2, 4, 1, 1, 32, 32, [7, 32]),
    ],
)
def test_quant_paged_decode_attention(b, kh, g, n_p, ps, d, lens, dtype, rng):
    """In-kernel dequant matches both oracles: the dense one (dequantize
    the pool, run the paged reference) and the blocked page-at-a-time
    recurrence with per-tile dequant."""
    q, kq, vq, ks, vs, tables, lengths = _quant_paged_case(
        rng, b, kh, g, n_p, ps, d, dtype, lens
    )
    out = quant_paged_decode_attention(
        q, kq, vq, ks, vs, tables, lengths, interpret=True
    )
    dense = quant_paged_decode_attention_ref(q, kq, vq, ks, vs, tables, lengths)
    blocked = quant_paged_decode_attention_blocked_ref(
        q, kq, vq, ks, vs, tables, lengths
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(dense, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(blocked, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


def test_quant_paged_close_to_full_precision(rng):
    """int8 round-trip error is bounded (absmax/254 per element), so the
    quantized kernel's output tracks the full-precision paged kernel
    within a loose tolerance — the end-to-end >= 99% greedy token match
    is gated on the real model in tests/test_quantized_serving.py."""
    b, kh, g, n_p, ps, d = 3, 2, 4, 4, 16, 32
    q, k, v, tables, lengths = _paged_case(
        rng, b, kh, g, n_p, ps, d, jnp.float32, [5, 33, 64]
    )
    kq, ks = quantize_pages(k)
    vq, vs = quantize_pages(v)
    out = quant_paged_decode_attention(
        q, kq, vq, ks, vs, tables, lengths, interpret=True
    )
    full = paged_decode_attention_ref(q, k, v, tables, lengths)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(full), atol=5e-2, rtol=5e-2
    )


def test_quant_paged_zero_pages_are_safe(rng):
    """All-zero pages quantize with scale 1.0 (never 0), so sequences
    whose live pages are zeros still produce finite output — and the
    kernel agrees with the dense oracle exactly on that case."""
    b, kh, g, n_p, ps, d = 2, 2, 4, 2, 16, 32
    pool = b * n_p + 1
    k = jnp.zeros((pool, kh, ps, d), jnp.float32)
    v = jnp.zeros((pool, kh, ps, d), jnp.float32)
    q = jnp.asarray(rng.randn(b, kh, g, d), jnp.float32)
    kq, ks = quantize_pages(k)
    vq, vs = quantize_pages(v)
    assert np.all(np.asarray(ks) == 1.0) and np.all(np.asarray(vs) == 1.0)
    tables = jnp.arange(1, pool, dtype=jnp.int32).reshape(b, n_p)
    lengths = jnp.asarray([9, 20], jnp.int32)
    out = quant_paged_decode_attention(
        q, kq, vq, ks, vs, tables, lengths, interpret=True
    )
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)


def test_quantize_pages_round_trip_exact_for_representable(rng):
    """Pages whose entries are exact multiples of their scale survive the
    round trip bit-exactly; dequantize_pages inverts quantize_pages."""
    kh, ps, d = 2, 8, 16
    scale = 0.5
    vals = rng.randint(-127, 128, (3, kh, ps, d)).astype(np.float32) * scale
    vals[:, :, 0, 0] = 127 * scale  # pin each group's absmax -> scale is exact
    kq, ks = quantize_pages(jnp.asarray(vals))
    np.testing.assert_allclose(np.asarray(ks), scale, atol=0, rtol=0)
    back = dequantize_pages(kq, ks)
    np.testing.assert_allclose(np.asarray(back), vals, atol=0, rtol=0)


@pytest.mark.parametrize(
    "b,slen,h,p,n,chunk",
    [(2, 64, 2, 16, 8, 16), (1, 128, 4, 32, 16, 32), (2, 32, 1, 8, 128, 32)],
)
def test_ssd_kernel_and_chunked(b, slen, h, p, n, chunk, rng):
    x = jnp.asarray(rng.randn(b, slen, h, p) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(b, slen, h)) * 0.5 + 0.1, jnp.float32)
    a = jnp.asarray(-np.abs(rng.randn(h)) - 0.2, jnp.float32)
    bm = jnp.asarray(rng.randn(b, slen, h, n) * 0.5, jnp.float32)
    cm = jnp.asarray(rng.randn(b, slen, h, n) * 0.5, jnp.float32)
    y_ref, fs_ref = ssd_ref(x, dt, a, bm, cm)
    y_k, fs_k = ssd(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    y_c, fs_c = ssd_chunked(x, dt, a, bm, cm, chunk)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), atol=5e-5)
    np.testing.assert_allclose(np.asarray(fs_k), np.asarray(fs_ref), atol=5e-5)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_ref), atol=5e-5)
    np.testing.assert_allclose(np.asarray(fs_c), np.asarray(fs_ref), atol=5e-5)


@pytest.mark.parametrize("n,nb", [(1000, 128), (513, 256), (4096, 64)])
def test_bootstrap_kernel_matches_ref(n, nb, rng):
    data = jnp.asarray(rng.randn(n) * 2 + 5, jnp.float32)
    km = bootstrap_means(
        data, jnp.uint32(42), n_boot=nb, block_boot=64, block_n=256, interpret=True
    )
    rm = bootstrap_means_ref(data, nb, 42)
    np.testing.assert_allclose(np.asarray(km), np.asarray(rm), atol=1e-5)


def test_bootstrap_statistics(rng):
    data = jnp.asarray(rng.randn(2000) * 3 + 10, jnp.float32)
    means = bootstrap_means_ref(data, 512, 7)
    sd = float(jnp.std(means))
    expected_se = 3 / np.sqrt(2000)
    assert abs(float(jnp.mean(means)) - 10.0) < 0.3
    assert 0.5 * expected_se < sd < 2.0 * expected_se


@pytest.mark.parametrize(
    "b,lc,lr,d", [(3, 16, 24, 32), (2, 8, 40, 64), (4, 32, 8, 16)]
)
def test_bertscore_kernel(b, lc, lr, d, rng):
    cand = jnp.asarray(rng.randn(b, lc, d), jnp.float32)
    ref = jnp.asarray(rng.randn(b, lr, d), jnp.float32)
    cmask = jnp.asarray(rng.rand(b, lc) > 0.2)
    rmask = jnp.asarray(rng.rand(b, lr) > 0.2)
    p, r = bertscore_pr(cand, ref, cmask, rmask, block_r=16, interpret=True)
    pr, rr, _ = bertscore_ref(cand, ref, cmask, rmask)
    np.testing.assert_allclose(np.asarray(p), np.asarray(pr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(r), np.asarray(rr), atol=1e-5)


def test_bertscore_identity(rng):
    """Identical sequences score P = R = 1."""
    emb = jnp.asarray(rng.randn(2, 12, 32), jnp.float32)
    mask = jnp.ones((2, 12))
    p, r = bertscore_pr(emb, emb, mask, mask, block_r=8, interpret=True)
    np.testing.assert_allclose(np.asarray(p), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(r), 1.0, atol=1e-5)
