"""Quantized paged KV cache (ISSUE 10) through the serving stack: the
real reduced-model int8-vs-bf16 greedy token-match gate, quantized
copy-on-write copying bytes *and* scales verbatim, byte-identical
determinism under preemption pressure, dtype validation, and the
simulated engine's byte-budget accounting surfaced in suite reports.

The token-match workload is pinned (param seed + prompt seeds): greedy
argmax on a random-init reduced model sits on razor-thin logit gaps, so
the acceptable quantization noise is calibrated against this exact
workload — changing the seeds moves the gap distribution, not the
quantizer's quality.
"""

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import (
    EngineModelConfig,
    EvalSession,
    EvalSuite,
    EvalTask,
    InferenceConfig,
    InferenceRequest,
    MetricConfig,
    SimulatedSlotEngine,
    StatisticsConfig,
)
from repro.core.engines import SIM_HEAD_DIM, SIM_KV_HEADS, SIM_LAYERS
from repro.models import params as pm
from repro.models.model import build_model
from repro.serve import ContinuousBatcher, Request
from repro.serve.paged_cache import kv_page_bytes, pages_for_budget

SLOT_MODEL = EngineModelConfig(provider="slotsim", model_name="slot-sim")


@pytest.fixture(scope="module")
def qwen():
    cfg = ARCHS["qwen3-4b"].reduced()
    model = build_model(cfg, remat="none")
    params = pm.init_params(jax.random.key(1), model.param_specs())
    return model, cfg, params


def _workload(cfg, seed, n=10):
    """Mixed shared-prefix + unique-tail prompts (the paged cache's
    target regime): 10 requests, 15-23 prompt tokens, 12 new tokens."""
    rng = np.random.default_rng(seed)
    shared = list(rng.integers(2, cfg.vocab_size, 20))
    reqs = []
    for i in range(n):
        toks = shared[: 12 + (i % 5)] + list(
            rng.integers(2, cfg.vocab_size, 3 + i % 7)
        )
        reqs.append(
            Request(i, prompt_tokens=[int(t) for t in toks], max_new_tokens=12)
        )
    return reqs


def _run(model, cfg, params, reqs, **kw):
    sched = ContinuousBatcher(
        model, cfg, params, n_slots=4, max_len=64, eos_id=1, page_size=16,
        **kw,
    )
    for r in reqs:
        sched.submit(r)
    done = {c.request_id: c for c in sched.run_to_completion()}
    return sched, [done[r.request_id].tokens for r in reqs]


# -- real-model token-match gate --------------------------------------------------


def test_int8_greedy_token_match_floor(qwen):
    """The acceptance gate: int8 pages must reproduce >= 99% of the
    bf16-page greedy tokens on the real reduced model.  Quantization
    noise (~absmax/254 per element) can flip argmax only at near-ties;
    the calibrated workload keeps that below 1% of steps."""
    model, cfg, params = qwen
    total = matched = 0
    for seed in (11, 4):
        reqs = _workload(cfg, seed)
        _, full = _run(model, cfg, params, reqs)
        sq, quant = _run(model, cfg, params, reqs, kv_cache_dtype="int8")
        assert sq.quantized and sq.scales is not None
        for a, b in zip(full, quant):
            total += max(len(a), len(b))
            matched += sum(1 for x, y in zip(a, b) if x == y)
        sq.manager.check_no_leaks()
    assert total >= 200  # enough decode steps for the rate to mean something
    assert matched / total >= 0.99, f"token match {matched}/{total}"


def test_int8_run_is_deterministic(qwen):
    """Quantize-on-write is a pure function of the token history, so two
    int8 runs are byte-identical (the crash-resume / replica-parity
    property at fixed dtype)."""
    model, cfg, params = qwen
    reqs = _workload(cfg, 11)
    _, a = _run(model, cfg, params, reqs, kv_cache_dtype="int8")
    _, b = _run(model, cfg, params, reqs, kv_cache_dtype="int8")
    assert a == b


def test_int8_identical_under_preemption(qwen):
    """A pool too small for the fleet's decode growth forces organic
    preempt/recompute cycles (short prompts, long generations — growth
    past the admission gate's one-page reserve); requantizing the
    replayed history must reproduce the exact bytes, so outputs never
    change (preemption costs work, not correctness)."""
    model, cfg, params = qwen
    rng = np.random.default_rng(11)
    reqs = [
        Request(
            i,
            prompt_tokens=[int(t) for t in rng.integers(
                2, cfg.vocab_size, 10 + i % 5
            )],
            max_new_tokens=40,
        )
        for i in range(8)
    ]
    roomy, a = _run(model, cfg, params, reqs, kv_cache_dtype="int8")
    tight, b = _run(
        model, cfg, params, reqs, kv_cache_dtype="int8", page_pool=8
    )
    assert tight.stats.preemptions > 0
    assert roomy.stats.preemptions == 0
    assert a == b
    tight.manager.check_no_leaks()


# -- quantized copy-on-write ------------------------------------------------------


def test_quantized_cow_copies_bytes_and_scales(qwen):
    """The CoW primitive for int8 pools must copy the stored int8 bytes
    AND the scale rows verbatim — requantizing on copy would round twice
    and break shared-page parity."""
    model, cfg, params = qwen
    sched = ContinuousBatcher(
        model, cfg, params, n_slots=2, max_len=64, eos_id=1, page_size=16,
        kv_cache_dtype="int8", page_pool=23,
    )
    sched.submit(Request(0, prompt_tokens=list(range(10, 30)),
                         max_new_tokens=4))
    sched.run_to_completion()
    src, dst = 1, 9  # src was written by the prefill above
    cache2, scales2 = sched._copy_page_q(sched.cache, sched.scales, src, dst)
    pool_leaves = zip(jax.tree.leaves(sched.cache), jax.tree.leaves(cache2))
    scale_leaves = zip(jax.tree.leaves(sched.scales), jax.tree.leaves(scales2))
    for (p0, p1), (s0, s1) in zip(pool_leaves, scale_leaves):
        n_pages = s0.shape[0]  # scale leaves lead with the page axis
        ax = p0.shape.index(n_pages)
        p0, p1 = np.asarray(p0), np.asarray(p1)
        np.testing.assert_array_equal(
            np.take(p1, dst, axis=ax), np.take(p0, src, axis=ax)
        )
        np.testing.assert_array_equal(np.asarray(s1)[dst], np.asarray(s0)[src])
        # every other page (and its scales) is untouched
        keep = [i for i in range(n_pages) if i != dst]
        np.testing.assert_array_equal(
            np.take(p1, keep, axis=ax), np.take(p0, keep, axis=ax)
        )
        np.testing.assert_array_equal(
            np.asarray(s1)[keep], np.asarray(s0)[keep]
        )


# -- validation -------------------------------------------------------------------


def test_kv_cache_dtype_validation(qwen):
    model, cfg, params = qwen
    with pytest.raises(ValueError, match="kv_page_size|page"):
        ContinuousBatcher(model, cfg, params, kv_cache_dtype="int8")
    with pytest.raises(ValueError, match="int8"):
        ContinuousBatcher(
            model, cfg, params, page_size=16, kv_cache_dtype="fp8"
        )
    with pytest.raises(ValueError, match="mutually exclusive"):
        ContinuousBatcher(
            model, cfg, params, page_size=16, page_pool=8,
            page_pool_bytes=1 << 20,
        )
    with pytest.raises(ValueError):
        SimulatedSlotEngine(SLOT_MODEL, kv_cache_dtype="int8")
    with pytest.raises(ValueError):
        SimulatedSlotEngine(
            SLOT_MODEL, kv_page_size=16, kv_cache_dtype="int4"
        )


# -- simulated engine: byte budgets ----------------------------------------------


def test_sim_engine_byte_budget_capacity():
    """At a fixed pool byte budget the int8 engine admits ~2x the pages
    and halves the advertised bytes-per-token."""
    budget = 14 * kv_page_bytes(16, SIM_KV_HEADS, SIM_HEAD_DIM, SIM_LAYERS)
    bf = SimulatedSlotEngine(
        SLOT_MODEL, kv_page_size=16, page_pool_bytes=budget, step_ms=0.0
    )
    q8 = SimulatedSlotEngine(
        SLOT_MODEL, kv_page_size=16, page_pool_bytes=budget,
        kv_cache_dtype="int8", step_ms=0.0,
    )
    pb_bf = kv_page_bytes(16, SIM_KV_HEADS, SIM_HEAD_DIM, SIM_LAYERS, "bf16")
    pb_q8 = kv_page_bytes(16, SIM_KV_HEADS, SIM_HEAD_DIM, SIM_LAYERS, "int8")
    assert bf._pages.n_pages == pages_for_budget(budget, pb_bf) == 14
    assert q8._pages.n_pages == pages_for_budget(budget, pb_q8)
    assert q8._pages.n_pages / bf._pages.n_pages >= 1.8
    assert bf.stats.kv_bytes_per_token == pb_bf // 16
    assert q8.stats.kv_bytes_per_token == pb_q8 // 16
    assert q8.stats.pool_pages == q8._pages.n_pages
    # the pool partitions its byte budget exactly
    assert q8._pages.pool_bytes == q8._pages.n_pages * pb_q8 <= budget


def test_sim_engine_quantized_identical_under_pressure():
    """Decode growth against a tight byte budget preempts the bf16 pool
    while the token plane never moves: int8 and bf16 produce identical
    texts, and the bf16 side preempts at least as often."""
    budget = 8 * kv_page_bytes(16, SIM_KV_HEADS, SIM_HEAD_DIM, SIM_LAYERS)
    rows = [
        " ".join(f"load{i}w{j}" for j in range(36)) + f" tail {i}"
        for i in range(12)
    ]

    def run(dtype):
        eng = SimulatedSlotEngine(
            SLOT_MODEL, n_slots=4, step_ms=0.0, kv_page_size=16,
            kv_cache_dtype=dtype, page_pool_bytes=budget,
            decode_page_growth=True, min_out=32, max_out=48,
        )
        eng.initialize()
        reqs = {
            eng.stream_submit(InferenceRequest(p, 48, 0.0)): p for p in rows
        }
        out = {}
        while eng.stream_pending():
            for rid, resp in eng.stream_pump():
                out[reqs[rid]] = resp.text
        eng._pages.check_no_leaks()
        return out, eng.stats

    bf_out, bf_stats = run("bf16")
    q8_out, q8_stats = run("int8")
    assert bf_out == q8_out
    assert bf_stats.preemptions > 0  # the budget actually binds
    assert q8_stats.preemptions <= bf_stats.preemptions


def test_inference_config_forwards_kv_cache_dtype():
    """``InferenceConfig.kv_cache_dtype`` reaches the engine through the
    session's paging kwargs, and the per-token byte rate lands in the
    serving snapshot and the suite markdown."""
    rows = [
        {"question": f"fwd check question {i} please", "reference": f"r {i}"}
        for i in range(8)
    ]
    task = EvalTask(
        task_id="fwd",
        model=SLOT_MODEL,
        inference=InferenceConfig(
            batch_size=8, n_workers=2, kv_page_size=16, kv_cache_dtype="int8"
        ),
        metrics=(MetricConfig("exact_match"),),
        statistics=StatisticsConfig(
            bootstrap_iterations=100, ci_method="percentile"
        ),
    )
    suite = EvalSuite("quantmd").add_task(task, rows)
    with EvalSession(engine_kwargs={"n_slots": 4, "step_ms": 0.0}) as session:
        sres = session.run_suite(suite)
        (snap,) = session.serving_stats()
    expect = kv_page_bytes(16, SIM_KV_HEADS, SIM_HEAD_DIM, SIM_LAYERS, "int8")
    assert snap["batcher"]["kv_bytes_per_token"] == expect // 16
    md = sres.to_markdown()
    assert "| kv B/tok " in md
    assert f" {expect // 16} " in md
