"""Dry-run integration: the production-mesh lowering pipeline end-to-end.

Runs in a subprocess because the dry-run forces 512 placeholder devices via
XLA_FLAGS, which must never leak into this (single-device) test process.
"""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_dryrun_single_cell(tmp_path):
    out_dir = tmp_path / "dryrun"
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "mamba2-2.7b", "--shape", "decode_32k",
            "--skip-accounting", "--out-dir", str(out_dir),
        ],
        cwd=REPO,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        capture_output=True,
        text=True,
        timeout=900,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    rec_path = out_dir / "mamba2-2.7b_decode_32k_16x16.json"
    assert rec_path.exists()
    rec = json.loads(rec_path.read_text())
    assert rec["ok"] and rec["chips"] == 256
    assert rec["memory"]["peak_bytes_est"] > 0
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
    # a 2.7B bf16 model on 256 chips must comfortably fit v5e HBM
    assert rec["memory"]["peak_bytes_est"] < 16e9


@pytest.mark.slow
def test_dryrun_multi_pod_cell(tmp_path):
    out_dir = tmp_path / "dryrun"
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "qwen3-4b", "--shape", "decode_32k",
            "--multi-pod", "--out-dir", str(out_dir),
        ],
        cwd=REPO,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        capture_output=True,
        text=True,
        timeout=900,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    rec = json.loads((out_dir / "qwen3-4b_decode_32k_2x16x16.json").read_text())
    assert rec["chips"] == 512  # proves the "pod" axis shards
