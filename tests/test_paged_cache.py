"""Paged KV cache manager (ISSUE 8): hash-chain prefix matching,
refcounted sharing, LRU eviction of cached pages, copy-on-write
divergence, and no-leak invariants under churn — plus (ISSUE 10) the
byte-accounting layer for quantized pools: bytes-per-page formulae,
byte-budget sizing, partition invariants, and int8 round-trip bounds."""

import random

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.serve.paged_cache import (
    PagedCacheManager,
    kv_page_bytes,
    page_hash_chain,
    pages_for_budget,
)


def _mgr(n_pages=32, page_size=4, **kw):
    return PagedCacheManager(n_pages, page_size, **kw)


# -- hash chain ------------------------------------------------------------------


def test_hash_chain_one_digest_per_full_page():
    assert page_hash_chain([1, 2, 3], 4) == []
    assert len(page_hash_chain(list(range(8)), 4)) == 2
    assert len(page_hash_chain(list(range(9)), 4)) == 2


def test_hash_chain_commits_to_whole_prefix():
    a = page_hash_chain([1, 2, 3, 4, 5, 6, 7, 8], 4)
    b = page_hash_chain([1, 2, 3, 4, 5, 6, 7, 8], 4)
    assert a == b
    # same second page, different first page -> different second digest
    c = page_hash_chain([9, 2, 3, 4, 5, 6, 7, 8], 4)
    assert a[0] != c[0] and a[1] != c[1]
    # token-boundary ambiguity does not collide: [12,3] vs [1,23]
    assert page_hash_chain([12, 3], 2) != page_hash_chain([1, 23], 2)


# -- acquire / release -----------------------------------------------------------


def test_acquire_allocates_ceil_pages_and_release_frees():
    m = _mgr()
    match = m.acquire("a", list(range(10)))  # 10 tokens / 4 -> 3 pages
    assert len(match.page_ids) == 3
    assert match.n_shared_pages == 0
    assert m.pages_active == 3
    m.release("a")
    m.check_no_leaks()


def test_prefix_reuse_after_release_hits_cached_pages():
    m = _mgr()
    toks = list(range(10))
    m.acquire("a", toks)
    m.register("a", toks)  # indexes the 2 full pages
    m.release("a")
    assert m.pages_cached == 2 and m.pages_free == 30
    match = m.acquire("b", toks)
    assert match.n_shared_pages == 2
    assert match.n_shared_tokens == 8
    assert m.stats.prefix_tokens_saved == 8
    m.release("b")
    m.check_no_leaks()


def test_final_token_page_never_shared():
    """A prompt that is an exact multiple of the page size still prefills
    its last page: sharing stops at (len-1)//ps pages."""
    m = _mgr()
    toks = list(range(8))  # exactly 2 pages
    m.acquire("a", toks)
    m.register("a", toks)
    m.release("a")
    match = m.acquire("b", toks)
    assert match.n_shared_pages == 1  # not 2: last page stays private
    assert len(match.page_ids) == 2
    m.release("b")


def test_divergent_prefix_shares_only_matching_pages():
    m = _mgr()
    base = list(range(12))
    m.acquire("a", base)
    m.register("a", base)
    fork = base[:8] + [99, 98, 97, 96]  # diverges at page 2
    match = m.acquire("b", fork)
    assert match.n_shared_pages == 2
    shared_ids = match.page_ids[:2]
    assert [m.refcount(p) for p in shared_ids] == [2, 2]
    m.release("a")
    assert [m.refcount(p) for p in shared_ids] == [1, 1]
    m.release("b")
    m.check_no_leaks()


def test_concurrent_sharers_refcount():
    m = _mgr()
    toks = list(range(16))
    m.acquire("a", toks)
    m.register("a", toks)
    owners = [f"o{i}" for i in range(5)]
    for o in owners:
        assert m.acquire(o, toks).n_shared_pages == 3
    first = m.table("a")[0]
    assert m.refcount(first) == 6
    for o in owners + ["a"]:
        m.release(o)
    m.check_no_leaks()


def test_duplicate_registration_keeps_first_mapping():
    """Two identical prompts prefilled concurrently (neither registered
    when the other acquired): second register is a no-op and both release
    cleanly."""
    m = _mgr()
    toks = list(range(10))
    m.acquire("a", toks)
    m.acquire("b", toks)  # nothing indexed yet -> no sharing
    assert m.pages_active == 6
    assert m.register("a", toks) == 2
    assert m.register("b", toks) == 0  # first registration wins
    m.release("a")
    m.release("b")
    # a's indexed pages parked in the prefix cache, b's freed outright
    assert m.pages_cached == 2
    m.check_no_leaks()


def test_acquire_rejects_double_owner_and_empty_prompt():
    m = _mgr()
    m.acquire("a", [1, 2, 3])
    with pytest.raises(ValueError):
        m.acquire("a", [4, 5])
    with pytest.raises(ValueError):
        m.acquire("b", [])


# -- eviction / exhaustion -------------------------------------------------------


def test_lru_eviction_of_cached_pages_under_pressure():
    m = _mgr(n_pages=8, page_size=4)
    for i in range(3):  # park 2 indexed pages per round, LRU order
        toks = [i * 100 + t for t in range(9)]
        m.acquire(f"o{i}", toks)
        m.register(f"o{i}", toks)
        m.release(f"o{i}")
    assert m.pages_cached + m.pages_free == 8
    # a 8-page prompt must evict cached pages to fit
    m.acquire("big", list(range(1000, 1029)))
    assert m.stats.evictions > 0
    # oldest chain (o0) evicted first: re-acquiring it finds nothing
    m.release("big")
    assert m.acquire("probe", [0, 1, 2, 3, 4]).n_shared_pages == 0
    m.release("probe")
    m.check_no_leaks()


def test_pool_exhaustion_by_active_pages_raises():
    m = _mgr(n_pages=4, page_size=4)
    m.acquire("a", list(range(16)))  # all 4 pages active
    with pytest.raises(RuntimeError, match="exhausted"):
        m.acquire("b", [1, 2, 3])
    m.release("a")
    m.check_no_leaks()


def test_matched_pages_survive_allocation_pressure_in_same_acquire():
    """The fresh-page allocation of an acquire must not LRU-evict the
    pages its own prefix walk just matched."""
    m = _mgr(n_pages=4, page_size=2)
    toks = [1, 2, 3, 4, 5]
    m.acquire("a", toks)
    m.register("a", toks)
    m.release("a")  # 2 cached + ... pool: 3 pages used, 1 free
    match = m.acquire("b", toks)  # needs 1 fresh page beyond the 2 shared
    assert match.n_shared_pages == 2
    assert len(set(match.page_ids)) == 3
    m.release("b")
    m.check_no_leaks()


# -- ensure_position / copy-on-write --------------------------------------------


def test_ensure_position_extends_table():
    m = _mgr(page_size=4)
    m.acquire("a", [1, 2, 3])
    pw = m.ensure_position("a", 3)  # same page, private -> in place
    assert not pw.allocated and pw.cow_src is None and pw.offset == 3
    pw = m.ensure_position("a", 4)  # next page
    assert pw.allocated and pw.page_index == 1 and pw.offset == 0
    with pytest.raises(ValueError):
        m.ensure_position("a", 12)  # non-contiguous
    m.release("a")
    m.check_no_leaks()


def test_ensure_position_cow_on_shared_page():
    m = _mgr(page_size=4)
    toks = list(range(12))
    m.acquire("a", toks)
    m.register("a", toks)
    m.acquire("b", toks)  # shares pages 0-1
    shared = m.table("b")[0]
    pw = m.ensure_position("b", 1)  # write inside a shared page
    assert pw.cow_src == shared
    assert pw.page_id != shared
    assert m.table("b")[0] == pw.page_id
    assert m.refcount(shared) == 1  # only "a" holds it now
    assert m.stats.cow_copies == 1
    m.release("a")
    m.release("b")
    m.check_no_leaks()


def test_ensure_position_cow_on_indexed_private_page():
    """Even with refcount 1, an *indexed* page is copy-on-write: writing
    in place would leave a stale hash in the prefix index."""
    m = _mgr(page_size=4)
    toks = list(range(8))
    m.acquire("a", toks)
    m.register("a", toks)
    indexed = m.table("a")[0]
    pw = m.ensure_position("a", 2)
    assert pw.cow_src == indexed
    # the old page parks in the prefix cache, still matchable
    assert m.pages_cached == 1
    m.release("a")
    m.check_no_leaks()


# -- churn stress ---------------------------------------------------------------


# -- byte accounting (quantized pools) -------------------------------------------


def test_kv_page_bytes_formula():
    """bf16 pages cost 2 bytes/elem; int8 pages cost 1 byte/elem plus one
    f32 scale per (layer, kv head, K/V) — under 1% overhead at 16x64."""
    bf16 = kv_page_bytes(16, 8, 64, 4)
    int8 = kv_page_bytes(16, 8, 64, 4, "int8")
    elems = 2 * 4 * 8 * 16 * 64  # K+V x layers x heads x page x head_dim
    assert bf16 == elems * 2 == 131072
    assert int8 == elems + 2 * 4 * 8 * 4 == 65792
    assert bf16 / int8 >= 1.8  # the capacity lever the benchmark gates on
    with pytest.raises(ValueError):
        kv_page_bytes(16, 8, 64, 4, "fp8")


def test_pages_for_budget():
    pb = kv_page_bytes(16, 8, 64, 4)
    assert pages_for_budget(10 * pb, pb) == 10
    assert pages_for_budget(10 * pb + pb - 1, pb) == 10  # floor, never round up
    with pytest.raises(ValueError):
        pages_for_budget(pb - 1, pb)  # budget below a single page


def test_byte_partition_tracks_page_partition():
    """With ``page_bytes`` set, the byte view is page counts scaled: the
    free/cached/active partition holds in bytes at every transition and
    check_no_leaks enforces it."""
    m = _mgr(n_pages=8, page_size=4, page_bytes=100)
    assert m.pool_bytes == 800
    assert m.kv_bytes_per_token == 25
    m.acquire("a", list(range(10)))  # 3 pages active
    m.register("a", list(range(10)))
    assert (m.bytes_active, m.bytes_cached, m.bytes_free) == (300, 0, 500)
    m.release("a")  # 2 full pages park in the prefix cache
    assert (m.bytes_active, m.bytes_cached, m.bytes_free) == (0, 200, 600)
    assert m.bytes_free + m.bytes_cached + m.bytes_active == m.pool_bytes
    m.check_no_leaks()


def test_launch_cells_int8_cache_meta_matches_pool_formula():
    """The analytical serve cells charge int8 caches the same per-page
    f32 scale overhead as the byte-budgeted serving pool: a GQA decode
    cell's ``cache_bytes`` meta equals pages x kv_page_bytes exactly."""
    import jax
    import jax.numpy as jnp

    from repro.configs import SHAPES, get_config
    from repro.launch.cells import build_cell

    devs = np.array(jax.devices() * 16)[:16]
    mesh = jax.sharding.Mesh(devs.reshape(4, 4), ("data", "model"))
    cfg = get_config("qwen3-4b")
    scfg = SHAPES["decode_32k"]
    assert scfg.seq_len % 16 == 0
    cell = build_cell("qwen3-4b", "decode_32k", mesh, cache_dtype=jnp.int8)
    pages = scfg.global_batch * (scfg.seq_len // 16)
    assert cell.meta["cache_bytes"] == pages * kv_page_bytes(
        16, cfg.n_kv_heads, cfg.head_dim, cfg.n_layers, "int8"
    )
    # and the bf16 cell sees the ~2x capacity lever the pool advertises
    bf16 = build_cell("qwen3-4b", "decode_32k", mesh)
    assert bf16.meta["cache_bytes"] / cell.meta["cache_bytes"] >= 1.8


# -- int8 round-trip bounds (hypothesis + deterministic counterparts) ------------


def _round_trip_check(vals):
    """Shared property body: |dequant - x| <= scale/2 per group, zero
    groups get scale 1.0 exactly."""
    import jax.numpy as jnp

    from repro.kernels.decode_attention import absmax_dequantize, absmax_quantize

    x = np.asarray(vals, np.float32).reshape(1, -1)
    q, s = absmax_quantize(jnp.asarray(x), (1,))
    back = np.asarray(absmax_dequantize(q, s, (1,)))
    scale = float(np.asarray(s)[0])
    absmax = float(np.abs(x).max())
    if absmax == 0.0:
        assert scale == 1.0
        assert (back == 0.0).all()
    else:
        assert scale == pytest.approx(absmax / 127.0, rel=1e-6)
        # bound: half a quantization step, plus f32 rounding headroom
        assert np.abs(back - x).max() <= scale / 2 + 1e-6 * absmax


@given(
    st.lists(
        st.floats(-1e6, 1e6, width=32, allow_nan=False, allow_infinity=False),
        min_size=1,
        max_size=64,
    )
)
@settings(max_examples=50, deadline=None)
def test_quant_round_trip_error_bounded_property(vals):
    """Property: for arbitrary f32 content (zeros, denormals, outliers)
    the absmax round-trip error never exceeds half a quantization step
    of its own group."""
    _round_trip_check(vals)


def test_quant_round_trip_error_bounded_examples():
    """Deterministic counterpart: all-zero group, a single-outlier group
    (one huge head crushing resolution elsewhere is bounded by *its own*
    group's scale), and a plain random group."""
    rng = np.random.RandomState(0)
    _round_trip_check([0.0] * 16)
    _round_trip_check([1e6] + [1e-3] * 15)
    _round_trip_check(list(rng.randn(64)))


def test_quant_masked_rows_excluded_from_scale_and_bytes():
    """The write-path mask keeps stale rows out of the absmax AND the
    stored bytes — quantized content is a pure function of valid
    history, the determinism the serving stack's resume relies on."""
    import jax.numpy as jnp

    from repro.kernels.decode_attention import absmax_quantize

    x = np.zeros((1, 4), np.float32)
    x[0, :2] = [1.0, -2.0]
    stale = x.copy()
    stale[0, 2:] = 1e6  # garbage beyond the valid prefix
    mask = np.asarray([[True, True, False, False]])
    q1, s1 = absmax_quantize(jnp.asarray(x), (1,), mask=jnp.asarray(mask))
    q2, s2 = absmax_quantize(jnp.asarray(stale), (1,), mask=jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    assert float(np.asarray(s1)[0]) == pytest.approx(2.0 / 127.0)
    assert (np.asarray(q1)[0, 2:] == 0).all()  # masked rows store zero bytes


# -- churn stress ---------------------------------------------------------------


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=15, deadline=None)
def test_byte_partition_invariant_under_churn_property(seed):
    """Property: under arbitrary acquire/register/extend/release churn on
    a byte-accounted (quantized-geometry) pool, free+cached+active bytes
    always partition the pool budget exactly."""
    _churn(random.Random(seed), page_bytes=65792)


def _churn(rnd, page_bytes=0):
    m = _mgr(n_pages=32, page_size=4, page_bytes=page_bytes)
    live: dict[int, list[int]] = {}
    headers = [[h * 1000 + t for t in range(12)] for h in range(3)]
    for i in range(120):
        roll = rnd.random()
        if live and (roll < 0.35 or len(live) >= 8):
            owner = rnd.choice(list(live))
            m.release(owner)
            del live[owner]
        elif live and roll < 0.55:
            owner = rnd.choice(list(live))
            m.ensure_position(owner, len(live[owner]))
            live[owner].append(i)
        else:
            toks = rnd.choice(headers) + [i, i + 1]
            try:
                m.acquire(i, toks)
            except RuntimeError:
                continue  # pool exhausted under churn: fine, keep going
            m.register(i, toks)
            live[i] = toks
        assert m.pages_free + m.pages_cached + m.pages_active == 32
        assert (
            m.bytes_free + m.bytes_cached + m.bytes_active == m.pool_bytes
        )
    for owner in list(live):
        m.release(owner)
    m.check_no_leaks()


def test_byte_partition_invariant_under_churn_examples():
    """Deterministic counterpart of the churn property."""
    for seed in (0, 7):
        _churn(random.Random(seed), page_bytes=65792)


def test_no_leaks_under_interleaved_shared_prefix_churn():
    rnd = random.Random(0)
    m = _mgr(n_pages=64, page_size=4)
    headers = [[h * 1000 + t for t in range(12)] for h in range(3)]
    live: dict[int, list[int]] = {}
    for i in range(200):
        if live and (rnd.random() < 0.45 or len(live) >= 10):
            owner = rnd.choice(list(live))
            m.release(owner)
            del live[owner]
        else:
            toks = rnd.choice(headers) + [i, i + 1]
            m.acquire(i, toks)
            m.register(i, toks)
            live[i] = toks
        assert m.pages_free + m.pages_cached + m.pages_active == 64
    for owner in list(live):
        m.release(owner)
    m.check_no_leaks()
    assert m.stats.prefix_tokens_saved > 0
