"""Paged KV cache manager (ISSUE 8): hash-chain prefix matching,
refcounted sharing, LRU eviction of cached pages, copy-on-write
divergence, and no-leak invariants under churn."""

import random

import pytest

from repro.serve.paged_cache import PagedCacheManager, page_hash_chain


def _mgr(n_pages=32, page_size=4, **kw):
    return PagedCacheManager(n_pages, page_size, **kw)


# -- hash chain ------------------------------------------------------------------


def test_hash_chain_one_digest_per_full_page():
    assert page_hash_chain([1, 2, 3], 4) == []
    assert len(page_hash_chain(list(range(8)), 4)) == 2
    assert len(page_hash_chain(list(range(9)), 4)) == 2


def test_hash_chain_commits_to_whole_prefix():
    a = page_hash_chain([1, 2, 3, 4, 5, 6, 7, 8], 4)
    b = page_hash_chain([1, 2, 3, 4, 5, 6, 7, 8], 4)
    assert a == b
    # same second page, different first page -> different second digest
    c = page_hash_chain([9, 2, 3, 4, 5, 6, 7, 8], 4)
    assert a[0] != c[0] and a[1] != c[1]
    # token-boundary ambiguity does not collide: [12,3] vs [1,23]
    assert page_hash_chain([12, 3], 2) != page_hash_chain([1, 23], 2)


# -- acquire / release -----------------------------------------------------------


def test_acquire_allocates_ceil_pages_and_release_frees():
    m = _mgr()
    match = m.acquire("a", list(range(10)))  # 10 tokens / 4 -> 3 pages
    assert len(match.page_ids) == 3
    assert match.n_shared_pages == 0
    assert m.pages_active == 3
    m.release("a")
    m.check_no_leaks()


def test_prefix_reuse_after_release_hits_cached_pages():
    m = _mgr()
    toks = list(range(10))
    m.acquire("a", toks)
    m.register("a", toks)  # indexes the 2 full pages
    m.release("a")
    assert m.pages_cached == 2 and m.pages_free == 30
    match = m.acquire("b", toks)
    assert match.n_shared_pages == 2
    assert match.n_shared_tokens == 8
    assert m.stats.prefix_tokens_saved == 8
    m.release("b")
    m.check_no_leaks()


def test_final_token_page_never_shared():
    """A prompt that is an exact multiple of the page size still prefills
    its last page: sharing stops at (len-1)//ps pages."""
    m = _mgr()
    toks = list(range(8))  # exactly 2 pages
    m.acquire("a", toks)
    m.register("a", toks)
    m.release("a")
    match = m.acquire("b", toks)
    assert match.n_shared_pages == 1  # not 2: last page stays private
    assert len(match.page_ids) == 2
    m.release("b")


def test_divergent_prefix_shares_only_matching_pages():
    m = _mgr()
    base = list(range(12))
    m.acquire("a", base)
    m.register("a", base)
    fork = base[:8] + [99, 98, 97, 96]  # diverges at page 2
    match = m.acquire("b", fork)
    assert match.n_shared_pages == 2
    shared_ids = match.page_ids[:2]
    assert [m.refcount(p) for p in shared_ids] == [2, 2]
    m.release("a")
    assert [m.refcount(p) for p in shared_ids] == [1, 1]
    m.release("b")
    m.check_no_leaks()


def test_concurrent_sharers_refcount():
    m = _mgr()
    toks = list(range(16))
    m.acquire("a", toks)
    m.register("a", toks)
    owners = [f"o{i}" for i in range(5)]
    for o in owners:
        assert m.acquire(o, toks).n_shared_pages == 3
    first = m.table("a")[0]
    assert m.refcount(first) == 6
    for o in owners + ["a"]:
        m.release(o)
    m.check_no_leaks()


def test_duplicate_registration_keeps_first_mapping():
    """Two identical prompts prefilled concurrently (neither registered
    when the other acquired): second register is a no-op and both release
    cleanly."""
    m = _mgr()
    toks = list(range(10))
    m.acquire("a", toks)
    m.acquire("b", toks)  # nothing indexed yet -> no sharing
    assert m.pages_active == 6
    assert m.register("a", toks) == 2
    assert m.register("b", toks) == 0  # first registration wins
    m.release("a")
    m.release("b")
    # a's indexed pages parked in the prefix cache, b's freed outright
    assert m.pages_cached == 2
    m.check_no_leaks()


def test_acquire_rejects_double_owner_and_empty_prompt():
    m = _mgr()
    m.acquire("a", [1, 2, 3])
    with pytest.raises(ValueError):
        m.acquire("a", [4, 5])
    with pytest.raises(ValueError):
        m.acquire("b", [])


# -- eviction / exhaustion -------------------------------------------------------


def test_lru_eviction_of_cached_pages_under_pressure():
    m = _mgr(n_pages=8, page_size=4)
    for i in range(3):  # park 2 indexed pages per round, LRU order
        toks = [i * 100 + t for t in range(9)]
        m.acquire(f"o{i}", toks)
        m.register(f"o{i}", toks)
        m.release(f"o{i}")
    assert m.pages_cached + m.pages_free == 8
    # a 8-page prompt must evict cached pages to fit
    m.acquire("big", list(range(1000, 1029)))
    assert m.stats.evictions > 0
    # oldest chain (o0) evicted first: re-acquiring it finds nothing
    m.release("big")
    assert m.acquire("probe", [0, 1, 2, 3, 4]).n_shared_pages == 0
    m.release("probe")
    m.check_no_leaks()


def test_pool_exhaustion_by_active_pages_raises():
    m = _mgr(n_pages=4, page_size=4)
    m.acquire("a", list(range(16)))  # all 4 pages active
    with pytest.raises(RuntimeError, match="exhausted"):
        m.acquire("b", [1, 2, 3])
    m.release("a")
    m.check_no_leaks()


def test_matched_pages_survive_allocation_pressure_in_same_acquire():
    """The fresh-page allocation of an acquire must not LRU-evict the
    pages its own prefix walk just matched."""
    m = _mgr(n_pages=4, page_size=2)
    toks = [1, 2, 3, 4, 5]
    m.acquire("a", toks)
    m.register("a", toks)
    m.release("a")  # 2 cached + ... pool: 3 pages used, 1 free
    match = m.acquire("b", toks)  # needs 1 fresh page beyond the 2 shared
    assert match.n_shared_pages == 2
    assert len(set(match.page_ids)) == 3
    m.release("b")
    m.check_no_leaks()


# -- ensure_position / copy-on-write --------------------------------------------


def test_ensure_position_extends_table():
    m = _mgr(page_size=4)
    m.acquire("a", [1, 2, 3])
    pw = m.ensure_position("a", 3)  # same page, private -> in place
    assert not pw.allocated and pw.cow_src is None and pw.offset == 3
    pw = m.ensure_position("a", 4)  # next page
    assert pw.allocated and pw.page_index == 1 and pw.offset == 0
    with pytest.raises(ValueError):
        m.ensure_position("a", 12)  # non-contiguous
    m.release("a")
    m.check_no_leaks()


def test_ensure_position_cow_on_shared_page():
    m = _mgr(page_size=4)
    toks = list(range(12))
    m.acquire("a", toks)
    m.register("a", toks)
    m.acquire("b", toks)  # shares pages 0-1
    shared = m.table("b")[0]
    pw = m.ensure_position("b", 1)  # write inside a shared page
    assert pw.cow_src == shared
    assert pw.page_id != shared
    assert m.table("b")[0] == pw.page_id
    assert m.refcount(shared) == 1  # only "a" holds it now
    assert m.stats.cow_copies == 1
    m.release("a")
    m.release("b")
    m.check_no_leaks()


def test_ensure_position_cow_on_indexed_private_page():
    """Even with refcount 1, an *indexed* page is copy-on-write: writing
    in place would leave a stale hash in the prefix index."""
    m = _mgr(page_size=4)
    toks = list(range(8))
    m.acquire("a", toks)
    m.register("a", toks)
    indexed = m.table("a")[0]
    pw = m.ensure_position("a", 2)
    assert pw.cow_src == indexed
    # the old page parks in the prefix cache, still matchable
    assert m.pages_cached == 1
    m.release("a")
    m.check_no_leaks()


# -- churn stress ---------------------------------------------------------------


def test_no_leaks_under_interleaved_shared_prefix_churn():
    rnd = random.Random(0)
    m = _mgr(n_pages=64, page_size=4)
    headers = [[h * 1000 + t for t in range(12)] for h in range(3)]
    live: dict[int, list[int]] = {}
    for i in range(200):
        if live and (rnd.random() < 0.45 or len(live) >= 10):
            owner = rnd.choice(list(live))
            m.release(owner)
            del live[owner]
        else:
            toks = rnd.choice(headers) + [i, i + 1]
            m.acquire(i, toks)
            m.register(i, toks)
            live[i] = toks
        assert m.pages_free + m.pages_cached + m.pages_active == 64
    for owner in list(live):
        m.release(owner)
    m.check_no_leaks()
    assert m.stats.prefix_tokens_saved > 0
