"""Metric implementations: known values + property tests."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import EngineModelConfig, SimulatedAPIEngine
from repro.metrics import (
    HashEmbedder,
    bleu,
    contains,
    embedding_similarity,
    exact_match,
    normalize,
    pointwise_judge,
    rouge_l,
    token_f1,
)
from repro.metrics.rag import context_precision, context_recall
from repro.metrics.semantic import bertscore_f1


def test_normalize():
    assert normalize("The  Quick, Brown Fox!") == "quick brown fox"
    assert normalize("An apple") == "apple"


def test_exact_match_and_contains():
    assert exact_match("The Answer", "answer") == 1.0
    assert exact_match("other", "answer") == 0.0
    assert contains("well the answer is 42", "answer is 42") == 1.0


def test_token_f1_known():
    assert token_f1("quick brown fox", "quick fox") == pytest.approx(0.8)
    assert token_f1("", "") == 1.0
    assert token_f1("x", "") == 0.0


def test_bleu_known():
    assert bleu("quick brown fox jumps high", "quick brown fox jumps high") > 0.99
    assert bleu("completely different words here now", "quick brown fox jumps") < 0.05
    # brevity penalty: shorter candidate penalized
    full = bleu("quick brown fox jumps high", "quick brown fox jumps high")
    short = bleu("quick brown fox", "quick brown fox jumps high")
    assert short < full


def test_rouge_l_known():
    assert rouge_l("x y z w", "x z w v") == pytest.approx(0.75)
    assert rouge_l("same text here", "same text here") == 1.0


@given(st.text(alphabet="abcdefg ", min_size=1, max_size=40))
@settings(max_examples=40, deadline=None)
def test_lexical_identity_and_range(s):
    for fn in (token_f1, rouge_l, bleu):
        v = fn(s, s)
        assert 0.0 <= v <= 1.0 + 1e-9
    if normalize(s):
        assert token_f1(s, s) == pytest.approx(1.0)
        assert rouge_l(s, s) == pytest.approx(1.0)


@given(
    st.text(alphabet="abcdefg ", min_size=1, max_size=30),
    st.text(alphabet="abcdefg ", min_size=1, max_size=30),
)
@settings(max_examples=40, deadline=None)
def test_lexical_symmetric_range(a, b):
    for fn in (token_f1, rouge_l):
        assert 0.0 <= fn(a, b) <= 1.0 + 1e-9


def test_embedding_similarity_orders_similarity():
    sims = embedding_similarity(
        ["gravity bends light", "gravity bends light", "pancake recipe batter"],
        ["gravity bends light rays", "pancake recipe batter", "pancake recipe batter"],
    )
    assert sims[0] > sims[1]
    assert sims[2] > sims[1]
    assert sims[2] > 0.9


def test_bertscore_f1_identity():
    f1 = bertscore_f1(["alpha beta gamma"], ["alpha beta gamma"])
    assert f1[0] == pytest.approx(1.0, abs=1e-5)
    f1b = bertscore_f1(["alpha beta gamma"], ["delta epsilon zeta"])
    assert f1b[0] < 0.5


def test_judge_parsing_and_unparseable():
    engine = SimulatedAPIEngine(
        EngineModelConfig(provider="openai", model_name="gpt-4o")
    )
    engine.initialize()
    qs = [f"Question {i}: why is the sky blue?" for i in range(40)]
    rs = [f"Because of Rayleigh scattering variant {i}." for i in range(40)]
    out = pointwise_judge(engine, qs, rs, scale=5)
    ok = out.scores[~np.isnan(out.scores)]
    assert len(ok) + len(out.unparseable) == 40
    assert np.all((ok >= 1) & (ok <= 5))


def test_context_precision_and_recall():
    contexts = [[
        "noise chunk entirely", "gravity was discovered in 1687", "more noise"
    ]]
    refs = ["gravity was discovered in 1687"]
    cp = context_precision(contexts, refs)
    assert 0.4 < cp[0] <= 1.0  # relevant chunk at rank 2 of 3
    cr = context_recall(contexts, refs)
    assert cr[0] == 1.0
    cr2 = context_recall([["unrelated text"]], refs)
    assert cr2[0] < 0.5


def test_hash_embedder_determinism():
    e1, e2 = HashEmbedder(), HashEmbedder()
    v1, v2 = e1.embed("deterministic vector"), e2.embed("deterministic vector")
    np.testing.assert_array_equal(v1, v2)
    assert abs(np.linalg.norm(v1) - 1.0) < 1e-6
